# Developer entry points. `make test` is the tier-1 verification command.
PY := python
export PYTHONPATH := src

.PHONY: test test-fast bench bench-smoke bench-sched check-clean ci

# Tier-1: full test suite (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# quick slice while iterating on the scheduler stack
test-fast:
	$(PY) -m pytest -x -q tests/test_scheduler_core.py tests/test_multi_class.py

# full paper-table benchmark suite
bench:
	$(PY) benchmarks/run.py

# K-class sweep at tiny n_ticks — CI-sized sanity pass
bench-smoke:
	$(PY) benchmarks/multi_class.py --smoke

# scheduler-throughput microbenchmark -> BENCH_scheduler.json
# (slots/sec at K=2 vs K=8 plus the batch-dispatch B x N sweep; the perf
# trajectory future PRs compare against)
bench-sched:
	$(PY) benchmarks/multi_class.py --sched-only

# repo hygiene: no bytecode may ever be tracked
check-clean:
	@bad=$$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$$' || true); \
	if [ -n "$$bad" ]; then \
		echo "ERROR: tracked bytecode files:"; echo "$$bad"; exit 1; \
	fi; echo "check-clean: no tracked __pycache__/*.pyc"

# CI entry point: hygiene check, tier-1 tests, CI-sized bench smoke
ci: check-clean test bench-smoke
