# Developer entry points. `make test` is the tier-1 verification command.
PY := python
export PYTHONPATH := src

.PHONY: test test-fast bench bench-smoke bench-sched bench-scenarios \
	check-bench check-clean ci

# Tier-1: full test suite (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# quick slice while iterating on the scheduler stack: the scheduler/sim
# test files minus the `slow`-marked long sim-horizon tests (~1 min);
# CI runs the full suite via `make test`
test-fast:
	$(PY) -m pytest -x -q -m "not slow" \
		tests/test_scheduler_core.py tests/test_multi_class.py \
		tests/test_batch_dispatch.py tests/test_sim.py \
		tests/test_scenarios.py

# full paper-table benchmark suite
bench:
	$(PY) benchmarks/run.py

# CI-sized sanity pass: K-class sweep + scenario sweep at tiny horizons,
# both exiting nonzero on any non-finite aggregate metric
bench-smoke:
	$(PY) benchmarks/multi_class.py --smoke
	$(PY) benchmarks/scenario_sweep.py --smoke

# scheduler-throughput microbenchmark -> BENCH_scheduler.json
# (slots/sec at K=2 vs K=8 plus the batch-dispatch B x N sweep; the perf
# trajectory future PRs compare against)
bench-sched:
	$(PY) benchmarks/multi_class.py --sched-only

# full nonstationary scenario grid -> BENCH_scenarios.json
bench-scenarios:
	$(PY) benchmarks/scenario_sweep.py

# bench-regression gate: fresh B=16 dispatch rate vs the committed
# BENCH_scheduler.json baseline (>30% drop fails; BENCH_TOLERANCE widens)
check-bench:
	$(PY) benchmarks/check_regression.py

# repo hygiene: no bytecode may ever be tracked
check-clean:
	@bad=$$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$$' || true); \
	if [ -n "$$bad" ]; then \
		echo "ERROR: tracked bytecode files:"; echo "$$bad"; exit 1; \
	fi; echo "check-clean: no tracked __pycache__/*.pyc"

# CI entry point (.github/workflows/ci.yml runs exactly this): hygiene
# check, tier-1 tests, CI-sized bench smoke, bench-regression gate
ci: check-clean test bench-smoke check-bench
