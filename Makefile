# Developer entry points. `make test` is the tier-1 verification command.
PY := python
export PYTHONPATH := src

.PHONY: test test-fast bench bench-smoke bench-sched

# Tier-1: full test suite (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# quick slice while iterating on the scheduler stack
test-fast:
	$(PY) -m pytest -x -q tests/test_scheduler_core.py tests/test_multi_class.py

# full paper-table benchmark suite
bench:
	$(PY) benchmarks/run.py

# K-class sweep at tiny n_ticks — CI-sized sanity pass
bench-smoke:
	$(PY) benchmarks/multi_class.py --smoke

# scheduler-throughput microbenchmark -> BENCH_scheduler.json
# (slots/sec at K=2 vs K=8; the perf trajectory future PRs compare against)
bench-sched:
	$(PY) benchmarks/multi_class.py --sched-only
