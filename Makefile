# Developer entry points. `make test` is the tier-1 verification command.
PY := python
export PYTHONPATH := src
# never write bytecode under src/ — check-clean fails on stray
# __pycache__ dirs there (editable installs / PYTHONPATH runs leave them)
export PYTHONDONTWRITEBYTECODE := 1

.PHONY: test test-fast bench bench-smoke bench-sched bench-scale \
	bench-scenarios bench-client bench-fleet bench-faults serve-smoke \
	check-bench check-clean lint ci

# Tier-1: full test suite (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# quick slice while iterating on the scheduler stack: the scheduler/sim
# test files minus the `slow`-marked long sim-horizon tests (~1 min);
# CI runs the full suite via `make test`
test-fast:
	$(PY) -m pytest -x -q -m "not slow" \
		tests/test_scheduler_core.py tests/test_multi_class.py \
		tests/test_batch_dispatch.py tests/test_sim.py \
		tests/test_scenarios.py

# full paper-table benchmark suite
bench:
	$(PY) benchmarks/run.py

# CI-sized sanity pass: K-class sweep + scenario sweep at tiny horizons,
# both exiting nonzero on any non-finite aggregate metric
bench-smoke:
	$(PY) benchmarks/multi_class.py --smoke
	$(PY) benchmarks/scenario_sweep.py --smoke
	$(PY) benchmarks/fleet_sweep.py --smoke
	$(PY) benchmarks/fault_sweep.py --smoke

# scheduler-throughput microbenchmark -> BENCH_scheduler.json
# (slots/sec at K=2 vs K=8, the batch-dispatch B x N sweep, and the
# active-window N x W sweep; the perf trajectory future PRs compare
# against).  Committed N=1e6 windowed rows are carried forward — only
# bench-scale re-measures them.
bench-sched:
	$(PY) benchmarks/multi_class.py --sched-only

# the N=1e6 scale runs (active-window cells the dense path can't touch,
# plus the full scenario grid at a million requests -> `scale_1e6` in
# BENCH_scenarios.json); excluded from bench-smoke/CI like the `slow`
# pytest marker — run locally when the windowed engine changes
bench-scale:
	$(PY) benchmarks/multi_class.py --sched-only --scale
	$(PY) benchmarks/scenario_sweep.py --scale

# full nonstationary scenario grid -> BENCH_scenarios.json
bench-scenarios:
	$(PY) benchmarks/scenario_sweep.py

# fleet dispatch sweep: failover at P in {1,4,16} (recovery >= 0.99
# gate on the P>1 cells; P=1 is the no-alternative control), skew,
# brownout -> `fleet_sweep` rows in BENCH_scenarios.json
bench-fleet:
	$(PY) benchmarks/fleet_sweep.py

# chaos recovery sweep: the fault scenarios (silent_drop, stuck_tail,
# dup_storm) through the resilient client vs the trusting control ->
# `fault_sweep` rows in BENCH_scenarios.json (resilience-on completion
# >= 0.99, off demonstrably degraded, zero double-retires)
bench-faults:
	$(PY) benchmarks/fault_sweep.py

# streaming client-session throughput (requests/s over MockProvider at
# N in {1e3,1e5}) -> client_session rows in BENCH_scheduler.json; the
# N-independence of the per-request rate is the windowed-client bar.
# check-bench gates these rows in CI (30% tolerance) plus the >=10x
# fused-tick speedup vs the frozen client_session_pr5 snapshot
bench-client:
	$(PY) benchmarks/client_bench.py

# serving-path smoke: ClientSession drains a mock workload to 100% and
# the deprecated ScheduledClient shim still serves a closed list
serve-smoke:
	$(PY) benchmarks/client_bench.py --smoke

# bench-regression gate: fresh B=16 dispatch, windowed dispatch, and
# client-session rates vs the committed BENCH_scheduler.json baseline
# (>30% drop fails; BENCH_TOLERANCE widens), plus the structural bars
# (B16/B1, win/dense, client N-independence, fused-tick >=10x)
check-bench:
	$(PY) benchmarks/check_regression.py

# repo hygiene: no bytecode may ever be tracked — and none may be
# *trackable*: if .gitignore stops covering __pycache__ (tests/ included),
# `git status` starts offering the files and a stray `git add -A` commits
# them, so the gate also fails on any unignored bytecode in the tree
check-clean:
	@bad=$$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$$' || true); \
	if [ -n "$$bad" ]; then \
		echo "ERROR: tracked bytecode files:"; echo "$$bad"; exit 1; \
	fi; \
	loose=$$(git ls-files -o --exclude-standard | \
		grep -E '(^|/)__pycache__/|\.pyc$$' || true); \
	if [ -n "$$loose" ]; then \
		echo "ERROR: bytecode not covered by .gitignore:"; \
		echo "$$loose"; exit 1; \
	fi; \
	stray=$$(find src -type d -name __pycache__ 2>/dev/null || true); \
	if [ -n "$$stray" ]; then \
		echo "ERROR: stray __pycache__ under src/ (editable install?):"; \
		echo "$$stray"; exit 1; \
	fi; echo "check-clean: no tracked, unignored, or stray bytecode"

# Static analysis gate (DESIGN.md "Static analysis"): ruff first when
# installed (pyflakes/E9 baseline — CI installs it via requirements.txt;
# the container image may not have it, reprolint's RPL006 covers the
# import-hygiene core either way), then the reprolint invariant rules.
# Nonzero exit on any unsuppressed finding.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks; \
	else \
		echo "lint: ruff not installed; relying on reprolint RPL006"; \
	fi
	$(PY) -m repro.analysis.lint src tests benchmarks
	$(PY) -m repro.analysis.docs_check

# CI entry point (.github/workflows/ci.yml runs exactly this): hygiene
# check, lint gate (fail fast, before the expensive suites), tier-1
# tests, CI-sized bench smoke, serving smoke, bench-regression gate
ci: check-clean lint test bench-smoke serve-smoke check-bench
