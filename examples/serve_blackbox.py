"""End-to-end driver (the paper's deployment shape): a REAL JAX model
served behind an opaque submit() API, with the three-layer client
scheduler deciding order and admission.

This is the same batched `schedule_batch` the simulator exercises, driven
by wall clock (one vectorized pass drains up to `max_grants` sends per
poll) — proving the policy stack is not simulator-bound. The model is a
reduced same-family variant of an assigned architecture (CPU-friendly);
on TPU hardware the provider would wrap the pjit-sharded engine from
repro/launch/serve.py instead.

Usage:  PYTHONPATH=src python examples/serve_blackbox.py \
            [--arch stablelm-1.6b] [--requests 16] [--policy final_adrr_olc]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import ServeConfig
from repro.configs import ARCHS, get_smoke
from repro.core.policy import STRATEGIES, strategy
from repro.launch.serve import make_requests
from repro.models import init_model
from repro.serving import BlackBoxProvider, ScheduledClient


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--policy", choices=list(STRATEGIES),
                    default="final_adrr_olc")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    print(f"init reduced {cfg.name} (d_model={cfg.d_model}, "
          f"layers={cfg.n_layers}) ...")
    model = init_model(jax.random.PRNGKey(0), cfg)
    provider = BlackBoxProvider(model.params, cfg,
                                ServeConfig(max_seq=128, temperature=0.0))
    client = ScheduledClient(provider, strategy(args.policy))

    reqs = make_requests(args.requests, seed=0)
    t0 = time.time()
    out = client.run(reqs, time_scale=50.0)
    wall = time.time() - t0

    done = [r for r in out if r.status == "completed"]
    rej = [r for r in out if r.status == "rejected"]
    lat = np.asarray([r.finish_s - r.arrival_s for r in done])
    print(f"\n{len(done)}/{len(out)} completed, {len(rej)} rejected, "
          f"{wall:.1f}s wall")
    if len(lat):
        print(f"latency mean {lat.mean():.2f}s  p95 "
              f"{np.percentile(lat, 95):.2f}s")
    for r in out[:8]:
        otxt = "" if r.output is None else f" out[:6]={r.output[:6].tolist()}"
        print(f"  req {r.rid}: bucket={r.bucket} tokens={r.max_new} "
              f"status={r.status}{otxt}")


if __name__ == "__main__":
    main()
