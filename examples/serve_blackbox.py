"""End-to-end driver (the paper's deployment shape): a REAL JAX model
served behind an opaque async submit() API, with the three-layer client
scheduler deciding order and admission through the streaming
`ClientSession` (DESIGN.md §7).

This is the same batched `schedule_batch` the simulator exercises,
driven by wall clock: each poll makes one vectorized decision over the
windowed slot pool and submits up to `max_grants` requests to the
provider *without blocking* — several generations ride in flight on the
provider's thread pool, idle waits sleep until the next actionable
instant, and an optional `--max-inflight` turns the boundary into a
429-emitting rate limit that exercises the session's Retry-After
backoff.  The model is a reduced same-family variant of an assigned
architecture (CPU-friendly); on TPU hardware the provider would wrap
the pjit-sharded engine from repro/launch/serve.py instead.

(The old `ScheduledClient.run(list)` surface still works as a
deprecated shim over this session.)

Usage:  PYTHONPATH=src python examples/serve_blackbox.py \
            [--arch stablelm-1.6b] [--requests 16] [--policy final_adrr_olc] \
            [--max-inflight 4]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.client import (
    AsyncBlackBoxProvider,
    ClientSession,
    SessionConfig,
)
from repro.config import ServeConfig
from repro.configs import ARCHS, get_smoke
from repro.core.policy import STRATEGIES, strategy
from repro.launch.serve import make_requests
from repro.models import init_model
from repro.serving import BlackBoxProvider


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--policy", choices=list(STRATEGIES),
                    default="final_adrr_olc")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="provider-side concurrency cap; exceeding it "
                         "429s with a Retry-After the session honors")
    ap.add_argument("--time-scale", type=float, default=2.0,
                    help="session seconds per wall second")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    print(f"init reduced {cfg.name} (d_model={cfg.d_model}, "
          f"layers={cfg.n_layers}) ...")
    model = init_model(jax.random.PRNGKey(0), cfg)
    engine = BlackBoxProvider(model.params, cfg,
                              ServeConfig(max_seq=128, temperature=0.0))
    provider = AsyncBlackBoxProvider(
        engine, max_workers=4, max_inflight=args.max_inflight)
    # the reduced CPU model is orders of magnitude slower per token than
    # the provider physics the deadline budgets assume — relax the
    # timeout multiple so the demo exercises scheduling, not wholesale
    # client-side abandonment (the session, unlike the old blocking
    # client, really enforces the paper's timeout rule)
    policy = strategy(args.policy)._replace(
        timeout_mult=jnp.full((4,), 30.0, jnp.float32))
    session = ClientSession(
        provider,
        policy,
        SessionConfig(window=max(32, args.requests), max_grants=4,
                      time_scale=args.time_scale),
        clock="wall",
    )

    t0 = time.time()
    for r in make_requests(args.requests, seed=0):
        session.submit(r)
    out = session.drain()
    wall = time.time() - t0
    provider.shutdown()

    done = [r for r in out if r.status == "completed"]
    rej = [r for r in out if r.status == "rejected"]
    lat = np.asarray([r.finish_s - r.arrival_s for r in done])
    s = session.stats
    print(f"\n{len(done)}/{len(out)} completed, {len(rej)} rejected, "
          f"{wall:.1f}s wall")
    print(f"polls={s.n_polls} idle_sleeps={s.n_idle_sleeps} "
          f"throttled={s.n_throttled} peak_inflight={s.peak_inflight}")
    if len(lat):
        print(f"latency mean {lat.mean():.2f}s  p95 "
              f"{np.percentile(lat, 95):.2f}s")
    for r in out[:8]:
        otxt = "" if r.output is None else f" out[:6]={r.output[:6].tolist()}"
        print(f"  req {r.rid}: bucket={r.bucket} tokens={r.max_new} "
              f"status={r.status}{otxt}")


if __name__ == "__main__":
    main()
