"""Train a ~100M-parameter decoder for a few hundred steps on CPU.

Uses the full substrate stack (data pipeline -> model -> AdamW ->
checkpointing) through the same `repro.launch.train.run` entry point the
cluster launcher uses; only the config is reduced. Loss must fall from
~ln(vocab) — the script asserts it does.

Usage:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import tempfile

from repro.configs import get_smoke
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="stablelm-1.6b")
    args = ap.parse_args()

    # ~100M-class variant: smoke config widened to a realistic trunk
    cfg = get_smoke(args.arch)
    print(f"arch family: {cfg.name}")

    with tempfile.TemporaryDirectory() as d:
        losses = run(arch=args.arch, smoke=True, steps=args.steps,
                     batch=8, seq=128, lr=3e-4, microbatches=1,
                     ckpt_dir=d, log_every=20)
    first, last = losses[0], sum(losses[-10:]) / 10
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps")
    assert last < first - 0.5, "training did not reduce loss"
    print("OK: loss decreased; checkpoint written and removed with tmpdir")


if __name__ == "__main__":
    main()
