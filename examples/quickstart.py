"""Quickstart: the paper's three-layer client scheduler in ~40 lines.

Runs the congestion-aware mock provider under the balanced / high regime
and compares uncontrolled naive dispatch against the full stack
(adaptive DRR allocation + feasible-set ordering + cost-ladder overload
control), printing the paper's joint metrics.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.policy import strategy
from repro.sim import SimConfig, WorkloadConfig, run_cell, summarize

REGIME = WorkloadConfig(n_requests=160, mix="balanced", congestion="high",
                        information="coarse")
SIM = SimConfig(n_ticks=14000)

KEYS = ["short_p95_ms", "global_p95_ms", "completion_rate",
        "satisfaction", "goodput_rps", "n_rejects", "n_defer_events"]


def main():
    print(f"regime: {REGIME.mix}/{REGIME.congestion}, "
          f"{REGIME.n_requests} requests, 5 seeds\n")
    print(f"{'policy':16s} " + " ".join(f"{k:>15s}" for k in KEYS))
    for name in ["direct_naive", "quota_tiered", "adaptive_drr",
                 "final_adrr_olc"]:
        s = summarize(run_cell(strategy(name), REGIME, seeds=5, sim_cfg=SIM))
        row = " ".join(f"{s[k][0]:>9.1f}±{s[k][1]:<5.1f}" for k in KEYS)
        print(f"{name:16s} {row}")
    print("\nRead jointly (paper §4.3): low tails with low completion = "
          "withheld work, not a better system.")


if __name__ == "__main__":
    main()
