"""Information-ladder demo (paper §4.4): what does the client's knowledge
buy, with the SAME Final (OLC) stack held fixed?

Walks the four levels — no-information blind, class-only, coarse
semi-clairvoyant, oracle — on the balanced / high regime and shows the
short-tail inflation when magnitude priors are removed.

Usage:  PYTHONPATH=src python examples/info_ladder_demo.py
"""
from repro.core.policy import strategy, with_information
from repro.sim import SimConfig, WorkloadConfig, run_cell, summarize

SIM = SimConfig(n_ticks=14000)
LEVELS = ["no_info", "class_only", "coarse", "oracle"]


def main():
    base = strategy("final_adrr_olc")
    rows = {}
    for level in LEVELS:
        wl = WorkloadConfig(n_requests=160, mix="balanced",
                            congestion="high", information=level)
        s = summarize(run_cell(with_information(base, level), wl,
                               seeds=5, sim_cfg=SIM))
        rows[level] = s
        print(f"{level:12s} shortP95={s['short_p95_ms'][0]:7.0f}"
              f"±{s['short_p95_ms'][1]:<6.0f} CR={s['completion_rate'][0]:.2f} "
              f"sat={s['satisfaction'][0]:.2f} "
              f"goodput={s['goodput_rps'][0]:.2f}/s")

    infl = rows["no_info"]["short_p95_ms"][0] / rows["coarse"]["short_p95_ms"][0]
    print(f"\nremoving magnitude priors inflates short P95 by {infl:.1f}x "
          f"(paper: up to 5.8x); oracle ≈ coarse — the practical bar is "
          f"coarse magnitude, not exact tokens.")


if __name__ == "__main__":
    main()
