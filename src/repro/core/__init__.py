"""Core client-side scheduling stack (the paper's contribution).

Layers:
  * repro.core.drr       — allocation (adaptive DRR + alternatives)
  * repro.core.ordering  — intra-class feasible-set scoring
  * repro.core.overload  — severity + cost-ladder admission
  * repro.core.scheduler — fused per-slot decision
  * repro.core.policy    — PolicyConfig + named paper strategies
"""
from repro.core.policy import PolicyConfig, strategy, STRATEGIES  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    BatchDecision,
    SlotDecision,
    schedule_batch,
    schedule_slot,
)
