"""Cross-program float determinism helpers.

The windowed engine (DESIGN.md §6) re-evaluates the *same* scalar and
per-request arithmetic the dense engine runs, but inside a
differently-shaped program — (W,)-wide views instead of (N,)-wide
arrays.  XLA:CPU's instruction selection is context-dependent: a
mul+add may FMA-contract in one fusion but not the other, and a
division may lower to an exact `div` or a refined reciprocal depending
on the surrounding loop.  Any 1-ulp drift in a value that feeds a
scheduling decision (severity, ordering scores, the tail EMA)
eventually flips a threshold comparison and breaks the engines'
bit-exact contract.

`pinned(x)` wraps `lax.optimization_barrier`: it cuts the value out of
the surrounding fusion so the arithmetic between two pins compiles as
the same isolated subgraph in both programs and rounds identically.
The barrier is the identity on values — it only constrains the
compiler — so it is free at the numerics level and ~free at runtime
(it forces materialization of a handful of small buffers).

Wrapped via `custom_batching.custom_vmap` because
`optimization_barrier` ships without a batching rule: under `vmap`
(e.g. the runner's seed axis) the barrier simply applies to the
stacked value, which preserves the isolation property — all seeds
share one program.
"""
from __future__ import annotations

import jax
from jax.custom_batching import custom_vmap


@custom_vmap
def pinned(x):
    """Identity that pins the rounding of the computation producing `x`
    (and of consumers that would otherwise fuse through it)."""
    return jax.lax.optimization_barrier(x)


@pinned.def_vmap
def _pinned_vmap(axis_size, in_batched, x):
    del axis_size
    # in_batched is a single-element list (one positional arg); the
    # output batching spec must mirror the output pytree, i.e. x's.
    # Re-enter `pinned` (not the raw barrier): under vmap-of-vmap the
    # rule itself is traced by the outer vmap, and optimization_barrier
    # has no batching rule of its own — recursing through the custom
    # wrapper peels one batch level per call instead.
    return pinned(x), in_batched[0]
