"""The fused three-layer client scheduler (paper §3), K-class generalized.

`schedule_slot` composes the layers exactly as the paper describes:
the allocation layer selects a class; the ordering layer names a concrete
request in that class; the overload layer may block or delay that release.
It is a pure function of (PolicyConfig, RequestBatch, SimState) and
returns a `SlotDecision`.

`schedule_batch` is the multi-grant generalization (DESIGN.md §3): one
vectorized pass that grants up to B releases per decision epoch.  The
O(K·N) work — eligibility, the per-class ranked candidate lists, the
severity evaluation — happens up front, outside the grant loop; only
the O(K) allocation step runs per grant, so a tick costs O(K·N + B·K)
instead of the B full `schedule_slot` traces the sequential slot loop
paid.  Severity is
frozen across the B grants (one cost-ladder evaluation drives every
admission decision in the batch), while DRR deficits, per-class and
global inflight caps, and the FQ pointer update cumulatively per grant.
With max_grants=1 the pass reduces bit-exactly to `schedule_slot`.

Both entry points are consumed by the simulation engine
(repro.sim.engine) and the live serving adapter (repro.serving.blackbox),
so the policy logic is written once.

Fleet dispatch (DESIGN.md §10) slots in *above* these layers: when a
`(N,)` endpoint assignment and `(N,)` route-cost vector are provided
(from `core.routing.route_requests`), the route cost joins the ordering
score as a fourth term and `schedule_batch` gathers the chosen
endpoint into `BatchDecision.provider_idx` per grant — which-request
and which-endpoint stay separable decisions, and with `endpoint=None`
the compiled program is the single-provider one unchanged.

The class count K is static — the length of `PolicyConfig`'s per-class
arrays and of `SchedState.deficit`.  All per-class computation here is
vectorized over a (K, N) class-membership mask (no Python loop over
classes), so trace size and compile time are O(1) in K and the same
compiled program shape serves the paper's 2-lane split, a per-bucket
4-lane scheme, or K tenants.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import drr, ordering, overload
from repro.core.policy import ALLOC_ADRR, PolicyConfig, n_classes
from repro.core.types import INFLIGHT, RequestBatch, SimState


class SlotDecision(NamedTuple):
    action: jnp.ndarray       # () int32: -1 idle, 0 admit, 1 defer, 2 reject
    req_idx: jnp.ndarray      # () int32 target request (valid iff action>=0)
    severity: jnp.ndarray     # () f32 overload severity used
    deficit: jnp.ndarray      # (K,) f32 updated allocation deficits
    rr_turn: jnp.ndarray      # () int32 updated FQ pointer


class BatchDecision(NamedTuple):
    """Up to B grants from one vectorized dispatch pass.

    Row g is the g-th grant in decision order; rows with action == IDLE
    carry no release (their req_idx must be ignored).  `inflight_at` is
    the provider inflight count each grant was decided against, so
    consumers can reproduce the sequential engine's per-admit service
    physics exactly.
    """

    actions: jnp.ndarray      # (B,) int32: -1 idle, 0 admit, 1 defer, 2 reject
    req_idx: jnp.ndarray      # (B,) int32 target request (valid iff action>=0)
    inflight_at: jnp.ndarray  # (B,) int32 inflight total seen by grant g
    severity: jnp.ndarray     # () f32 severity shared by all B decisions
    deficit: jnp.ndarray      # (K,) f32 updated allocation deficits
    rr_turn: jnp.ndarray      # () int32 updated FQ pointer
    # (B,) int32 fleet endpoint per grant (fleet mode only; None in
    # single-provider mode — the absence is pytree structure, so the
    # P=1-free program is byte-identical to the pre-fleet one)
    provider_idx: Optional[jnp.ndarray] = None


IDLE = -1


def effective_class(cfg: PolicyConfig, batch: RequestBatch) -> jnp.ndarray:
    """Info-ladder: without class routing every request shares one lane.

    Class ids are clipped into [0, K) so a batch generated for a larger
    class scheme degrades gracefully instead of indexing out of range.
    """
    k = n_classes(cfg)
    cls = jnp.clip(batch.cls, 0, k - 1)
    return jnp.where(cfg.route_by_class > 0, cls, 0).astype(jnp.int32)


def _refund(cfg, k, cls_id, head_cost, action, ignore_class):
    """Deficit conservation: DRR charged the head cost assuming a
    release; credit it back when the overload layer blocked the release
    (defer/reject consumed no share).  Only ADRR ever charges, so the
    refund is gated on the mode — FQ/quota/SP/naive deficits must not
    be silently credited across mode switches."""
    return (
        jax.nn.one_hot(cls_id, k)
        * head_cost[cls_id]
        * ((action == overload.DEFER) | (action == overload.REJECT))
        * (~ignore_class)
        * (cfg.alloc_mode == ALLOC_ADRR)
    )


def charge_resubmit(cfg: PolicyConfig, deficit: jnp.ndarray,
                    charge: jnp.ndarray) -> jnp.ndarray:
    """Debit resubmission traffic against the class deficits.

    The client's resilience layer re-sends stuck requests through the
    same provider boundary the scheduler meters — if that recovery
    traffic rode for free, a class with a high fault rate could starve
    the others through its retries.  `charge` is the (K,) per-class sum
    of p50 costs resubmitted this epoch; like `_refund`, the debit is
    gated on ADRR (the only mode that charges deficits at all) and on
    an actual charge being present, so the zero-charge epoch returns
    `deficit` bit-unchanged (x - 0.0 is not an f32 identity at -0.0)
    and the no-resilience trace never contains this op at all.
    """
    debited = deficit - charge
    return jnp.where(
        (charge > 0.0).any() & jnp.isfinite(debited).all()
        & (cfg.alloc_mode == ALLOC_ADRR),
        debited, deficit)


def schedule_slot(
    cfg: PolicyConfig, batch: RequestBatch, state: SimState
) -> SlotDecision:
    k = n_classes(cfg)
    now = state.now_ms
    elig = ordering.eligibility(
        batch, state.req.status, state.req.defer_until, now
    )
    eff_cls = effective_class(cfg, batch)

    # (K, N) class-membership masks — the vectorized class axis
    cls_onehot = eff_cls[None, :] == jnp.arange(k, dtype=jnp.int32)[:, None]
    elig_kn = cls_onehot & elig[None, :]

    # --- layer 2 first per class: the allocation layer needs each class's
    # would-be head cost to test deficit affordability (classic DRR).
    cand_idx, cand_ok = ordering.select_per_class(batch, elig_kn, now, cfg)
    head_cost = jnp.where(cand_ok, batch.p50[cand_idx], jnp.inf)

    backlog = elig_kn.sum(axis=1).astype(jnp.int32)

    inflight_mask = state.req.status == INFLIGHT
    inflight_cls = (cls_onehot & inflight_mask[None, :]).sum(axis=1).astype(
        jnp.int32
    )
    inflight_total = state.provider.inflight

    # --- layer 3 signals (client-observable only)
    sev = overload.severity_score(
        cfg,
        inflight_total=inflight_total,
        n_pending=elig.sum(),
        ema_latency_ratio=state.sched.ema_latency_ratio,
    )

    # --- layer 1: which class gets this send opportunity?
    choice = drr.allocate(
        cfg,
        backlog=backlog,
        head_cost=head_cost,
        inflight_cls=inflight_cls,
        inflight_total=inflight_total,
        severity=sev,
        deficit=state.sched.deficit,
        rr_turn=state.sched.rr_turn,
    )

    # naive mode ignores lanes entirely: global FIFO
    fifo_idx, fifo_ok = ordering.select_fifo(batch, elig)
    idx = jnp.where(choice.ignore_class, fifo_idx, cand_idx[choice.cls_id])
    ok = jnp.where(choice.ignore_class, fifo_ok, cand_ok[choice.cls_id])
    ok = ok & choice.send_ok

    # --- layer 3 decision on the concrete candidate
    act = overload.admission_action(
        cfg,
        severity=sev,
        bucket=batch.bucket[idx],
        n_defers=state.req.n_defers[idx],
    )
    action = jnp.where(ok, act, IDLE).astype(jnp.int32)

    refund = _refund(cfg, k, choice.cls_id, head_cost, action,
                     choice.ignore_class)
    deficit = jnp.where(
        jnp.isfinite(choice.deficit + refund), choice.deficit + refund, choice.deficit
    )

    return SlotDecision(
        action=action,
        req_idx=idx.astype(jnp.int32),
        severity=sev,
        deficit=deficit,
        rr_turn=choice.rr_turn,
    )


def schedule_batch(
    cfg: PolicyConfig,
    batch: RequestBatch,
    state: SimState,
    max_grants: int = 1,
    backend: str = "jnp",
    route=None,
    endpoint=None,
) -> BatchDecision:
    """Grant up to `max_grants` releases in one vectorized pass.

    The expensive O(K·N) layer-2 work runs up front, outside the grant
    loop: eligibility, the ranked top-B candidate list per class
    (`ordering.select_top_b` — one top_k pass on the jnp backend, K·B
    fused argmax streams on the Pallas backend), the global FIFO ranking
    for the naive lane, and one severity evaluation shared by every
    grant's cost-ladder decision.
    The per-grant loop then replays only the O(K) allocation step —
    deficits are charged per grant, per-class caps and the global
    max_inflight bind cumulatively (each admit raises the counts the
    next grant is decided against), and a deferred/rejected candidate
    leaves the feasible set for the rest of the batch exactly as its
    backoff/terminal status would remove it in the sequential path.

    `max_grants` and `backend` must be static under jit.  With
    max_grants=1 the decision stream is bit-exact with `schedule_slot`.

    Fleet mode (`route`/`endpoint` from `routing.route_requests`): the
    (N,) route term joins the scored ordering, and each grant's row in
    `BatchDecision.provider_idx` is the granted request's pre-computed
    best endpoint — routing happens above allocation, so the three
    paper layers are unchanged and a (P,)-aware consumer only has to
    gather.  Both default to None; passing neither reproduces the
    single-provider program exactly.
    """
    k = n_classes(cfg)
    bmax = min(int(max_grants), batch.n)
    now = state.now_ms
    elig = ordering.eligibility(
        batch, state.req.status, state.req.defer_until, now
    )
    eff_cls = effective_class(cfg, batch)
    cls_onehot = eff_cls[None, :] == jnp.arange(k, dtype=jnp.int32)[:, None]
    elig_kn = cls_onehot & elig[None, :]

    # --- layer 2 once: ranked candidates per class + global FIFO lane
    rank_idx, n_elig_cls = ordering.select_top_b(
        batch, elig_kn, now, cfg, bmax, backend=backend, route=route
    )
    glob_idx, n_elig_tot = ordering.rank_fifo(batch, elig, bmax,
                                              backend=backend)
    # grantable candidates this batch can actually see per lane
    visible_cls = jnp.minimum(n_elig_cls, bmax)
    visible_glob = jnp.minimum(n_elig_tot, bmax)

    inflight_mask = state.req.status == INFLIGHT
    inflight_cls0 = (cls_onehot & inflight_mask[None, :]).sum(axis=1).astype(
        jnp.int32
    )

    # --- layer 3 once: a single severity drives all B ladder decisions
    sev = overload.severity_score(
        cfg,
        inflight_total=state.provider.inflight,
        n_pending=n_elig_tot,
        ema_latency_ratio=state.sched.ema_latency_ratio,
    )

    def grant(g, carry):
        (deficit, rr_turn, infl_cls, infl_tot, cls_ptr, glob_ptr,
         actions, idxs, infl_at) = carry

        # per-class heads at the current rank pointers
        col = jnp.clip(cls_ptr, 0, bmax - 1)
        head_idx = rank_idx[jnp.arange(k), col]
        ok_c = cls_ptr < visible_cls
        head_cost = jnp.where(ok_c, batch.p50[head_idx], jnp.inf)
        backlog = (visible_cls - cls_ptr).astype(jnp.int32)

        choice = drr.allocate(
            cfg,
            backlog=backlog,
            head_cost=head_cost,
            inflight_cls=infl_cls,
            inflight_total=infl_tot,
            severity=sev,
            deficit=deficit,
            rr_turn=rr_turn,
        )

        gidx = glob_idx[jnp.clip(glob_ptr, 0, bmax - 1)]
        ok_g = glob_ptr < visible_glob
        idx = jnp.where(choice.ignore_class, gidx, head_idx[choice.cls_id])
        ok = jnp.where(choice.ignore_class, ok_g, ok_c[choice.cls_id])
        ok = ok & choice.send_ok

        act = overload.admission_action(
            cfg,
            severity=sev,
            bucket=batch.bucket[idx],
            n_defers=state.req.n_defers[idx],
        )
        action = jnp.where(ok, act, IDLE).astype(jnp.int32)

        refund = _refund(cfg, k, choice.cls_id, head_cost, action,
                         choice.ignore_class)
        deficit = jnp.where(
            jnp.isfinite(choice.deficit + refund),
            choice.deficit + refund,
            choice.deficit,
        )

        # cumulative bookkeeping for the next grant: any live decision
        # consumes its candidate (a deferred/rejected request is out of
        # the feasible set for the rest of the batch); only admits hold
        # provider slots.
        live = (action != IDLE).astype(jnp.int32)
        admit = (action == overload.ADMIT).astype(jnp.int32)
        gcls = eff_cls[idx]
        cls_take = jax.nn.one_hot(gcls, k, dtype=jnp.int32) * live
        use_glob = choice.ignore_class.astype(jnp.int32)
        return (
            deficit,
            choice.rr_turn,
            infl_cls + cls_take * admit,
            infl_tot + admit,
            cls_ptr + cls_take * (1 - use_glob),
            glob_ptr + live * use_glob,
            actions.at[g].set(action),
            idxs.at[g].set(idx.astype(jnp.int32)),
            infl_at.at[g].set(infl_tot),
        )

    carry0 = (
        state.sched.deficit,
        state.sched.rr_turn,
        inflight_cls0,
        state.provider.inflight,
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.full((bmax,), IDLE, jnp.int32),
        jnp.zeros((bmax,), jnp.int32),
        jnp.zeros((bmax,), jnp.int32),
    )
    (deficit, rr_turn, _, _, _, _, actions, idxs, infl_at) = jax.lax.fori_loop(
        0, bmax, grant, carry0
    )
    provider_idx = None
    if endpoint is not None:
        # gather-only: the endpoint choice was fixed before allocation,
        # so granting never re-routes (integer gather, no float math)
        provider_idx = endpoint[jnp.clip(idxs, 0, batch.n - 1)].astype(
            jnp.int32)
    return BatchDecision(
        actions=actions,
        req_idx=idxs,
        inflight_at=infl_at,
        severity=sev,
        deficit=deficit,
        rr_turn=rr_turn,
        provider_idx=provider_idx,
    )
