"""The fused three-layer client scheduler (paper §3), K-class generalized.

`schedule_slot` composes the layers exactly as the paper describes:
the allocation layer selects a class; the ordering layer names a concrete
request in that class; the overload layer may block or delay that release.
It is a pure function of (PolicyConfig, RequestBatch, SimState) and
returns a `SlotDecision`; the simulation engine (repro.sim.engine) and
the live serving adapter (repro.serving.blackbox) both consume it, so
the policy logic is written once.

The class count K is static — the length of `PolicyConfig`'s per-class
arrays and of `SchedState.deficit`.  All per-class computation here is
vectorized over a (K, N) class-membership mask (no Python loop over
classes), so trace size and compile time are O(1) in K and the same
compiled program shape serves the paper's 2-lane split, a per-bucket
4-lane scheme, or K tenants.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import drr, ordering, overload
from repro.core.policy import PolicyConfig, n_classes
from repro.core.types import INFLIGHT, RequestBatch, SimState


class SlotDecision(NamedTuple):
    action: jnp.ndarray       # () int32: -1 idle, 0 admit, 1 defer, 2 reject
    req_idx: jnp.ndarray      # () int32 target request (valid iff action>=0)
    severity: jnp.ndarray     # () f32 overload severity used
    deficit: jnp.ndarray      # (K,) f32 updated allocation deficits
    rr_turn: jnp.ndarray      # () int32 updated FQ pointer


IDLE = -1


def effective_class(cfg: PolicyConfig, batch: RequestBatch) -> jnp.ndarray:
    """Info-ladder: without class routing every request shares one lane.

    Class ids are clipped into [0, K) so a batch generated for a larger
    class scheme degrades gracefully instead of indexing out of range.
    """
    k = n_classes(cfg)
    cls = jnp.clip(batch.cls, 0, k - 1)
    return jnp.where(cfg.route_by_class > 0, cls, 0).astype(jnp.int32)


def schedule_slot(
    cfg: PolicyConfig, batch: RequestBatch, state: SimState
) -> SlotDecision:
    k = n_classes(cfg)
    now = state.now_ms
    elig = ordering.eligibility(
        batch, state.req.status, state.req.defer_until, now
    )
    eff_cls = effective_class(cfg, batch)

    # (K, N) class-membership masks — the vectorized class axis
    cls_onehot = eff_cls[None, :] == jnp.arange(k, dtype=jnp.int32)[:, None]
    elig_kn = cls_onehot & elig[None, :]

    # --- layer 2 first per class: the allocation layer needs each class's
    # would-be head cost to test deficit affordability (classic DRR).
    cand_idx, cand_ok = ordering.select_per_class(batch, elig_kn, now, cfg)
    head_cost = jnp.where(cand_ok, batch.p50[cand_idx], jnp.inf)

    backlog = elig_kn.sum(axis=1).astype(jnp.int32)

    inflight_mask = state.req.status == INFLIGHT
    inflight_cls = (cls_onehot & inflight_mask[None, :]).sum(axis=1).astype(
        jnp.int32
    )
    inflight_total = state.provider.inflight

    # --- layer 3 signals (client-observable only)
    sev = overload.severity_score(
        cfg,
        inflight_total=inflight_total,
        n_pending=elig.sum(),
        ema_latency_ratio=state.sched.ema_latency_ratio,
    )

    # --- layer 1: which class gets this send opportunity?
    choice = drr.allocate(
        cfg,
        backlog=backlog,
        head_cost=head_cost,
        inflight_cls=inflight_cls,
        inflight_total=inflight_total,
        severity=sev,
        deficit=state.sched.deficit,
        rr_turn=state.sched.rr_turn,
    )

    # naive mode ignores lanes entirely: global FIFO
    fifo_idx, fifo_ok = ordering.select_fifo(batch, elig)
    idx = jnp.where(choice.ignore_class, fifo_idx, cand_idx[choice.cls_id])
    ok = jnp.where(choice.ignore_class, fifo_ok, cand_ok[choice.cls_id])
    ok = ok & choice.send_ok

    # --- layer 3 decision on the concrete candidate
    act = overload.admission_action(
        cfg,
        severity=sev,
        bucket=batch.bucket[idx],
        n_defers=state.req.n_defers[idx],
    )
    action = jnp.where(ok, act, IDLE).astype(jnp.int32)

    # DRR charged the head cost assuming a release; refund it when the
    # overload layer blocked the release (defer/reject consumed no share).
    refund = (
        jax.nn.one_hot(choice.cls_id, k)
        * head_cost[choice.cls_id]
        * ((action == overload.DEFER) | (action == overload.REJECT))
        * (~choice.ignore_class)
    )
    deficit = jnp.where(
        jnp.isfinite(choice.deficit + refund), choice.deficit + refund, choice.deficit
    )

    return SlotDecision(
        action=action,
        req_idx=idx.astype(jnp.int32),
        severity=sev,
        deficit=deficit,
        rr_turn=choice.rr_turn,
    )
