"""PolicyConfig: one scalar/array-valued struct that covers every named
strategy in the paper branchlessly.

The paper's named strategies map onto this struct as follows (see
`strategy()` at the bottom):

  direct_naive    alloc_mode=NAIVE, overload off, FIFO ordering
  quota_tiered    alloc_mode=QUOTA, per-class inflight quotas, no borrowing
  adaptive_drr    alloc_mode=ADRR, ordering on, overload off
  final_adrr_olc  alloc_mode=ADRR, ordering on, overload cost ladder
  fair_queuing    alloc_mode=FQ (strict round-robin between classes)
  short_priority  alloc_mode=SP (interactive class strictly first)

Overload `bucket_policy` shapes (paper §4.7) are expressed purely as the
per-bucket threshold tables `defer_thr` / `reject_thr` (inf = never):

  ladder         defer [-,-,.45,.45], reject [-,-,.80,.65]
  uniform_mild   defer [-,.45,.45,.45], reject [-,-,-,-]
  uniform_harsh  defer [-,.45,.45,.45], reject [-,.65,.65,.65]
  reverse        defer [-,-,.45,.45], reject [-,-,.65,.80]

Short requests are never rejected under every shape except the
`no_information` ladder condition, where the client cannot distinguish
buckets at all (paper §4.4 level 1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.types import NEVER

# Allocation modes ----------------------------------------------------------
ALLOC_NAIVE = 0     # single FIFO lane, admit-all
ALLOC_QUOTA = 1     # tiered isolation: per-class inflight quotas, no borrow
ALLOC_ADRR = 2      # adaptive deficit round robin (the paper's allocation)
ALLOC_FQ = 3        # fair queuing: strict round-robin across classes
ALLOC_SP = 4        # short-priority: interactive strictly first


class PolicyConfig(NamedTuple):
    """All fields are jnp scalars/arrays => one XLA program serves every
    strategy; sweeps vmap over stacked PolicyConfigs."""

    # --- allocation (layer 1) ---
    alloc_mode: jnp.ndarray          # () int32, one of ALLOC_*
    drr_quantum: jnp.ndarray         # () f32 tokens added per backlogged turn
    drr_weights: jnp.ndarray         # (2,) f32 base class weights
    congestion_kappa: jnp.ndarray    # () f32 short-weight scaling vs severity
    deficit_cap: jnp.ndarray         # () f32 max deficit (anti-burst)
    class_cap: jnp.ndarray           # (2,) f32 per-class inflight caps
    cap_kappa: jnp.ndarray           # () f32 severity shrink of the heavy cap
    max_inflight: jnp.ndarray        # () f32 client-wide concurrency cap
    load_ref: jnp.ndarray            # () f32 severity normalizer for
                                     #        provider load (decoupled from the
                                     #        concurrency cap so the severity
                                     #        signal saturates near the mock's
                                     #        comfortable operating point)

    # --- ordering (layer 2) ---
    ord_w_wait: jnp.ndarray          # () f32 weight on wait/cost
    ord_w_size: jnp.ndarray          # () f32 weight on size/ref (penalty)
    ord_w_urg: jnp.ndarray           # () f32 weight on deadline urgency
    ord_ref_tokens: jnp.ndarray      # () f32 size normalizer

    # --- overload control (layer 3) ---
    olc_enabled: jnp.ndarray         # () f32 0/1
    olc_w_load: jnp.ndarray          # () f32
    olc_w_queue: jnp.ndarray         # () f32
    olc_w_tail: jnp.ndarray          # () f32
    defer_thr: jnp.ndarray           # (4,) f32 per-bucket severity cutoffs
    reject_thr: jnp.ndarray          # (4,) f32 per-bucket severity cutoffs
    defer_backoff_ms: jnp.ndarray    # () f32 base re-eligibility delay
    max_defers: jnp.ndarray          # () f32 defers before forced decision
    queue_ref: jnp.ndarray           # () f32 queue-pressure normalizer
    tail_ref: jnp.ndarray            # () f32 tail-ratio normalizer

    # --- misc ---
    route_by_class: jnp.ndarray      # () f32 0/1 — info-ladder class routing
    timeout_mult: jnp.ndarray        # (4,) f32 per-bucket patience: abandon
                                     #        after timeout_mult[bucket] *
                                     #        deadline_budget (inf-like = wait)


def _f(x) -> jnp.ndarray:
    return jnp.asarray(x, jnp.float32)


def base_policy(**overrides) -> PolicyConfig:
    """The Final (OLC) configuration — paper defaults."""
    cfg = dict(
        alloc_mode=jnp.asarray(ALLOC_ADRR, jnp.int32),
        drr_quantum=_f(220.0),
        drr_weights=_f([2.0, 1.0]),
        congestion_kappa=_f(1.5),
        deficit_cap=_f(8000.0),
        # shorts are cheap: effectively uncapped; heavy work holds at most 4
        # provider slots, shrinking toward 2 as severity rises — this is how
        # interactive traffic keeps protected share without idling capacity.
        class_cap=_f([16.0, 4.0]),
        cap_kappa=_f(0.5),
        max_inflight=_f(20.0),
        load_ref=_f(6.0),
        ord_w_wait=_f(1.0),
        ord_w_size=_f(0.6),
        ord_w_urg=_f(0.8),
        ord_ref_tokens=_f(512.0),
        olc_enabled=_f(1.0),
        olc_w_load=_f(0.40),
        olc_w_queue=_f(0.30),
        olc_w_tail=_f(0.30),
        defer_thr=_f([NEVER, NEVER, 0.45, 0.45]),
        reject_thr=_f([NEVER, NEVER, 0.80, 0.65]),
        defer_backoff_ms=_f(1000.0),
        max_defers=_f(2.0),
        queue_ref=_f(40.0),
        tail_ref=_f(4.0),
        route_by_class=_f(1.0),
        timeout_mult=_f([3.0, 3.0, 3.0, 3.0]),
    )
    cfg.update(overrides)
    return PolicyConfig(**cfg)


# ---------------------------------------------------------------------------
# Named strategies (paper §4.5/§4.6)
# ---------------------------------------------------------------------------

def direct_naive() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_NAIVE, jnp.int32),
        olc_enabled=_f(0.0),
        ord_w_size=_f(0.0),
        ord_w_urg=_f(0.0),
        route_by_class=_f(0.0),
        class_cap=_f([1e9, 1e9]),
        max_inflight=_f(1e9),  # admit-all: no client-side shaping at all
    )


def quota_tiered() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_QUOTA, jnp.int32),
        olc_enabled=_f(0.0),
        # strict isolation: small heavy quota protects tails but strands work
        class_cap=_f([8.0, 3.0]),
        cap_kappa=_f(0.0),
        # tiered SLAs: interactive/medium lanes wait; stranded longs are
        # tolerated (they drag the completed tail), stranded xlongs expire
        # fast (the quota's "withheld work" shows up in completion rate)
        timeout_mult=_f([3.0, 3.0, 2.0, 0.45]),
    )


def adaptive_drr() -> PolicyConfig:
    return base_policy(olc_enabled=_f(0.0))


def final_adrr_olc() -> PolicyConfig:
    return base_policy()


def fair_queuing() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_FQ, jnp.int32), olc_enabled=_f(0.0))


def short_priority() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_SP, jnp.int32), olc_enabled=_f(0.0))


# ---------------------------------------------------------------------------
# Overload bucket_policy shapes (paper §4.7) applied on top of Final (OLC)
# ---------------------------------------------------------------------------

def with_bucket_policy(cfg: PolicyConfig, shape: str) -> PolicyConfig:
    tables = {
        "ladder": ([NEVER, NEVER, 0.45, 0.45], [NEVER, NEVER, 0.80, 0.65]),
        "uniform_mild": ([NEVER, 0.45, 0.45, 0.45], [NEVER] * 4),
        "uniform_harsh": ([NEVER, 0.45, 0.45, 0.45], [NEVER, 0.65, 0.65, 0.65]),
        "reverse": ([NEVER, NEVER, 0.45, 0.45], [NEVER, NEVER, 0.65, 0.80]),
    }
    d, r = tables[shape]
    return cfg._replace(defer_thr=_f(d), reject_thr=_f(r))


# ---------------------------------------------------------------------------
# Information-ladder conditions (paper §4.4) — policy-side part.
# (The workload generator owns the prior-side part: neutral vs coarse vs
# oracle p50/p90.)
# ---------------------------------------------------------------------------

def with_information(cfg: PolicyConfig, level: str) -> PolicyConfig:
    if level == "no_info":
        # single neutral lane; uniform admission severity (client cannot
        # infer cost from labels)
        return cfg._replace(
            route_by_class=_f(0.0),
            defer_thr=_f([0.60] * 4),
            reject_thr=_f([0.92] * 4),
        )
    if level == "class_only":
        # labels drive routing + tiered overload; priors stay neutral
        return cfg
    if level in ("coarse", "oracle"):
        return cfg
    raise ValueError(f"unknown information level: {level}")


STRATEGIES = {
    "direct_naive": direct_naive,
    "quota_tiered": quota_tiered,
    "adaptive_drr": adaptive_drr,
    "final_adrr_olc": final_adrr_olc,
    "fair_queuing": fair_queuing,
    "short_priority": short_priority,
}


def strategy(name: str) -> PolicyConfig:
    return STRATEGIES[name]()
