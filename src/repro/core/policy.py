"""PolicyConfig: one scalar/array-valued struct that covers every named
strategy in the paper branchlessly.

The paper's named strategies map onto this struct as follows (see
`strategy()` at the bottom):

  direct_naive    alloc_mode=NAIVE, overload off, FIFO ordering
  quota_tiered    alloc_mode=QUOTA, per-class inflight quotas, no borrowing
  adaptive_drr    alloc_mode=ADRR, ordering on, overload off
  final_adrr_olc  alloc_mode=ADRR, ordering on, overload cost ladder
  fair_queuing    alloc_mode=FQ (strict round-robin between classes)
  short_priority  alloc_mode=SP (interactive class strictly first)

Overload `bucket_policy` shapes (paper §4.7) are expressed purely as the
per-bucket threshold tables `defer_thr` / `reject_thr` (inf = never):

  ladder         defer [-,-,.45,.45], reject [-,-,.80,.65]
  uniform_mild   defer [-,.45,.45,.45], reject [-,-,-,-]
  uniform_harsh  defer [-,.45,.45,.45], reject [-,.65,.65,.65]
  reverse        defer [-,-,.45,.45], reject [-,-,.65,.80]

Short requests are never rejected under every shape except the
`no_information` ladder condition, where the client cannot distinguish
buckets at all (paper §4.4 level 1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.types import NEVER

# Allocation modes ----------------------------------------------------------
ALLOC_NAIVE = 0     # single FIFO lane, admit-all
ALLOC_QUOTA = 1     # tiered isolation: per-class inflight quotas, no borrow
ALLOC_ADRR = 2      # adaptive deficit round robin (the paper's allocation)
ALLOC_FQ = 3        # fair queuing: strict round-robin across classes
ALLOC_SP = 4        # short-priority: interactive strictly first


class PolicyConfig(NamedTuple):
    """All fields are jnp scalars/arrays => one XLA program serves every
    strategy; sweeps vmap over stacked PolicyConfigs.

    The class count K is carried implicitly as the (static) length of the
    per-class arrays (`drr_weights`, `class_cap`, `class_protect`,
    `ord_scored`); `n_classes(cfg)` reads it back.  Every per-class array
    must share one K.
    """

    # --- allocation (layer 1) ---
    alloc_mode: jnp.ndarray          # () int32, one of ALLOC_*
    drr_quantum: jnp.ndarray         # () f32 tokens added per backlogged turn
    drr_weights: jnp.ndarray         # (K,) f32 base class weights
    congestion_kappa: jnp.ndarray    # () f32 protected-weight scaling vs severity
    deficit_cap: jnp.ndarray         # () f32 max deficit (anti-burst)
    class_cap: jnp.ndarray           # (K,) f32 per-class inflight caps
    cap_kappa: jnp.ndarray           # () f32 severity shrink of unprotected caps
    class_protect: jnp.ndarray       # (K,) f32 0/1 — protected lanes gain
                                     #        weight and keep their cap under
                                     #        stress (paper: interactive lane)
    max_inflight: jnp.ndarray        # () f32 client-wide concurrency cap
    load_ref: jnp.ndarray            # () f32 severity normalizer for
                                     #        provider load (decoupled from the
                                     #        concurrency cap so the severity
                                     #        signal saturates near the mock's
                                     #        comfortable operating point)

    # --- ordering (layer 2) ---
    ord_scored: jnp.ndarray          # (K,) f32 0/1 — scored rule per class
                                     #        (0 = FIFO; paper: shorts FIFO,
                                     #        heavy scored)
    ord_w_wait: jnp.ndarray          # () f32 weight on wait/cost
    ord_w_size: jnp.ndarray          # () f32 weight on size/ref (penalty)
    ord_w_urg: jnp.ndarray           # () f32 weight on deadline urgency
    ord_ref_tokens: jnp.ndarray      # () f32 size normalizer
    ord_w_route: jnp.ndarray         # () f32 weight on the fleet route
                                     #        cost term (seconds of
                                     #        predicted queue delay at the
                                     #        request's best endpoint;
                                     #        unused outside fleet mode)

    # --- overload control (layer 3) ---
    olc_enabled: jnp.ndarray         # () f32 0/1
    olc_w_load: jnp.ndarray          # () f32
    olc_w_queue: jnp.ndarray         # () f32
    olc_w_tail: jnp.ndarray          # () f32
    defer_thr: jnp.ndarray           # (4,) f32 per-bucket severity cutoffs
    reject_thr: jnp.ndarray          # (4,) f32 per-bucket severity cutoffs
    defer_backoff_ms: jnp.ndarray    # () f32 base re-eligibility delay
    max_defers: jnp.ndarray          # () f32 defers before forced decision
    queue_ref: jnp.ndarray           # () f32 queue-pressure normalizer
    tail_ref: jnp.ndarray            # () f32 tail-ratio normalizer

    # --- misc ---
    route_by_class: jnp.ndarray      # () f32 0/1 — info-ladder class routing
    timeout_mult: jnp.ndarray        # (4,) f32 per-bucket patience: abandon
                                     #        after timeout_mult[bucket] *
                                     #        deadline_budget (inf-like = wait)


def _f(x) -> jnp.ndarray:
    return jnp.asarray(x, jnp.float32)


def n_classes(cfg: PolicyConfig) -> int:
    """Static class count K carried by the per-class policy arrays."""
    return cfg.drr_weights.shape[-1]


# Default client-wide concurrency budget — shared by base_policy and the
# K-class cap sizing so the two can't drift apart.
DEFAULT_MAX_INFLIGHT = 20.0


def base_policy(**overrides) -> PolicyConfig:
    """The Final (OLC) configuration — paper defaults."""
    cfg = dict(
        alloc_mode=jnp.asarray(ALLOC_ADRR, jnp.int32),
        drr_quantum=_f(220.0),
        drr_weights=_f([2.0, 1.0]),
        congestion_kappa=_f(1.5),
        deficit_cap=_f(8000.0),
        # shorts are cheap: effectively uncapped; heavy work holds at most 4
        # provider slots, shrinking toward 2 as severity rises — this is how
        # interactive traffic keeps protected share without idling capacity.
        class_cap=_f([16.0, 4.0]),
        cap_kappa=_f(0.5),
        class_protect=_f([1.0, 0.0]),
        max_inflight=_f(DEFAULT_MAX_INFLIGHT),
        load_ref=_f(6.0),
        ord_scored=_f([0.0, 1.0]),
        ord_w_wait=_f(1.0),
        ord_w_size=_f(0.6),
        ord_w_urg=_f(0.8),
        ord_ref_tokens=_f(512.0),
        ord_w_route=_f(1.0),
        olc_enabled=_f(1.0),
        olc_w_load=_f(0.40),
        olc_w_queue=_f(0.30),
        olc_w_tail=_f(0.30),
        defer_thr=_f([NEVER, NEVER, 0.45, 0.45]),
        reject_thr=_f([NEVER, NEVER, 0.80, 0.65]),
        defer_backoff_ms=_f(1000.0),
        max_defers=_f(2.0),
        queue_ref=_f(40.0),
        tail_ref=_f(4.0),
        route_by_class=_f(1.0),
        timeout_mult=_f([3.0, 3.0, 3.0, 3.0]),
    )
    cfg.update(overrides)
    return PolicyConfig(**cfg)


# ---------------------------------------------------------------------------
# Named strategies (paper §4.5/§4.6)
# ---------------------------------------------------------------------------

def direct_naive() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_NAIVE, jnp.int32),
        olc_enabled=_f(0.0),
        ord_w_size=_f(0.0),
        ord_w_urg=_f(0.0),
        route_by_class=_f(0.0),
        class_cap=_f([1e9, 1e9]),
        max_inflight=_f(1e9),  # admit-all: no client-side shaping at all
    )


def quota_tiered() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_QUOTA, jnp.int32),
        olc_enabled=_f(0.0),
        # strict isolation: small heavy quota protects tails but strands work
        class_cap=_f([8.0, 3.0]),
        cap_kappa=_f(0.0),
        # tiered SLAs: interactive/medium lanes wait; stranded longs are
        # tolerated (they drag the completed tail), stranded xlongs expire
        # fast (the quota's "withheld work" shows up in completion rate)
        timeout_mult=_f([3.0, 3.0, 2.0, 0.45]),
    )


def adaptive_drr() -> PolicyConfig:
    return base_policy(olc_enabled=_f(0.0))


def final_adrr_olc() -> PolicyConfig:
    return base_policy()


def fair_queuing() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_FQ, jnp.int32), olc_enabled=_f(0.0))


def short_priority() -> PolicyConfig:
    return base_policy(
        alloc_mode=jnp.asarray(ALLOC_SP, jnp.int32), olc_enabled=_f(0.0))


# ---------------------------------------------------------------------------
# Overload bucket_policy shapes (paper §4.7) applied on top of Final (OLC)
# ---------------------------------------------------------------------------

def with_bucket_policy(cfg: PolicyConfig, shape: str) -> PolicyConfig:
    tables = {
        "ladder": ([NEVER, NEVER, 0.45, 0.45], [NEVER, NEVER, 0.80, 0.65]),
        "uniform_mild": ([NEVER, 0.45, 0.45, 0.45], [NEVER] * 4),
        "uniform_harsh": ([NEVER, 0.45, 0.45, 0.45], [NEVER, 0.65, 0.65, 0.65]),
        "reverse": ([NEVER, NEVER, 0.45, 0.45], [NEVER, NEVER, 0.65, 0.80]),
    }
    d, r = tables[shape]
    return cfg._replace(defer_thr=_f(d), reject_thr=_f(r))


# ---------------------------------------------------------------------------
# Information-ladder conditions (paper §4.4) — policy-side part.
# (The workload generator owns the prior-side part: neutral vs coarse vs
# oracle p50/p90.)
# ---------------------------------------------------------------------------

def with_information(cfg: PolicyConfig, level: str) -> PolicyConfig:
    if level == "no_info":
        # single neutral lane; uniform admission severity (client cannot
        # infer cost from labels)
        return cfg._replace(
            route_by_class=_f(0.0),
            defer_thr=_f([0.60] * 4),
            reject_thr=_f([0.92] * 4),
        )
    if level == "class_only":
        # labels drive routing + tiered overload; priors stay neutral
        return cfg
    if level in ("coarse", "oracle"):
        return cfg
    raise ValueError(f"unknown information level: {level}")


# ---------------------------------------------------------------------------
# K-class builders (beyond-paper scenarios) — the tentpole generalization.
# The paper's decomposition is explicitly objective-agnostic; these builders
# instantiate the same three-layer stack for richer class structures.
# ---------------------------------------------------------------------------

def kclass_policy(
    k: int,
    *,
    weights=None,
    caps=None,
    protect=None,
    scored=None,
    **overrides,
) -> PolicyConfig:
    """Generic K-class policy: seed defaults with (K,)-shaped class arrays.

    Unspecified per-class arrays fall back to symmetric defaults: uniform
    weights, evenly split inflight caps, no protected lane, scored
    ordering everywhere.  `overrides` pass through to `base_policy`.
    """
    if k < 1:
        raise ValueError(f"n_classes must be >= 1, got {k}")
    w = _f([1.0] * k) if weights is None else _f(weights)
    # split the global concurrency budget with slack so borrowing-like
    # work conservation still has room (mirrors the seed's 16+4 > 20);
    # honor a max_inflight override so caps track the actual budget
    budget = float(overrides.get("max_inflight", DEFAULT_MAX_INFLIGHT))
    default_cap = max(2.0, round(2.0 * budget / k))
    c = _f([default_cap] * k) if caps is None else _f(caps)
    p = _f([0.0] * k) if protect is None else _f(protect)
    s = _f([1.0] * k) if scored is None else _f(scored)
    for name, arr in (("weights", w), ("caps", c), ("protect", p), ("scored", s)):
        if arr.shape != (k,):
            raise ValueError(f"{name} must have shape ({k},), got {arr.shape}")
    return base_policy(
        drr_weights=w, class_cap=c, class_protect=p, ord_scored=s, **overrides
    )


def multi_tenant_policy(k: int, **overrides) -> PolicyConfig:
    """K symmetric tenants: uniform DRR weights, per-tenant inflight caps,
    scored ordering in every lane, no protected lane (fairness is purely
    the allocation layer's deficit accounting)."""
    return kclass_policy(k, **overrides)


def per_bucket_policy(**overrides) -> PolicyConfig:
    """Four lanes keyed directly on the token bucket (short/medium/long/
    xlong): the short lane keeps the paper's protected-FIFO role; the
    other three use the scored rule with descending weight."""
    return kclass_policy(
        4,
        weights=[2.0, 1.0, 0.7, 0.4],
        caps=[16.0, 6.0, 4.0, 3.0],
        protect=[1.0, 0.0, 0.0, 0.0],
        scored=[0.0, 1.0, 1.0, 1.0],
        **overrides,
    )


STRATEGIES = {
    "direct_naive": direct_naive,
    "quota_tiered": quota_tiered,
    "adaptive_drr": adaptive_drr,
    "final_adrr_olc": final_adrr_olc,
    "fair_queuing": fair_queuing,
    "short_priority": short_priority,
}


def strategy(name: str) -> PolicyConfig:
    return STRATEGIES[name]()
