"""Layer 1 — allocation (paper §3.1.1), generalized to K classes.

Adaptive Deficit Round Robin over K service classes plus the
alternative allocation policies evaluated in the paper (§4.5/§4.6):
naive FIFO, quota-tiered isolation, fair queuing, strict priority.
K is static (the length of the per-class arrays in `PolicyConfig` and
of the deficit vector), so one trace serves any class count and trace
size is O(1) in K.

Semantics implemented (one *dispatch slot* at a time):
  * each backlogged class accrues `quantum * w_eff` deficit per slot;
  * a class may send iff its deficit covers the estimated cost (p50
    tokens) of the request its ordering layer would release;
  * work-conserving borrowing: idle classes' quanta are redistributed
    to backlogged classes in proportion to their effective weights
    (for K=2 this reduces exactly to the classic "lone class consumes
    the idle peer's quantum" rule);
  * congestion adaptation: protected classes (`class_protect`) scale
    their weight by (1 + kappa * severity) so protected share grows
    under stress, and keep their inflight cap while unprotected caps
    shrink.

Returns a `ClassChoice` — which class (if any) may release one request
this slot — plus updated deficits.  Branchless across allocation modes:
`lax.switch` on `alloc_mode` with every branch computing from the same
inputs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.policy import (
    ALLOC_ADRR,
    ALLOC_FQ,
    ALLOC_NAIVE,
    ALLOC_QUOTA,
    ALLOC_SP,
    PolicyConfig,
)


class ClassChoice(NamedTuple):
    cls_id: jnp.ndarray        # () int32 selected class (valid iff send_ok)
    send_ok: jnp.ndarray       # () bool a release is allowed this slot
    ignore_class: jnp.ndarray  # () bool pick request globally (naive lane)
    deficit: jnp.ndarray       # (K,) f32 updated deficit counters
    rr_turn: jnp.ndarray       # () int32 updated round-robin pointer


def effective_weights(cfg: PolicyConfig, severity) -> jnp.ndarray:
    """Congestion-aware weights: protected share grows with severity."""
    return cfg.drr_weights * (
        1.0 + cfg.congestion_kappa * severity * cfg.class_protect
    )


def allocate(
    cfg: PolicyConfig,
    *,
    backlog: jnp.ndarray,        # (K,) int32 eligible count per class
    head_cost: jnp.ndarray,      # (K,) f32 p50 of each class's would-be pick
    inflight_cls: jnp.ndarray,   # (K,) int32 in-flight count per class
    inflight_total: jnp.ndarray, # () int32
    severity: jnp.ndarray,       # () f32 overload severity in [0, ~1.5]
    deficit: jnp.ndarray,        # (K,) f32
    rr_turn: jnp.ndarray,        # () int32
) -> ClassChoice:
    k = deficit.shape[-1]
    under_cap = inflight_total < cfg.max_inflight
    # per-class inflight caps; unprotected caps shrink with severity so
    # protected traffic keeps its share under stress without leaving
    # capacity idle when the unprotected classes are empty.
    shrink = jnp.maximum(1.0 - cfg.cap_kappa * jnp.minimum(severity, 1.2), 0.3)
    cap_eff = cfg.class_cap * jnp.where(cfg.class_protect > 0, 1.0, shrink)
    cap_eff = jnp.maximum(cap_eff, 1.0)
    open_cls = inflight_cls < cap_eff
    has_work = (backlog > 0) & open_cls
    any_work = has_work.any()
    i32 = lambda x: jnp.asarray(x, jnp.int32)

    def _naive(_):
        # single lane, admit-all order-of-arrival; no deficit bookkeeping
        return ClassChoice(
            cls_id=i32(0),
            send_ok=(backlog > 0).any() & under_cap,
            ignore_class=jnp.asarray(True),
            deficit=deficit,
            rr_turn=rr_turn,
        )

    def _quota(_):
        # tiered isolation: a class may send iff its own inflight < quota.
        # No borrowing — strict silos (this is what strands heavy work).
        # Tiering prefers the lowest class index (argmax = first True).
        cls_id = jnp.argmax(has_work)
        return ClassChoice(
            cls_id=i32(cls_id),
            send_ok=any_work & under_cap,
            ignore_class=jnp.asarray(False),
            deficit=deficit,
            rr_turn=rr_turn,
        )

    def _adrr(_):
        w_eff = effective_weights(cfg, severity)
        # classic DRR: backlogged classes accrue quantum*w; borrowing
        # redistributes idle classes' quanta to backlogged ones in
        # proportion to effective weight (work conservation).
        accrue = cfg.drr_quantum * w_eff * has_work
        idle_quota = (cfg.drr_quantum * w_eff * (~has_work)).sum()
        w_backlogged = w_eff * has_work
        denom = w_backlogged.sum()
        share = jnp.where(denom > 0, w_backlogged / denom, 0.0)
        borrow = idle_quota * share
        d = jnp.minimum(deficit + accrue + borrow, cfg.deficit_cap)
        # affordability is clamped by the cap so a single oversized request
        # can never starve behind an unreachable deficit target
        affordable = has_work & (d >= jnp.minimum(head_cost, cfg.deficit_cap))
        # among affordable classes pick the largest normalized deficit
        pref = jnp.where(
            affordable, d * cfg.drr_weights / cfg.drr_weights.sum(), -jnp.inf
        )
        cls_id = jnp.argmax(pref)
        ok = affordable.any() & under_cap
        d = jnp.where(
            ok,
            d - jax.nn.one_hot(cls_id, k) * head_cost[cls_id],
            d,
        )
        # deficits of idle classes reset (classic DRR drops state when empty)
        d = jnp.where(has_work, d, 0.0)
        return ClassChoice(
            cls_id=i32(cls_id),
            send_ok=ok,
            ignore_class=jnp.asarray(False),
            deficit=d,
            rr_turn=rr_turn,
        )

    def _fq(_):
        # strict round robin across classes; skip empty classes by taking
        # the first backlogged class in rotation order from rr_turn
        offsets = (rr_turn + jnp.arange(k)) % k
        cls_id = offsets[jnp.argmax(has_work[offsets])]
        ok = any_work & under_cap
        # wrap the stored pointer: cls_id can be k-1, and rr_turn must
        # stay in [0, k) rather than rely on the re-modulo above
        turn = jnp.where(ok, (cls_id + 1) % k, rr_turn)
        return ClassChoice(
            cls_id=i32(cls_id),
            send_ok=ok,
            ignore_class=jnp.asarray(False),
            deficit=deficit,
            rr_turn=i32(turn),
        )

    def _sp(_):
        # strict priority: lowest backlogged class index first
        cls_id = jnp.argmax(has_work)
        return ClassChoice(
            cls_id=i32(cls_id),
            send_ok=any_work & under_cap,
            ignore_class=jnp.asarray(False),
            deficit=deficit,
            rr_turn=rr_turn,
        )

    return jax.lax.switch(
        jnp.clip(cfg.alloc_mode, 0, 4),
        [_naive, _quota, _adrr, _fq, _sp],
        operand=None,
    )


_ = (ALLOC_NAIVE, ALLOC_QUOTA, ALLOC_ADRR, ALLOC_FQ, ALLOC_SP)  # branch order doc
