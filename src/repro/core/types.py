"""Shared struct-of-array types for the client-side scheduling stack.

Everything here is a pytree of jnp arrays so the whole scheduler is
jit/vmap-able.  Request state follows the paper's lifecycle:

    PENDING --admit--> INFLIGHT --complete--> COMPLETED
            --defer--> (PENDING with defer_until in the future)
            --reject--> REJECTED
            --timeout--> ABANDONED  (implicit failure the paper's overload
                                     layer exists to replace)

Buckets follow the paper's token classes (short / medium / long / xlong)
and service classes are interactive (short) vs heavy (everything else).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Request status codes
# ---------------------------------------------------------------------------
PENDING = 0
INFLIGHT = 1
COMPLETED = 2
REJECTED = 3
ABANDONED = 4

# Bucket ids (paper: short <=64, medium 65-256, long 257-1024, xlong >1024)
SHORT, MEDIUM, LONG, XLONG = 0, 1, 2, 3
N_BUCKETS = 4

# Service classes.  The paper's scheme is two lanes — interactive
# (short) vs heavy (everything else) — but the whole stack is now
# parameterized by a static class count K: `PolicyConfig` carries
# (K,)-shaped per-class arrays, `SchedState.deficit` is (K,), and the
# scheduler vectorizes over the class axis, so trace size and compile
# time are O(1) in K.  `N_CLASSES` remains the default (paper) K = 2.
CLS_INTERACTIVE = 0
CLS_HEAVY = 1
N_CLASSES = 2

NEVER = jnp.inf  # threshold value meaning "this action never fires"


class RequestBatch(NamedTuple):
    """Struct-of-arrays for one workload instance (fixed capacity N).

    Static per-request fields produced by the workload generator; the
    simulator never mutates these.
    """

    arrival_ms: jnp.ndarray      # (N,) float32 absolute arrival time
    bucket: jnp.ndarray          # (N,) int32 in [0, 4)
    cls: jnp.ndarray             # (N,) int32 service class in [0, K)
    true_tokens: jnp.ndarray     # (N,) float32 realized output tokens
    p50: jnp.ndarray             # (N,) float32 policy-facing coarse prior
    p90: jnp.ndarray             # (N,) float32 policy-facing tail prior
    deadline_budget_ms: jnp.ndarray  # (N,) float32 relative SLO budget
    valid: jnp.ndarray           # (N,) bool — padding mask (N may exceed count)

    @property
    def n(self) -> int:
        return self.arrival_ms.shape[0]


class RequestState(NamedTuple):
    """Mutable per-request lifecycle state (simulator-owned)."""

    status: jnp.ndarray       # (N,) int32 status code
    submit_ms: jnp.ndarray    # (N,) float32 time handed to the provider
    finish_ms: jnp.ndarray    # (N,) float32 provider completion time
    defer_until: jnp.ndarray  # (N,) float32 earliest re-eligibility
    n_defers: jnp.ndarray     # (N,) int32 times this request was deferred
    n_throttles: jnp.ndarray  # (N,) int32 provider 429s this request saw
                              #        (rate-limited sends that bounced with
                              #        a client-visible retry-after)
    endpoint: Optional[jnp.ndarray] = None
                              # (N,) int32 fleet endpoint the request was
                              #        last routed to (fleet mode only;
                              #        None = single-provider — absence is
                              #        pytree structure, so the P=1-free
                              #        program is unchanged)


class SchedState(NamedTuple):
    """Scheduler-internal state (allocation layer + overload signals)."""

    deficit: jnp.ndarray       # (K,) float32 DRR deficit counters
    rr_turn: jnp.ndarray       # () int32 round-robin pointer (fair queuing)
    ema_latency_ratio: jnp.ndarray  # () float32 observed/expected latency EMA
    n_completed_obs: jnp.ndarray    # () int32 completions observed so far


class ProviderState(NamedTuple):
    """Client-visible view of the black box: only aggregate signals.

    `tb_tokens` / `n_throttled` are the provider-boundary token-bucket
    rate limiter (sim/provider.ProviderDynamics): grants remaining per
    service class and the running count of 429-style bounces.  Both stay
    at their init values when no limiter is configured, so every
    existing consumer is unaffected.
    """

    inflight: jnp.ndarray       # () int32 outstanding requests
    inflight_tokens: jnp.ndarray  # () float32 outstanding predicted work
    tb_tokens: jnp.ndarray      # (K,) float32 rate-limit grants available
    n_throttled: jnp.ndarray    # () int32 total 429-style bounces


class FleetState(NamedTuple):
    """Per-endpoint provider state along the fleet axis P (DESIGN.md §10).

    The fleet generalization of `ProviderState`: every aggregate signal
    gains a leading (P,) axis.  Present only in fleet mode
    (`SimState.fleet` is None otherwise — absence is pytree structure,
    so the single-provider program never traces a fleet branch).  In
    fleet mode `ProviderState` keeps the *global* totals (the
    allocation/overload layers are endpoint-agnostic); `FleetState`
    carries the per-endpoint split the routing layer scores.
    """

    inflight: jnp.ndarray         # (P,) int32 outstanding per endpoint
    inflight_tokens: jnp.ndarray  # (P,) float32 outstanding predicted work
    tb_tokens: jnp.ndarray        # (P, K) float32 per-endpoint rate grants
    n_throttled: jnp.ndarray      # (P,) int32 429 bounces per endpoint
    n_requeued: jnp.ndarray       # (P,) int32 in-flight requests requeued
                                  #       by an endpoint failure (failover)


class SimState(NamedTuple):
    now_ms: jnp.ndarray  # () float32
    req: RequestState
    sched: SchedState
    provider: ProviderState
    fleet: Optional[FleetState] = None  # (P,) fleet split; None = single


class WindowCarry(NamedTuple):
    """Compacted active-window slot pool (engine scan carry, DESIGN.md §6).

    The window holds every *live* request (PENDING or INFLIGHT, i.e.
    arrived and not yet terminal) in a fixed-capacity `(W,)` slot pool so
    the per-tick policy cost is O(W) instead of O(N).  Invariants the
    engine maintains every tick:

      * occupied slots are the compacted prefix `[0, n_live)`; the free
        region is the tail — reclamation is a stable compaction, not a
        positional free list, so that...
      * ...occupied slots are sorted by request id.  Arrivals are
        admitted in arrival order (ids are assigned arrival-sorted by the
        workload generator) and compaction preserves relative order, so
        slot order == request-id order.  This is what makes the ordering
        layer's first-occurrence tie-breaking over the window bit-exact
        with the dense `(N,)` path.
      * `slot_req[i] == n` marks slot i empty (out-of-range sentinel:
        gathers clamp, scatters drop).
    """

    slot_req: jnp.ndarray  # (W,) int32 request id per slot; n = empty
    arr_ptr: jnp.ndarray   # () int32 arrivals admitted so far (the batch's
                           #   arrival-sorted prefix [0, arr_ptr) is in or
                           #   through the window)
    n_live: jnp.ndarray    # () int32 occupied slot count (prefix length)


def init_request_state(n: int) -> RequestState:
    return RequestState(
        status=jnp.zeros((n,), jnp.int32),
        submit_ms=jnp.full((n,), jnp.inf, jnp.float32),
        finish_ms=jnp.full((n,), jnp.inf, jnp.float32),
        defer_until=jnp.zeros((n,), jnp.float32),
        n_defers=jnp.zeros((n,), jnp.int32),
        n_throttles=jnp.zeros((n,), jnp.int32),
    )


def init_sched_state(n_classes: int = N_CLASSES) -> SchedState:
    return SchedState(
        deficit=jnp.zeros((n_classes,), jnp.float32),
        rr_turn=jnp.zeros((), jnp.int32),
        ema_latency_ratio=jnp.ones((), jnp.float32),
        n_completed_obs=jnp.zeros((), jnp.int32),
    )


def init_provider_state(n_classes: int = N_CLASSES) -> ProviderState:
    # tb_tokens starts at zero; the engine seeds it to the configured
    # burst capacity when a rate limiter is active (sim/engine.run_sim).
    return ProviderState(
        inflight=jnp.zeros((), jnp.int32),
        inflight_tokens=jnp.zeros((), jnp.float32),
        tb_tokens=jnp.zeros((n_classes,), jnp.float32),
        n_throttled=jnp.zeros((), jnp.int32),
    )


def init_fleet_state(p: int, n_classes: int = N_CLASSES) -> FleetState:
    # tb_tokens starts at zero; the engine seeds it to the configured
    # per-endpoint burst capacity when a limiter is active (run_sim).
    return FleetState(
        inflight=jnp.zeros((p,), jnp.int32),
        inflight_tokens=jnp.zeros((p,), jnp.float32),
        tb_tokens=jnp.zeros((p, n_classes), jnp.float32),
        n_throttled=jnp.zeros((p,), jnp.int32),
        n_requeued=jnp.zeros((p,), jnp.int32),
    )


def empty_window_batch(w: int) -> RequestBatch:
    """A (W,)-shaped all-empty batch view — the starting slot pool of a
    streaming `ClientSession` (repro.client.session).  Empty slots carry
    the same neutralization the engine's `_window_view` applies to
    unoccupied slots: valid=False (never eligible); field values are
    don't-cares masked out of every decision path."""
    return RequestBatch(
        arrival_ms=jnp.zeros((w,), jnp.float32),
        bucket=jnp.zeros((w,), jnp.int32),
        cls=jnp.zeros((w,), jnp.int32),
        true_tokens=jnp.ones((w,), jnp.float32),
        p50=jnp.ones((w,), jnp.float32),
        p90=jnp.ones((w,), jnp.float32),
        deadline_budget_ms=jnp.full((w,), 1e9, jnp.float32),
        valid=jnp.zeros((w,), bool),
    )


def empty_window_request_state(w: int) -> RequestState:
    """Matching (W,)-shaped request state for `empty_window_batch`:
    empty slots are terminal (REJECTED, like the engine view's sentinel)
    and never land (finish=inf), so they are invisible to retirement,
    eligibility, and the inflight recount."""
    return RequestState(
        status=jnp.full((w,), REJECTED, jnp.int32),
        submit_ms=jnp.full((w,), jnp.inf, jnp.float32),
        finish_ms=jnp.full((w,), jnp.inf, jnp.float32),
        defer_until=jnp.zeros((w,), jnp.float32),
        n_defers=jnp.zeros((w,), jnp.int32),
        n_throttles=jnp.zeros((w,), jnp.int32),
    )


def init_window_carry(w: int, n: int) -> WindowCarry:
    return WindowCarry(
        slot_req=jnp.full((w,), n, jnp.int32),
        arr_ptr=jnp.zeros((), jnp.int32),
        n_live=jnp.zeros((), jnp.int32),
    )


def init_sim_state(n: int, n_classes: int = N_CLASSES) -> SimState:
    return SimState(
        now_ms=jnp.zeros((), jnp.float32),
        req=init_request_state(n),
        sched=init_sched_state(n_classes),
        provider=init_provider_state(n_classes),
    )
