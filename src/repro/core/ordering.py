"""Layer 2 — intra-class ordering (paper §3.1.2), generalized to K classes.

Among requests eligible under the fairness constraints, score each
candidate with the paper's slowdown-aware feasible-set rule

    score = w1 * (wait / cost) - w2 * (size / ref) + w3 * urgency

and release the argmax.  Whether a class orders FIFO or scored is a
per-class policy bit (`PolicyConfig.ord_scored`); the paper's scheme is
FIFO for the interactive class (shorts have near-uniform cost) and
scored for heavy.

`select_per_class` is the vectorized entry point: FIFO keys and scores
are computed once over the request axis and reduced along a (K, N)
class-mask, so the trace contains no Python loop over classes and is
O(1) in K.

All functions are pure and operate on the full struct-of-arrays with a
feasibility mask, so they jit/vmap cleanly and can be swapped for the
Pallas `sched_score` kernel at large queue depths.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.policy import PolicyConfig
from repro.core.types import RequestBatch

_NEG = -1e30


def eligibility(batch: RequestBatch, status, defer_until, now_ms):
    """Feasible set: arrived, pending, not under defer backoff."""
    return (
        batch.valid
        & (status == 0)
        & (batch.arrival_ms <= now_ms)
        & (defer_until <= now_ms)
    )


def order_scores(batch: RequestBatch, now_ms, cfg: PolicyConfig):
    """Paper scoring rule over every request (mask applied by caller)."""
    wait = jnp.maximum(now_ms - batch.arrival_ms, 0.0)
    cost = jnp.maximum(batch.p50, 1.0)
    deadline_abs = batch.arrival_ms + batch.deadline_budget_ms
    time_left = deadline_abs - now_ms
    urgency = jnp.clip(1.0 - time_left / jnp.maximum(batch.deadline_budget_ms, 1.0), 0.0, 2.0)
    return (
        cfg.ord_w_wait * (wait / cost)
        - cfg.ord_w_size * (cost / cfg.ord_ref_tokens)
        + cfg.ord_w_urg * urgency
    )


def select_fifo(batch: RequestBatch, mask):
    """FIFO pick: earliest arrival among mask. Returns (idx, any)."""
    key = jnp.where(mask, batch.arrival_ms, jnp.inf)
    idx = jnp.argmin(key)
    return idx, mask.any()


def select_scored(batch: RequestBatch, mask, now_ms, cfg: PolicyConfig):
    """Score-based pick among mask. Returns (idx, any)."""
    scores = jnp.where(mask, order_scores(batch, now_ms, cfg), _NEG)
    idx = jnp.argmax(scores)
    return idx, mask.any()


def select_per_class(
    batch: RequestBatch,
    cls_mask: jnp.ndarray,  # (K, N) bool — eligible requests per class
    now_ms,
    cfg: PolicyConfig,
):
    """Vectorized head-of-line pick for every class at once.

    Returns (idx, ok): (K,) int32 candidate per class and (K,) bool
    whether the class has any eligible request.  FIFO keys and scores
    are evaluated once over N; the per-class argmin/argmax is a masked
    reduction over the class axis — no Python loop, trace O(1) in K.
    """
    fifo_key = jnp.where(cls_mask, batch.arrival_ms[None, :], jnp.inf)
    scores = jnp.where(
        cls_mask, order_scores(batch, now_ms, cfg)[None, :], _NEG
    )
    fifo_idx = jnp.argmin(fifo_key, axis=1)
    sc_idx = jnp.argmax(scores, axis=1)
    use_score = cfg.ord_scored > 0
    idx = jnp.where(use_score, sc_idx, fifo_idx).astype(jnp.int32)
    ok = cls_mask.any(axis=1)
    return idx, ok
