"""Layer 2 — intra-class ordering (paper §3.1.2).

Among requests eligible under the fairness constraints, score each
candidate with the paper's slowdown-aware feasible-set rule

    score = w1 * (wait / cost) - w2 * (size / ref) + w3 * urgency

and release the argmax.  The interactive class is FIFO (the paper applies
the scoring rule to the heavy class; shorts have near-uniform cost).

All functions are pure and operate on the full struct-of-arrays with a
feasibility mask, so they jit/vmap cleanly and can be swapped for the
Pallas `sched_score` kernel at large queue depths.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.policy import PolicyConfig
from repro.core.types import RequestBatch

_NEG = -1e30


def eligibility(batch: RequestBatch, status, defer_until, now_ms):
    """Feasible set: arrived, pending, not under defer backoff."""
    return (
        batch.valid
        & (status == 0)
        & (batch.arrival_ms <= now_ms)
        & (defer_until <= now_ms)
    )


def order_scores(batch: RequestBatch, now_ms, cfg: PolicyConfig):
    """Paper scoring rule over every request (mask applied by caller)."""
    wait = jnp.maximum(now_ms - batch.arrival_ms, 0.0)
    cost = jnp.maximum(batch.p50, 1.0)
    deadline_abs = batch.arrival_ms + batch.deadline_budget_ms
    time_left = deadline_abs - now_ms
    urgency = jnp.clip(1.0 - time_left / jnp.maximum(batch.deadline_budget_ms, 1.0), 0.0, 2.0)
    return (
        cfg.ord_w_wait * (wait / cost)
        - cfg.ord_w_size * (cost / cfg.ord_ref_tokens)
        + cfg.ord_w_urg * urgency
    )


def select_fifo(batch: RequestBatch, mask):
    """FIFO pick: earliest arrival among mask. Returns (idx, any)."""
    key = jnp.where(mask, batch.arrival_ms, jnp.inf)
    idx = jnp.argmin(key)
    return idx, mask.any()


def select_scored(batch: RequestBatch, mask, now_ms, cfg: PolicyConfig):
    """Score-based pick among mask. Returns (idx, any)."""
    scores = jnp.where(mask, order_scores(batch, now_ms, cfg), _NEG)
    idx = jnp.argmax(scores)
    return idx, mask.any()


def select_for_class(batch: RequestBatch, mask, cls_id, now_ms, cfg: PolicyConfig):
    """Class 0 (interactive) is FIFO; class 1 (heavy) uses the scored rule.

    `cls_id` is a traced scalar, so blend the two selections branchlessly.
    """
    fifo_idx, fifo_any = select_fifo(batch, mask)
    sc_idx, sc_any = select_scored(batch, mask, now_ms, cfg)
    use_score = cls_id == 1
    idx = jnp.where(use_score, sc_idx, fifo_idx)
    ok = jnp.where(use_score, sc_any, fifo_any)
    return idx, ok
