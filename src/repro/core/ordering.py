"""Layer 2 — intra-class ordering (paper §3.1.2), generalized to K classes.

Among requests eligible under the fairness constraints, score each
candidate with the paper's slowdown-aware feasible-set rule

    score = w1 * (wait / cost) - w2 * (size / ref) + w3 * urgency

and release the argmax.  Whether a class orders FIFO or scored is a
per-class policy bit (`PolicyConfig.ord_scored`); the paper's scheme is
FIFO for the interactive class (shorts have near-uniform cost) and
scored for heavy.

`select_per_class` is the vectorized entry point: FIFO keys and scores
are computed once over the request axis and reduced along a (K, N)
class-mask, so the trace contains no Python loop over classes and is
O(1) in K.  `select_top_b` generalizes it to a ranked (K, B) candidate
list — the feed for the multi-grant batch dispatcher
(`scheduler.schedule_batch`).

Both selectors take a `backend` switch: "jnp" is the masked-reduction
path; "pallas" routes the score+argmax through the fused
`kernels/sched_score` kernel (one VMEM stream per argmax, no HBM score
materialization), the intended path at production queue depths (10^5+
pending).  FIFO classes run through the same kernel with weights
[1, 0, 0, 1], unit cost, and -arrival_ms in the wait slot, making the
score exactly -arrival_ms — argmax == argmin(arrival) with identical
first-occurrence tie-breaking, independent of now_ms.

All functions are pure and operate on the full struct-of-arrays with a
feasibility mask, so they jit/vmap cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import pinned
from repro.core.policy import PolicyConfig
from repro.core.types import RequestBatch

_NEG = -1e30

BACKENDS = ("jnp", "pallas")


def eligibility(batch: RequestBatch, status, defer_until, now_ms):
    """Feasible set: arrived, pending, not under defer backoff."""
    return (
        batch.valid
        & (status == 0)
        & (batch.arrival_ms <= now_ms)
        & (defer_until <= now_ms)
    )


def _wait_and_urgency(batch: RequestBatch, now_ms):
    """Shared score features — the single definition both the jnp path
    (`order_scores`) and the Pallas kernel inputs build from, so the
    backends cannot drift."""
    wait = jnp.maximum(now_ms - batch.arrival_ms, 0.0)
    deadline_abs = batch.arrival_ms + batch.deadline_budget_ms
    time_left = deadline_abs - now_ms
    urgency = jnp.clip(
        1.0 - time_left / jnp.maximum(batch.deadline_budget_ms, 1.0), 0.0, 2.0)
    return wait, urgency


def order_scores(batch: RequestBatch, now_ms, cfg: PolicyConfig, route=None):
    """Paper scoring rule over every request (mask applied by caller).

    The barrier pins each term's rounding before the sum: scores decide
    the top-B ranking, and the windowed engine evaluates this chain over
    (W,)-shaped views of the same requests the dense engine sees as
    (N,) — without the barrier XLA may FMA-contract one program but not
    the other, and a 1-ulp score drift can reorder near-ties.

    `route` ((N,) f32, fleet mode) is the predicted queue delay at the
    request's best endpoint in seconds (`routing.route_requests`); it
    enters as a fourth pinned term subtracted after the base sum — the
    same left-to-right association the Pallas kernel and its oracle use.
    """
    wait, urgency = _wait_and_urgency(batch, now_ms)
    cost = jnp.maximum(batch.p50, 1.0)
    if route is None:
        terms = pinned((
            cfg.ord_w_wait * (wait / cost),
            cfg.ord_w_size * (cost / cfg.ord_ref_tokens),
            cfg.ord_w_urg * urgency,
        ))
        return (terms[0] - terms[1]) + terms[2]
    terms = pinned((
        cfg.ord_w_wait * (wait / cost),
        cfg.ord_w_size * (cost / cfg.ord_ref_tokens),
        cfg.ord_w_urg * urgency,
        cfg.ord_w_route * route,
    ))
    return ((terms[0] - terms[1]) + terms[2]) - terms[3]


def select_fifo(batch: RequestBatch, mask):
    """FIFO pick: earliest arrival among mask. Returns (idx, any)."""
    key = jnp.where(mask, batch.arrival_ms, jnp.inf)
    idx = jnp.argmin(key)
    return idx, mask.any()


def select_scored(batch: RequestBatch, mask, now_ms, cfg: PolicyConfig,
                  route=None):
    """Score-based pick among mask. Returns (idx, any)."""
    scores = jnp.where(mask, order_scores(batch, now_ms, cfg, route), _NEG)
    idx = jnp.argmax(scores)
    return idx, mask.any()


def _kernel_inputs(batch: RequestBatch, now_ms, cfg: PolicyConfig,
                   with_route: bool = False):
    """Per-request feature vectors + per-class weight rows for the fused
    kernel.  A FIFO class feeds -arrival_ms through the `wait` slot with
    unit cost, zero urgency, and weights [1, 0, 0, 1], so its score is
    exactly -arrival_ms: argmax == argmin(arrival) with identical
    first-occurrence tie-breaking and no dependence on now_ms (a
    `now - arrival` key would quantize distinct arrivals into f32 ties
    at large now_ms).  With `with_route` the rows grow a fifth weight:
    `ord_w_route` for scored classes, 0 for FIFO (the route feature is
    streamed for every class but a zero weight keeps FIFO's score
    exactly -arrival_ms)."""
    wait, urgency = _wait_and_urgency(batch, now_ms)
    fifo_key = -batch.arrival_ms
    cost = batch.p50  # the kernel applies the max(cost, 1) clamp itself
    scored_w = [cfg.ord_w_wait, cfg.ord_w_size, cfg.ord_w_urg,
                cfg.ord_ref_tokens]
    fifo_w = [1.0, 0.0, 0.0, 1.0]
    if with_route:
        scored_w.append(cfg.ord_w_route)
        fifo_w.append(0.0)
    w_scored = jnp.stack(
        [jnp.asarray(w, jnp.float32) for w in scored_w]).astype(jnp.float32)
    w_fifo = jnp.asarray(fifo_w, jnp.float32)
    return wait, fifo_key, cost, urgency, w_scored, w_fifo


def select_per_class(
    batch: RequestBatch,
    cls_mask: jnp.ndarray,  # (K, N) bool — eligible requests per class
    now_ms,
    cfg: PolicyConfig,
    backend: str = "jnp",
    route=None,
):
    """Vectorized head-of-line pick for every class at once.

    Returns (idx, ok): (K,) int32 candidate per class and (K,) bool
    whether the class has any eligible request.  Defined as the b=1
    column of `select_top_b` on both backends — one source of truth for
    the ranking, so the head pick and the ranked list cannot drift
    (`lax.top_k` keeps argmax/argmin first-occurrence tie-breaking).
    `backend` must be static (a Python string) under jit.
    """
    idx, _ = select_top_b(batch, cls_mask, now_ms, cfg, 1, backend=backend,
                          route=route)
    return idx[:, 0], cls_mask.any(axis=1)


def rank_fifo(batch: RequestBatch, mask, b: int, backend: str = "jnp"):
    """Global FIFO ranked list: the first `b` eligible requests by
    arrival (earliest first).  Returns ((L,) int32 indices, () int32
    eligible count), L = min(b, N).  Feeds the naive (ignore-class)
    lane of the batch dispatcher.

    The pallas backend routes through the fused top-B kernel with the
    FIFO weight row: score == -arrival_ms exactly, so the ranking (and
    its first-occurrence tie-breaking) matches `lax.top_k(-key)` —
    masked lanes carry NEG on the kernel vs -inf here, but both rank
    after every eligible lane in the same index order.
    """
    b = min(int(b), batch.n)
    n_elig = mask.sum().astype(jnp.int32)
    if backend == "pallas":
        from repro.kernels.sched_score.ops import sched_score_topb

        w_fifo = jnp.asarray([1.0, 0.0, 0.0, 1.0], jnp.float32)
        idx, _ = sched_score_topb(
            -batch.arrival_ms, jnp.ones_like(batch.arrival_ms),
            jnp.zeros_like(batch.arrival_ms), mask, w_fifo, b)
        return idx, n_elig
    if backend != "jnp":
        raise ValueError(f"unknown ordering backend: {backend!r}")
    key = jnp.where(mask, batch.arrival_ms, jnp.inf)
    _, idx = jax.lax.top_k(-key, b)
    return idx.astype(jnp.int32), n_elig


def _select_top_b_pallas(batch, cls_mask, now_ms, cfg, b: int, route=None):
    """Ranked (K, B) candidates via the fused score+top-B kernel: one
    tiled pass per class computes scores and the blockwise partial top-B
    reduction in VMEM (kernels/sched_score), never materializing the
    (K, N) score matrix in HBM.  K is small and static, so the Python
    class loop costs K kernel launches, each streaming the queue once —
    versus the former B successive fused-argmax passes (B streams per
    class).  In fleet mode the route feature streams as a fifth row for
    every class; the FIFO weight row zeroes it out."""
    from repro.kernels.sched_score.ops import sched_score_topb

    k = cls_mask.shape[0]
    wait, fifo_key, cost, urgency, w_scored, w_fifo = _kernel_inputs(
        batch, now_ms, cfg, with_route=route is not None)
    rows = []
    for c in range(k):
        use_score = cfg.ord_scored[c] > 0
        w = jnp.where(use_score, w_scored, w_fifo)
        wait_c = jnp.where(use_score, wait, fifo_key)
        cost_c = jnp.where(use_score, cost, 1.0)
        urg_c = jnp.where(use_score, urgency, 0.0)
        idx, _ = sched_score_topb(wait_c, cost_c, urg_c, cls_mask[c], w, b,
                                  route)
        rows.append(idx)
    return jnp.stack(rows)


def select_top_b(
    batch: RequestBatch,
    cls_mask: jnp.ndarray,  # (K, N) bool — eligible requests per class
    now_ms,
    cfg: PolicyConfig,
    b: int,
    backend: str = "jnp",
    route=None,
):
    """Ranked head-of-line candidates for every class: the top `b`
    releases per class in release order (best first).

    Returns (idx, n_elig): (K, L) int32 ranked candidate indices with
    L = min(b, N), and (K,) int32 true per-class eligible counts.  Only
    the first min(n_elig[c], L) entries of row c are meaningful; column
    0 is bit-identical to `select_per_class` (same argmax/argmin with
    first-occurrence tie-breaking, which `lax.top_k` preserves).
    `route` ((N,) f32 or None) adds the fleet route cost term to scored
    classes on both backends; FIFO ranking never sees it.
    """
    b = min(int(b), batch.n)
    n_elig = cls_mask.sum(axis=1).astype(jnp.int32)
    if backend == "pallas":
        return _select_top_b_pallas(batch, cls_mask, now_ms, cfg, b,
                                    route), n_elig
    if backend != "jnp":
        raise ValueError(f"unknown ordering backend: {backend!r}")
    fifo_key = jnp.where(cls_mask, batch.arrival_ms[None, :], jnp.inf)
    scores = jnp.where(
        cls_mask, order_scores(batch, now_ms, cfg, route)[None, :], _NEG
    )
    _, fifo_rank = jax.lax.top_k(-fifo_key, b)   # (K, L) earliest-first
    _, sc_rank = jax.lax.top_k(scores, b)        # (K, L) best-score-first
    use_score = cfg.ord_scored[:, None] > 0
    idx = jnp.where(use_score, sc_rank, fifo_rank).astype(jnp.int32)
    return idx, n_elig
