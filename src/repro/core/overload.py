"""Layer 3 — overload control (paper §3.1.3).

Severity integrates only client-observable signals:

    severity = w_load * provider_load + w_queue * queue_pressure
             + w_tail * tail_latency_ratio

and the admission decision for the candidate request maps severity
through per-bucket threshold tables (the "cost ladder" and its §4.7
alternatives are all expressible as defer_thr/reject_thr vectors; inf
means never).  Short requests are never rejected under the ladder
because reject_thr[SHORT] = inf.

Actions:  0 = admit,  1 = defer,  2 = reject.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.numerics import pinned
from repro.core.policy import PolicyConfig

ADMIT, DEFER, REJECT = 0, 1, 2


def severity_score(
    cfg: PolicyConfig,
    *,
    inflight_total,     # () int32/float
    n_pending,          # () int32/float
    ema_latency_ratio,  # () f32 observed/expected completion latency EMA
) -> jnp.ndarray:
    provider_load = jnp.asarray(inflight_total, jnp.float32) / jnp.maximum(cfg.load_ref, 1.0)
    queue_pressure = jnp.asarray(n_pending, jnp.float32) / jnp.maximum(cfg.queue_ref, 1.0)
    tail_ratio = (jnp.maximum(ema_latency_ratio, 1.0) - 1.0) / jnp.maximum(cfg.tail_ref - 1.0, 1e-3)
    # barrier before the sum: severity drives every admission threshold,
    # and the windowed engine (DESIGN.md §6) compiles this identical
    # scalar subgraph inside a differently-shaped program — without the
    # barrier XLA may contract a mul into an FMA on one side only, and a
    # 1-ulp severity drift breaks the engines' bit-exact contract
    terms = pinned((
        cfg.olc_w_load * jnp.minimum(provider_load, 2.0),
        cfg.olc_w_queue * jnp.minimum(queue_pressure, 2.0),
        cfg.olc_w_tail * jnp.minimum(tail_ratio, 2.0),
    ))
    return jnp.maximum((terms[0] + terms[1]) + terms[2], 0.0)


def admission_action(
    cfg: PolicyConfig,
    *,
    severity,     # () f32
    bucket,       # () int32 candidate request's bucket
    n_defers,     # () int32 times this candidate was already deferred
) -> jnp.ndarray:
    """Cost-ladder decision for one candidate. Returns ADMIT/DEFER/REJECT.

    Reject dominates defer when both thresholds are crossed (the ladder's
    progressive tiers).  After `max_defers` deferrals a request is either
    admitted (if only defer fires) — deferral cannot stall work forever —
    matching the paper's "explicit, objective-aligned shedding" intent.
    """
    over_defer = severity > cfg.defer_thr[bucket]
    over_reject = severity > cfg.reject_thr[bucket]
    defer_exhausted = jnp.asarray(n_defers, jnp.float32) >= cfg.max_defers
    action = jnp.where(
        over_reject,
        REJECT,
        jnp.where(over_defer & ~defer_exhausted, DEFER, ADMIT),
    )
    return jnp.where(cfg.olc_enabled > 0, action, ADMIT).astype(jnp.int32)


def defer_backoff(cfg: PolicyConfig, severity, n_defers) -> jnp.ndarray:
    """Backoff grows with severity and with repeat deferrals (mild exp)."""
    growth = 1.0 + 0.5 * jnp.asarray(n_defers, jnp.float32)
    return cfg.defer_backoff_ms * (0.5 + severity) * growth
