"""Layer 0 — fleet routing: endpoint choice above allocation (DESIGN.md §10).

With a (P,) provider axis every release carries two decisions: *which
request* (the three paper layers, unchanged) and *which endpoint* (this
module).  Both read the same client-observable signals — per-endpoint
outstanding counts, comfort estimates, rate-limit pressure — and both
are pure functions, so the fleet engine and the live `FleetProvider`
share one definition of the routing cost.

The cost of sending request r to endpoint p is a predicted completion
time:

    cost[p, r] = unloaded(p, r) * (1 + inflight[p] / comfort[p])
                 + 429_pressure[p]          (+ UNAVAIL if p is down)

  * `unloaded(p, r) = base_ms[p] + ms_per_token[p] * p50[r]` — the
    endpoint's speed on this request's predicted size;
  * the load factor is a first-order queue-delay estimate: a fleet
    client cannot see the provider's true slowdown curve, only its own
    outstanding count per endpoint;
  * `429_pressure[p]` charges the expected Retry-After cost scaled by
    the fraction of the endpoint's class buckets that are dry — an
    endpoint that just bounced work is de-prioritized before it bounces
    more;
  * a down endpoint gets the finite `UNAVAIL` penalty (not inf: the
    cost feeds score arithmetic, and inf would poison the min when the
    whole fleet is down).

`route_requests` returns (endpoint, route): the per-request argmin
endpoint, and the min cost in seconds — the *route score term* the
ordering layer subtracts (requests whose best endpoint is congested
rank later; `PolicyConfig.ord_w_route` weights the term, and the Pallas
`sched_score` kernels carry it as a fifth feature row).

Everything is integer counts, schedule values, and elementwise f32
chains routed through `pinned`, so the windowed and dense fleet engines
compute bit-identical routes over the same requests (the same
cross-program discipline as `ordering.order_scores`).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax.numpy as jnp

from repro.core.numerics import pinned
from repro.core.types import FleetState

if TYPE_CHECKING:  # annotation-only: core must not import sim at runtime
    from repro.sim.provider import FleetPhysics

# Finite "effectively never" routing penalty for a down endpoint: large
# enough to dominate any real predicted delay, small enough that
# cost arithmetic (and the route score term) stays finite when the
# whole fleet is down.
UNAVAIL_MS = 1e9


def route_requests(
    fphys: FleetPhysics,
    fleet: FleetState,
    p50: jnp.ndarray,
    comfort_t=None,
    avail_t=None,
    retry_after_ms=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Score every (endpoint, request) pair; pick each request's endpoint.

    fphys: (P,)-leaf fleet physics; fleet: current `FleetState`;
    p50: (N,) f32 predicted sizes (any N — the dense batch or a window
    view); comfort_t: (P,) f32 brownout row or None; avail_t: (P,) f32
    availability row or None; retry_after_ms: () f32 when a limiter is
    configured (enables the 429-pressure term).

    Returns (endpoint (N,) i32, route (N,) f32): the argmin endpoint
    per request (ties to the lowest index) and the min predicted
    completion cost in seconds — the ordering layer's route score term.
    """
    comfort = fphys.comfort_concurrency
    if comfort_t is not None:
        comfort = comfort * jnp.asarray(comfort_t, jnp.float32)
    # integer outstanding count over comfort: a deterministic, width-
    # independent congestion estimate (the float inflight_tokens sum
    # reduces at engine width and is NOT cross-engine stable)
    load = fleet.inflight.astype(jnp.float32) / jnp.maximum(comfort, 1.0)
    penalty = jnp.zeros_like(load)
    if retry_after_ms is not None:
        # 429 pressure: expected Retry-After, scaled by how much of the
        # endpoint's rate budget is dry (fraction of class buckets
        # without a whole grant left)
        dry = (fleet.tb_tokens < 1.0).mean(axis=1)
        penalty = jnp.asarray(retry_after_ms, jnp.float32) * dry
    # the barrier isolates the cost chain from differently-shaped
    # producers so both engine programs lower it identically (the same
    # cross-program pin as ordering.order_scores)
    base, mpt, loadv, pen = pinned(
        (fphys.base_ms, fphys.ms_per_token, load, penalty))
    unloaded = base[:, None] + mpt[:, None] * p50[None, :]   # (P, N)
    cost = unloaded * (1.0 + loadv[:, None]) + pen[:, None]
    if avail_t is not None:
        cost = jnp.where(
            jnp.asarray(avail_t, jnp.float32)[:, None] < 0.5,
            jnp.float32(UNAVAIL_MS), cost)
    endpoint = jnp.argmin(cost, axis=0).astype(jnp.int32)
    route = pinned(jnp.min(cost, axis=0) * 1e-3)
    return endpoint, route
