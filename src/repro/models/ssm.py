"""Mamba2 (state-space duality / SSD) mixer [arXiv:2405.21060].

Chunked SSD computation (the quadratic-intra + linear-inter decomposition
that the paper's Algorithm 1 establishes):

  per head h (head_dim P, state N), with per-step log-decay
  la_t = -exp(A_log_h) * dt_t and input scale dt_t:

    state_t = exp(la_t) * state_{t-1} + dt_t * (x_t outer B_t)
    y_t     = C_t . state_t + D_h * x_t

  split the sequence into chunks of length Q:
    * intra-chunk: masked (C_t.B_s) kernel weighted by the decay segment
      exp(cum_t - cum_s) — a Q x Q matmul per (batch, chunk, head);
    * inter-chunk: carry chunk-final states with a lax.scan (nc steps).

The O(1)-state `ssd_step` is the decode path (this is what makes
long_500k native for SSM archs).  `repro.kernels.ssd_scan` provides the
Pallas TPU kernel for the intra-chunk part; `ssd_chunked` here is its
pure-jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import dense_apply, dense_init
from repro.models.norms import norm_apply, norm_init


def ssm_init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d, di, N = cfg.d_model, cfg.d_inner, s.d_state
    H = cfg.n_ssm_heads
    ks = jax.random.split(key, 3)
    conv_ch = di + 2 * N
    params = {
        # fused input projection -> [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * N + H, "embed", "ssm_inner", dtype)[0],
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch), dtype)
                   * jnp.asarray(1.0 / jnp.sqrt(s.conv_width), dtype)),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": norm_init(di, "rmsnorm", dtype)[0],
        "out_proj": dense_init(ks[2], di, d, "ssm_inner", "embed", dtype)[0],
    }
    axes = {
        "in_proj": {"w": ("embed", "ssm_inner")},
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": {"scale": ("ssm_inner",)},
        "out_proj": {"w": ("ssm_inner", "embed")},
    }
    return params, axes


def _split_proj(cfg: ModelConfig, h):
    di, N = cfg.d_inner, cfg.ssm.d_state
    z, x, B, C, dt = jnp.split(
        h, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(w, b, u, state=None):
    """Depthwise causal conv, width W.  u: (B, S, C).  state: (B, W-1, C)
    carries the last W-1 inputs for streaming decode. Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)                # (B, S+W-1, C)
    y = sum(ext[:, i:i + u.shape[1]] * w[i] for i in range(W)) + b
    new_state = ext[:, -(W - 1):]
    return jax.nn.silu(y), new_state


def ssd_chunked(x, Bm, Cm, dt, A, chunk: int, state0=None, impl: str = "xla"):
    """Chunked SSD scan.

    x: (B,S,H,P); Bm/Cm: (B,S,N); dt: (B,S,H) (softplus'd, f32);
    A: (H,) positive decay rates (la = -A*dt); state0: (B,H,P,N) or None.
    Returns (y: (B,S,H,P) in x.dtype, final_state: (B,H,P,N) f32).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_pad = -S % Q
    if S_pad:
        # zero-pad to a chunk boundary: dt = 0 means decay exp(0) = 1 and
        # zero input contribution, so padded steps are exact no-ops for the
        # state; padded y rows are sliced off below.
        pad = lambda a: jnp.pad(a, ((0, 0), (0, S_pad)) + ((0, 0),) * (a.ndim - 2))
        x, Bm, Cm, dt = pad(x), pad(Bm), pad(Cm), pad(dt)
    S_full = S + S_pad
    nc = S_full // Q

    xc = x.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)

    la = -A[None, None, None, :] * dtc                       # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(la, axis=2)                             # inclusive cumsum

    if impl == "pallas":
        from repro.kernels.ssd_scan import ops as ssd_ops
        y_intra, chunk_state = ssd_ops.ssd_intra(xc, Bc, Cc, dtc, cum)
    else:
        y_intra, chunk_state = ssd_intra_ref(xc, Bc, Cc, dtc, cum)

    # ---- inter-chunk recurrence over chunk-final states
    total = cum[:, :, -1]                                    # (B,nc,H)
    if state0 is None:
        state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(carry, inp):
        st_in, tot, = inp
        out_prev = carry                                     # state before chunk
        new = st_in + jnp.exp(tot)[:, :, None, None] * out_prev
        return new, out_prev

    # scan over chunks: carry (B,H,P,N)
    final_state, prev_states = jax.lax.scan(
        step,
        state0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,P,N)

    # inter-chunk contribution: y_t += C_t . (exp(cum_t) * prev_state)
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(Bsz, S_full, H, P)[:, :S].astype(x.dtype)
    return y, final_state


def ssd_intra_ref(xc, Bc, Cc, dtc, cum):
    """Pure-jnp oracle for the intra-chunk SSD kernel.

    xc: (B,nc,Q,H,P) f32; Bc/Cc: (B,nc,Q,N); dtc/cum: (B,nc,Q,H).
    Returns (y_intra: (B,nc,Q,H,P), chunk_state: (B,nc,H,P,N))."""
    Q = xc.shape[2]
    # decay segment exp(cum_t - cum_s) masked to s <= t  -> (B,nc,H,Q,Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Qt,Qs,H)
    seg = jnp.moveaxis(seg, -1, 2)                           # (B,nc,H,Qt,Qs)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # mask INSIDE the exponent: exp(seg) for s > t can overflow to inf in
    # the forward pass, and the cotangent of where() would then be inf*0=NaN
    decay = jnp.exp(jnp.where(mask, seg, -1e9))
    kernel = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)           # (B,nc,Qt,Qs)
    W = kernel[:, :, None] * decay                           # (B,nc,H,Qt,Qs)
    W = W * jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]       # weight by dt_s
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", W, xc)
    # chunk-final state: sum_s exp(cum_Q - cum_s) dt_s (x_s outer B_s)
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dtc            # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", tail, xc, Bc)
    return y_intra, chunk_state


def ssd_step(x, Bm, Cm, dt, A, D, state):
    """O(1) decode step.

    x: (B,H,P); Bm/Cm: (B,N); dt: (B,H); state: (B,H,P,N) f32.
    Returns (y: (B,H,P), new_state)."""
    xf = x.astype(jnp.float32)
    a = jnp.exp(-A[None, :] * dt)                            # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xf, Bm.astype(jnp.float32))
    new_state = a[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    y = y + D[None, :, None] * xf
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full mixer (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def ssm_prefill(p, cfg: ModelConfig, x, state=None, impl: str = "xla"):
    """x: (B,S,d_model). Returns (out, cache={'ssd','conv'})."""
    s = cfg.ssm
    H, P, N, di = cfg.n_ssm_heads, s.head_dim, s.d_state, cfg.d_inner
    h = dense_apply(p["in_proj"], x)
    z, u, Bm, Cm, dt = _split_proj(cfg, h)
    conv_in = jnp.concatenate([u, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(
        p["conv_w"], p["conv_b"], conv_in,
        state["conv"] if state else None)
    u, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = jnp.exp(p["A_log"].astype(jnp.float32))
    y, ssd_state = ssd_chunked(
        u.reshape(*u.shape[:2], H, P), Bm, Cm, dt, A, s.chunk,
        state0=state["ssd"] if state else None, impl=impl)
    y = y + p["D"].astype(x.dtype)[None, None, :, None] * u.reshape(*u.shape[:2], H, P)
    y = y.reshape(*x.shape[:2], di)
    y = norm_apply(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = dense_apply(p["out_proj"], y)
    return out, {"ssd": ssd_state, "conv": conv_state}


def ssm_decode(p, cfg: ModelConfig, x, state, impl: str = "xla"):
    """x: (B,1,d_model); state from prefill/init. O(1) per token."""
    s = cfg.ssm
    H, P, N, di = cfg.n_ssm_heads, s.head_dim, s.d_state, cfg.d_inner
    h = dense_apply(p["in_proj"], x)
    z, u, Bm, Cm, dt = _split_proj(cfg, h)
    conv_in = jnp.concatenate([u, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(
        p["conv_w"], p["conv_b"], conv_in, state["conv"])
    u, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = jnp.exp(p["A_log"].astype(jnp.float32))
    y, ssd_state = ssd_step(
        u[:, 0].reshape(-1, H, P), Bm[:, 0], Cm[:, 0], dt[:, 0], A,
        p["D"].astype(jnp.float32), state["ssd"])
    y = y.reshape(x.shape[0], 1, di)
    y = norm_apply(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = dense_apply(p["out_proj"], y)
    return out, {"ssd": ssd_state, "conv": conv_state}


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    return {
        "ssd": jnp.zeros((batch, cfg.n_ssm_heads, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros(
            (batch, s.conv_width - 1, cfg.d_inner + 2 * s.d_state), dtype),
    }


SSM_STATE_AXES = {
    "ssd": ("cache_batch", None, "ssm_inner", None),
    "conv": ("cache_batch", None, "ssm_inner"),
}
