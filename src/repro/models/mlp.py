"""Feed-forward blocks: SiLU-gated (llama-style), squared-ReLU
(Nemotron-4), and plain GELU (StarCoder2 / MusicGen)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_apply, dense_init


def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype,
             bias: bool = False):
    ks = jax.random.split(key, 3)
    params, axes = {}, {}
    if activation == "silu_gated":
        params["wi"], axes["wi"] = dense_init(ks[0], d_model, d_ff, "embed", "mlp", dtype, bias)
        params["wg"], axes["wg"] = dense_init(ks[1], d_model, d_ff, "embed", "mlp", dtype, bias)
    else:
        params["wi"], axes["wi"] = dense_init(ks[0], d_model, d_ff, "embed", "mlp", dtype, bias)
    params["wo"], axes["wo"] = dense_init(ks[2], d_ff, d_model, "mlp", "embed", dtype, bias)
    return params, axes


def mlp_apply(p, x, activation: str):
    h = dense_apply(p["wi"], x)
    if activation == "silu_gated":
        h = jax.nn.silu(h) * dense_apply(p["wg"], x)
    elif activation == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(activation)
    return dense_apply(p["wo"], h)
