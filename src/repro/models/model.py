"""Full decoder LM: embeddings -> lax.scan over stacked blocks -> head.

Three entry points (all pure functions of (params, cfg, inputs)):
  * forward_train : logits + aux losses (no caches)
  * prefill       : logits for the prompt + decode-ready caches
  * decode_step   : one token against caches (the serve_step the
                    assigned decode shapes lower)

VLM/audio frontends are stubs per the assignment carve-out: callers pass
`prefix_embeds` (B, prefix_len, d_model) — the patch/frame embeddings a
real ViT/EnCodec encoder would produce — and the decoder consumes them as
a prefix; the loss masks prefix positions.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import CACHE_AXES, KVCache
from repro.models.blocks import block_decode, block_init, block_prefill
from repro.models.common import dtype_of, is_axes_leaf, stack_inits
from repro.models.norms import norm_apply, norm_init
from repro.sharding.rules import constrain
from repro.models.rope import sinusoidal_embed


class Model(NamedTuple):
    params: Any
    axes: Any
    cfg: ModelConfig


def layer_globals(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) 0/1: layers using full (global) attention in hybrid archs."""
    g = jnp.zeros((cfg.n_layers,), jnp.int32)
    for i in cfg.global_layers:
        g = g.at[i].set(1)
    return g


def init_model(key, cfg: ModelConfig) -> Model:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    params: dict = {}
    axes: dict = {}

    scale = 1.0 / jnp.sqrt(cfg.d_model)
    params["embed"] = (
        jax.random.normal(k_emb, (cfg.padded_vocab, cfg.d_model), dtype) * scale)
    axes["embed"] = ("vocab", "embed")

    params["blocks"], axes["blocks"] = stack_inits(
        lambda k: block_init(k, cfg, dtype), k_blocks, cfg.n_layers)

    params["final_norm"], axes["final_norm"] = norm_init(
        cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.padded_vocab), dtype)
            * scale)
        axes["head"] = ("embed", "vocab")
    return Model(params, axes, cfg)


def _embed(params, cfg: ModelConfig, tokens, prefix_embeds, pos0: int = 0):
    """tokens: (B, S_txt) int32; prefix_embeds: (B, P, D) or None."""
    h = params["embed"][tokens]
    h = constrain(h, "batch", None, None)  # re-pin batch after the gather
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    if not cfg.rope:  # MusicGen-style absolute sinusoidal positions
        h = h + sinusoidal_embed(positions, cfg.d_model, h.dtype)
    return h, positions


def _head(params, cfg: ModelConfig, h):
    h = norm_apply(params["final_norm"], h, cfg.norm)
    h = constrain(h, "batch", None, None)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (h @ w).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    if cfg.padded_vocab != cfg.vocab:  # mask alignment-padding columns
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(valid, logits, -jnp.inf)
    return logits


# ---------------------------------------------------------------------------
# Train / no-cache forward
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, tokens, prefix_embeds=None,
                  impl: str = "xla", remat: bool = True):
    h, positions = _embed(params, cfg, tokens, prefix_embeds)
    is_global = layer_globals(cfg)

    def body(carry, xs):
        layer_params, g = xs
        x, aux = carry
        x, _, a = block_prefill(layer_params, cfg, x, positions, g, None, impl)
        return (x, aux + a), None

    block_fn = jax.checkpoint(body) if remat else body
    (h, aux), _ = jax.lax.scan(block_fn, (h, jnp.float32(0.0)),
                               (params["blocks"], is_global),
                               unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return _head(params, cfg, h), aux


def lm_loss(params, cfg: ModelConfig, tokens, labels, prefix_embeds=None,
            impl: str = "xla", remat: bool = True):
    """Next-token cross entropy; prefix positions (VLM/audio stub) excluded
    automatically because labels only cover text tokens."""
    logits, aux = forward_train(params, cfg, tokens, prefix_embeds, impl, remat)
    P = logits.shape[1] - labels.shape[1]
    logits = logits[:, P:]  # drop prefix positions
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def _ring_from_linear(k, S: int, window: int):
    """Convert the last `window` positions of a linear (B,S,KV,hd) K/V into
    ring layout (slot = pos % window)."""
    if S <= window:
        pad = jnp.zeros((k.shape[0], window - S, *k.shape[2:]), k.dtype)
        return jnp.concatenate([k, pad], axis=1)  # slots 0..S-1 valid
    last = k[:, S - window:]
    return jnp.roll(last, S % window, axis=1)


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode caches for every layer (stacked on a leading L axis)."""
    dtype = dtype_of(cfg.dtype)
    L = cfg.n_layers
    cache = {}
    if cfg.arch_type != "ssm":
        # Hybrid archs with global layers share one scan-stacked linear
        # buffer sized max_seq (windowed layers mask down to their window
        # via the unified validity rule in attn_decode); pure windowed
        # archs get a compact ring of size `window`.
        if cfg.arch_type == "hybrid" and cfg.global_layers:
            S_buf = max_seq
        elif cfg.sliding_window > 0:
            S_buf = min(cfg.sliding_window, max_seq)
        else:
            S_buf = max_seq
        kv_shape = (batch, S_buf, cfg.n_kv, cfg.head_dim)
        cache["kv"] = KVCache(
            jnp.zeros((L, *kv_shape), dtype), jnp.zeros((L, *kv_shape), dtype))
    if cfg.arch_type in ("ssm", "hybrid"):
        one = ssm_mod.init_ssm_state(cfg, batch, dtype)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.zeros((L, *x.shape), x.dtype), one)
    return cache


def cache_axes(cfg: ModelConfig):
    axes = {}
    if cfg.arch_type != "ssm":
        axes["kv"] = KVCache(
            ("layers",) + tuple(CACHE_AXES.k), ("layers",) + tuple(CACHE_AXES.v))
    if cfg.arch_type in ("ssm", "hybrid"):
        axes["ssm"] = jax.tree.map(
            lambda a: ("layers",) + tuple(a), ssm_mod.SSM_STATE_AXES,
            is_leaf=is_axes_leaf)
    return axes


def prefill(params, cfg: ModelConfig, tokens, max_seq: int,
            prefix_embeds=None, impl: str = "xla"):
    """Run the prompt, returning (last-position logits, decode caches)."""
    h, positions = _embed(params, cfg, tokens, prefix_embeds)
    B, S, _ = h.shape
    is_global = layer_globals(cfg)
    dtype = dtype_of(cfg.dtype)

    def body(x, xs):
        layer_params, g = xs
        x, new_cache, _ = block_prefill(layer_params, cfg, x, positions, g, None, impl)
        ys = {}
        if "kv_raw" in new_cache:
            k, v = new_cache["kv_raw"]
            # layout for decode: compact ring for pure windowed archs;
            # linear buffer padded to max_seq otherwise (incl. hybrid)
            if cfg.sliding_window > 0 and cfg.arch_type != "hybrid":
                k_c = _ring_from_linear(k, S, min(cfg.sliding_window, max_seq))
                v_c = _ring_from_linear(v, S, min(cfg.sliding_window, max_seq))
            else:
                pad = max_seq - S
                k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            ys["kv"] = KVCache(k_c.astype(dtype), v_c.astype(dtype))
        if "ssm" in new_cache:
            ys["ssm"] = new_cache["ssm"]
        return x, ys

    h, caches = jax.lax.scan(body, h, (params["blocks"], is_global),
                             unroll=cfg.n_layers if cfg.scan_unroll else 1)
    logits = _head(params, cfg, h[:, -1:])
    return logits, caches


def decode_step(params, cfg: ModelConfig, token, pos, caches,
                impl: str = "xla"):
    """One decode step. token: (B, 1) int32; pos: () int32 current absolute
    position; caches: stacked per-layer caches. Returns (logits, caches)."""
    h, _ = _embed(params, cfg, token, None, pos0=0)
    if not cfg.rope:
        # _embed added position-0 sinusoid; replace with the true position
        h = params["embed"][token]
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
        h = h + sinusoidal_embed(positions, cfg.d_model, h.dtype)
    is_global = layer_globals(cfg)

    def body(x, xs):
        layer_params, g, cache = xs
        x, new_cache = block_decode(layer_params, cfg, x, pos, g, cache, impl)
        return x, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["blocks"], is_global, caches),
                                 unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return _head(params, cfg, h), new_caches
