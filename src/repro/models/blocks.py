"""Per-architecture transformer blocks, written scan-over-layers style:
`*_init` builds one layer's params; `stack_inits` in model.py vmaps them
into stacked (L, ...) leaves; the `*_apply` functions take ONE layer's
slice plus the running hidden state and optional per-layer cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    attn_decode,
    attn_init,
    attn_prefill,
)
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.models.norms import norm_apply, norm_init


def block_init(key, cfg: ModelConfig, dtype):
    """One layer. Returns (params, axes)."""
    ks = jax.random.split(key, 8)
    params, axes = {}, {}
    params["norm1"], axes["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)

    if cfg.arch_type == "ssm":
        params["ssm"], axes["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
        return params, axes

    if cfg.arch_type == "hybrid":
        params["attn"], axes["attn"] = attn_init(ks[0], cfg, dtype)
        params["ssm"], axes["ssm"] = ssm_mod.ssm_init(ks[1], cfg, dtype)
        params["branch_norm_attn"], axes["branch_norm_attn"] = norm_init(
            cfg.d_model, "rmsnorm", dtype)
        params["branch_norm_ssm"], axes["branch_norm_ssm"] = norm_init(
            cfg.d_model, "rmsnorm", dtype)
    else:
        params["attn"], axes["attn"] = attn_init(ks[0], cfg, dtype)

    params["norm2"], axes["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
    if cfg.moe is not None:
        params["moe"], axes["moe"] = moe_init(ks[2], cfg, dtype)
    else:
        params["mlp"], axes["mlp"] = mlp_init(
            ks[2], cfg.d_model, cfg.d_ff, cfg.activation, dtype, cfg.mlp_bias)
    return params, axes


def _layer_window(cfg: ModelConfig, is_global):
    """Per-layer effective window: hybrid global layers use full attention;
    `is_global` is a traced 0/1 scalar from the scanned layer metadata."""
    w = jnp.asarray(cfg.sliding_window, jnp.int32)
    return jnp.where(is_global > 0, 0, w)


def _mixer_prefill(p, cfg: ModelConfig, h, positions, is_global, cache, impl):
    """Token mixer (attention / ssm / hybrid) over a full sequence.
    cache: per-layer dict or None. Returns (out, new_cache)."""
    new_cache = {}
    if cfg.arch_type == "ssm":
        out, st = ssm_mod.ssm_prefill(p["ssm"], cfg, h, cache and cache.get("ssm"), impl)
        new_cache["ssm"] = st
        return out, new_cache

    window = _layer_window(cfg, is_global)
    if cfg.arch_type == "hybrid":
        a_out, kv = attn_prefill(p["attn"], cfg, h, positions, window, impl)
        s_out, st = ssm_mod.ssm_prefill(p["ssm"], cfg, h, cache and cache.get("ssm"), impl)
        out = 0.5 * (
            norm_apply(p["branch_norm_attn"], a_out, "rmsnorm")
            + norm_apply(p["branch_norm_ssm"], s_out, "rmsnorm"))
        new_cache["ssm"] = st
        new_cache["kv_raw"] = kv
        return out, new_cache

    out, kv = attn_prefill(p["attn"], cfg, h, positions, window, impl)
    new_cache["kv_raw"] = kv
    return out, new_cache


def _mixer_decode(p, cfg: ModelConfig, h, pos, is_global, cache, impl):
    new_cache = {}
    if cfg.arch_type == "ssm":
        out, st = ssm_mod.ssm_decode(p["ssm"], cfg, h, cache["ssm"], impl)
        new_cache["ssm"] = st
        return out, new_cache

    window = _layer_window(cfg, is_global)
    if cfg.arch_type == "hybrid":
        a_out, kv = attn_decode(p["attn"], cfg, h, pos, cache["kv"], window, impl)
        s_out, st = ssm_mod.ssm_decode(p["ssm"], cfg, h, cache["ssm"], impl)
        out = 0.5 * (
            norm_apply(p["branch_norm_attn"], a_out, "rmsnorm")
            + norm_apply(p["branch_norm_ssm"], s_out, "rmsnorm"))
        new_cache["ssm"] = st
        new_cache["kv"] = kv
        return out, new_cache

    out, kv = attn_decode(p["attn"], cfg, h, pos, cache["kv"], window, impl)
    new_cache["kv"] = kv
    return out, new_cache


def _channel_mix(p, cfg: ModelConfig, h):
    """MLP / MoE half of the block. Returns (out, aux_loss)."""
    if cfg.arch_type == "ssm":
        return jnp.zeros_like(h), jnp.float32(0.0)
    hn = norm_apply(p["norm2"], h, cfg.norm)
    if cfg.moe is not None:
        out, aux = moe_apply(p["moe"], cfg, hn)
        return out, aux
    return mlp_apply(p["mlp"], hn, cfg.activation), jnp.float32(0.0)


def block_prefill(p, cfg: ModelConfig, x, positions, is_global, cache, impl):
    """Full block over a sequence. Returns (x, new_cache, aux)."""
    h = norm_apply(p["norm1"], x, cfg.norm)
    mix, new_cache = _mixer_prefill(p, cfg, h, positions, is_global, cache, impl)
    x = x + mix
    ch, aux = _channel_mix(p, cfg, x)
    return x + ch, new_cache, aux


def block_decode(p, cfg: ModelConfig, x, pos, is_global, cache, impl):
    h = norm_apply(p["norm1"], x, cfg.norm)
    mix, new_cache = _mixer_decode(p, cfg, h, pos, is_global, cache, impl)
    x = x + mix
    ch, _ = _channel_mix(p, cfg, x)
    return x + ch, new_cache
