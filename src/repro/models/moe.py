"""Mixture-of-experts layer (Arctic 128e/top-2 + dense residual,
Phi-3.5-MoE 16e/top-2).

Sort-based token permutation (MaxText-style "dropping" implementation):

  1. router top-k per token,
  2. flatten (token, k) slots, stable-sort by expert id,
  3. rank-within-expert via cumulative offsets; slots whose rank exceeds
     the expert capacity are dropped (contribute zero),
  4. gather tokens into an (E, C, d) buffer, batched expert matmuls
     ('ecd,edf->ecf' — experts shardable over the tensor axis; the
     token->expert regroup is where GSPMD inserts the all-to-all),
  5. scatter-combine weighted by router gates.

Load-balance auxiliary loss follows Switch/Mixtral:
  aux = E * sum_e(frac_tokens_e * mean_router_prob_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import dense_apply, dense_init
from repro.models.mlp import mlp_apply


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 6)
    gate_mult = cfg.activation == "silu_gated"
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(ff)

    def expert_w(k, shape, scale):
        return jax.random.normal(k, shape, dtype) * jnp.asarray(scale, dtype)

    params = {
        "router": dense_init(ks[0], d, m.n_experts, "embed", "experts", dtype)[0],
        "wi": expert_w(ks[1], (m.n_experts, d, ff), s_in),
        "wo": expert_w(ks[3], (m.n_experts, ff, d), s_out),
    }
    axes = {
        "router": {"w": ("embed", "experts")},
        "wi": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if gate_mult:
        params["wg"] = expert_w(ks[2], (m.n_experts, d, ff), s_in)
        axes["wg"] = ("experts", "embed", "mlp")
    if m.dense_residual:
        from repro.models.mlp import mlp_init
        params["residual"], axes["residual"] = mlp_init(
            ks[4], d, ff, cfg.activation, dtype, cfg.mlp_bias)
    return params, axes


def _expert_ffn(p, x, activation: str):
    """x: (E, C, d) -> (E, C, d) with per-expert weights."""
    h = jnp.einsum("ecd,edf->ecf", x, p["wi"])
    if activation == "silu_gated":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x, p["wg"])
    elif activation == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def moe_apply(p, cfg: ModelConfig, x):
    """x: (B, S, d). Returns (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    k = m.top_k
    E = m.n_experts
    xt = x.reshape(T, d)

    logits = dense_apply(p["router"], xt).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                  # renormalize

    # --- aux load-balance loss (Switch-style)
    onehot = jax.nn.one_hot(expert_idx[:, 0], E)                 # top-1 usage
    frac_tokens = onehot.mean(0)
    frac_probs = probs.mean(0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * m.router_aux_weight

    # --- capacity & permutation
    # capacity_factor <= 0 or tiny token counts (decode steps) => dropless:
    # serving must never silently drop routed tokens.
    if m.capacity_factor <= 0 or T * k <= 4 * E:
        cap = T * k
    else:
        cap = int(max(1, round(m.capacity_factor * T * k / E)))
    flat_expert = expert_idx.reshape(-1)                         # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)                # slots by expert
    sorted_expert = flat_expert[order]
    # rank within expert for each sorted slot
    counts = jnp.bincount(flat_expert, length=E)                 # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - starts[sorted_expert]
    keep = rank < cap

    tok_of_slot = order // k                                     # source token
    # dispatch: (E, C, d)
    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[sorted_expert, jnp.where(keep, rank, 0)].add(
        jnp.where(keep[:, None], xt[tok_of_slot], 0.0).astype(x.dtype))

    out_buf = _expert_ffn(p, buf, cfg.activation)                # (E, C, d)

    # combine: gather each kept slot's output back to its token
    slot_out = out_buf[sorted_expert, jnp.where(keep, rank, 0)]
    slot_out = jnp.where(keep[:, None], slot_out, 0.0)
    slot_gate = gate_vals.reshape(-1)[order]
    y = jnp.zeros((T, d), x.dtype).at[tok_of_slot].add(
        (slot_out * slot_gate[:, None]).astype(x.dtype))

    if m.dense_residual:
        y = y + mlp_apply(p["residual"], xt, cfg.activation)
    return y.reshape(B, S, d), aux
