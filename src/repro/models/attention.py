"""Grouped-query attention with RoPE, optional QKV bias, sliding windows,
and a KV-cache decode path.

The jnp implementation here is the XLA reference (and the oracle for the
Pallas kernels in repro.kernels); `impl="pallas"` routes prefill through
`kernels.flash_attention` and single-token decode through
`kernels.decode_attention`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import dense_apply, dense_init
from repro.models.rope import apply_rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray    # (B, S_cache, KV, hd)
    v: jnp.ndarray    # (B, S_cache, KV, hd)
    # ring buffer when window > 0 (S_cache == window), else linear buffer


def attn_init(key, cfg: ModelConfig, dtype):
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    q, q_ax = dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, "embed", "heads",
                         dtype, bias=cfg.qkv_bias)
    k, k_ax = dense_init(ks[1], cfg.d_model, cfg.n_kv * hd, "embed", "kv_heads",
                         dtype, bias=cfg.qkv_bias)
    v, v_ax = dense_init(ks[2], cfg.d_model, cfg.n_kv * hd, "embed", "kv_heads",
                         dtype, bias=cfg.qkv_bias)
    o, o_ax = dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, "heads", "embed",
                         dtype, bias=cfg.out_bias,
                         scale=1.0 / jnp.sqrt(cfg.n_heads * hd) / jnp.sqrt(2 * cfg.n_layers))
    return (
        {"q": q, "k": k, "v": v, "o": o},
        {"q": q_ax, "k": k_ax, "v": v_ax, "o": o_ax},
    )


def _qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dense_apply(p["q"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense_apply(p["k"], x).reshape(B, S, cfg.n_kv, hd)
    v = dense_apply(p["v"], x).reshape(B, S, cfg.n_kv, hd)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q: (B,Sq,H,hd) k/v: (B,Skv,KV,hd) mask: (B,1,1,Sq,Skv) or None.
    GQA via grouped einsum; softmax in f32."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    # cast q down to the K/V dtype (converting the tiny q beats letting the
    # einsum promote the HUGE cache to f32 — XLA would otherwise carry a
    # second f32 copy of the whole cache; EXPERIMENTS.md §Perf/qwen-decode)
    qg = q.reshape(B, Sq, KV, G, hd).astype(k.dtype)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, Sq, H * hd)


def causal_mask(Sq: int, Skv: int, window=0, offset: int = 0):
    """(Sq, Skv) boolean mask; `window` may be a traced scalar (0 = full).
    offset = absolute position of query 0 minus position of key 0."""
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Skv)[None, :]
    m = kj <= qi
    w = jnp.asarray(window)
    return m & ((w <= 0) | (kj > qi - w))


# sequences longer than this use the blocked online-softmax path so the
# (Sq, Skv) score tensor is never materialized (the XLA analogue of flash
# attention; the Pallas kernel is the TPU-native version of the same tiling)
_FLASH_THRESHOLD = 2048
_QBLK = 1024
_KBLK = 1024


def flash_xla(q, k, v, window=0):
    """Blocked causal attention with online softmax, nested lax.scan.

    q: (B,Sq,H,hd) k/v: (B,Skv,KV,hd). Sq/Skv must be block-aligned
    (callers pad).  Returns (B,Sq,H*hd)."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Sq // _QBLK, Skv // _KBLK
    scale = 1.0 / jnp.sqrt(hd)
    qb = jnp.moveaxis(q.reshape(B, nq, _QBLK, KV, G, hd), 1, 0)

    def q_step(_, qblk_i):
        qblk, qi = qblk_i            # (B,QB,KV,G,hd), () block index
        q_off = qi * _QBLK

        def kv_step(carry, kj):
            m_prev, l_prev, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(k, kj * _KBLK, _KBLK, 1)
            vblk = jax.lax.dynamic_slice_in_dim(v, kj * _KBLK, _KBLK, 1)
            s = jnp.einsum("bqkgh,btkh->bkgqt", qblk.astype(kblk.dtype), kblk,
                           preferred_element_type=jnp.float32)
            s = s * scale
            mask = causal_mask(_QBLK, _KBLK, window, q_off - kj * _KBLK)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p_.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p_.astype(vblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, _QBLK), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, _QBLK), jnp.float32)
        a0 = jnp.zeros((B, KV, G, _QBLK, hd), jnp.float32)
        # only blocks at or before the query block contribute under causality
        n_used = nk  # static bound; masked blocks contribute zeros
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(n_used))
        out = acc / jnp.maximum(l_f[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    # outs: (nq, B, KV, G, QB, hd) -> (B, Sq, H*hd)
    outs = jnp.moveaxis(outs, 0, 3)              # (B,KV,G,nq,QB,hd)
    outs = outs.reshape(B, KV, G, Sq, hd)
    outs = jnp.moveaxis(outs.reshape(B, H, Sq, hd), 1, 2)
    return outs.reshape(B, Sq, H * hd)


def attn_prefill(p, cfg: ModelConfig, x, positions, window: int,
                 impl: str = "xla"):
    """Full-sequence causal attention. Returns (out, (k, v)) so serving can
    seed a cache."""
    q, k, v = _qkv(p, cfg, x, positions)
    S = q.shape[1]
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=True, window=window)
        out = out.reshape(*out.shape[:2], -1)
    elif S > _FLASH_THRESHOLD and S % _QBLK == 0:
        out = flash_xla(q, k, v, window)
    else:
        m = causal_mask(q.shape[1], k.shape[1], window)[None, None, None]
        out = _sdpa(q, k, v, m)
    return dense_apply(p["o"], out), (k, v)


def attn_decode(p, cfg: ModelConfig, x, pos, cache: KVCache, window: int,
                impl: str = "xla"):
    """Single-token decode against a cache.

    x: (B, 1, D); pos: () int32 — current absolute position (0-based).
    Linear cache when window == 0 (S_cache >= pos+1); ring buffer when
    window > 0 (S_cache == window; slot = pos % window).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions)

    S_cache = cache.k.shape[1]
    slot = pos % S_cache  # == pos for a linear cache (S_cache > pos)
    # store in the cache dtype: updating with an f32 token would promote
    # the ENTIRE cache to f32 round-trip in HLO (2x decode memory traffic —
    # EXPERIMENTS.md §Perf/qwen-decode iteration 2)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), slot, axis=1)

    # Unified ring/linear validity: slot s holds absolute position
    # p(s) = pos - ((pos - s) mod S_cache)  (the latest p <= pos congruent
    # to s).  Valid iff written (p >= 0) and within the window when one is
    # set.  Works for ring (S_cache == window), linear (S_cache >= seq),
    # and linear-buffer-with-window (hybrid layers sharing one buffer).
    idx = jnp.arange(S_cache)
    p_abs = pos - jnp.mod(pos - idx, S_cache)
    w = jnp.asarray(window)
    valid = (p_abs >= 0) & ((w <= 0) | (p_abs > pos - w))
    if impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q[:, 0], k, v, valid)
        out = out.reshape(B, 1, -1)
    else:
        mask = valid[None, None, None, None, :]
        out = _sdpa(q, k, v, mask)
    return dense_apply(p["o"], out), KVCache(k, v)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, window: int,
               dtype) -> KVCache:
    S = min(window, max_seq) if window > 0 else max_seq
    shape = (batch, S, cfg.n_kv, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# logical axes for a cache (consumed by the serving layer's shardings)
CACHE_AXES = KVCache(
    k=("cache_batch", "cache_seq", "kv_heads", None),
    v=("cache_batch", "cache_seq", "kv_heads", None),
)
