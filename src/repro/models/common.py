"""Functional parameter utilities (flax-free).

Every module exposes `init(key, cfg, ...) -> (params, axes)` where
`params` is a nested dict of jnp arrays and `axes` is a structurally
identical dict whose leaves are tuples of logical axis names consumed by
repro.sharding.rules.  Layer stacks are built with `jax.vmap` over init
keys, giving scan-compatible stacked leaves with a leading 'layers' axis.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Tree = Any


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def dense_init(key, in_dim: int, out_dim: int, in_ax: str, out_ax: str,
               dtype, bias: bool = False, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * jnp.asarray(scale, dtype)
    params = {"w": w}
    axes = {"w": (in_ax, out_ax)}
    if bias:
        params["b"] = jnp.zeros((out_dim,), dtype)
        axes["b"] = (out_ax,)
    return params, axes


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def stack_inits(init_fn, key, n: int):
    """vmap a per-layer init over n keys; prepend 'layers' to every axes
    tuple. init_fn must be key -> (params, axes)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)
    axes = jax.tree.map(
        lambda a: ("layers",) + a,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
    return params, axes


def is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def count_params(params: Tree) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
