"""Rotary position embeddings with partial-rotary support (StableLM uses
rotary on 25% of head dim) and sinusoidal absolute embeddings (MusicGen)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot_dim = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, fraction: float = 1.0, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: (..., S) int32 absolute positions."""
    hd = x.shape[-1]
    inv, rot_dim = rope_freqs(hd, fraction, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot_dim].astype(jnp.float32)
    xp = x[..., rot_dim:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1)


def sinusoidal_embed(positions, d_model: int, dtype=jnp.float32):
    """Absolute sinusoidal position embeddings (MusicGen-style)."""
    half = d_model // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
