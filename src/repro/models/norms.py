"""RMSNorm / LayerNorm, computed in float32 regardless of param dtype."""
from __future__ import annotations

import jax.numpy as jnp


def norm_init(d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}
    if kind == "layernorm":
        return (
            {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)},
        )
    raise ValueError(kind)


def norm_apply(p, x, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf / jnp.sqrt(ms + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) / jnp.sqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    raise ValueError(kind)
