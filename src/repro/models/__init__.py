"""Functional model zoo (dense / MoE / SSM / hybrid / VLM / audio)."""
from repro.models.model import (  # noqa: F401
    Model,
    cache_axes,
    decode_step,
    forward_train,
    init_caches,
    init_model,
    lm_loss,
    prefill,
)
