from repro.sharding.rules import (  # noqa: F401
    DEFAULT_ACT_RULES,
    DEFAULT_PARAM_RULES,
    logical_to_sharding,
    spec_for,
)
