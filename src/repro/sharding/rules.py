"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter leaf carries a tuple of *logical* axis names (built by the
model init functions alongside the arrays).  `logical_to_sharding` maps
those to `NamedSharding`s for a concrete mesh, automatically dropping any
rule whose dimension does not divide the mesh axis size (e.g. InternVL2's
14 heads on a 16-way tensor axis) — the hardware-adaptation behavior
documented in DESIGN.md §4.

Param logical axes:
  layers                  scan-stacked layer axis, never sharded
  embed                   d_model on params      -> FSDP axes (pod, data)
  vocab / heads / kv_heads / q_heads / mlp / experts / ssm_inner
                          parallel dims          -> tensor axis (model)
  none                    replicated small dims

Activation logical axes:
  batch -> (pod, data)    seq -> None (train/prefill)
  cache_batch -> data     cache_seq -> model (decode; see serving/cache.py)
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (tuple = joint sharding over several mesh axes)
DEFAULT_PARAM_RULES: dict[str, Any] = {
    "layers": None,
    "embed": ("pod", "data"),       # FSDP / ZeRO-3 over the data axes
    "vocab": "model",
    "heads": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "ssm_inner": "model",
    "none": None,
}

DEFAULT_ACT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "cache_batch": "data",
    "cache_seq": "model",
    "none": None,
}


def _mesh_axes_present(mesh: Mesh, axes) -> Optional[Any]:
    """Restrict a rule to axes that exist in this mesh."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    present = tuple(a for a in axes if a in mesh.axis_names)
    return present if present else None


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def spec_for(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, Any] | None = None,
) -> P:
    """PartitionSpec for one array, dropping non-divisible rules."""
    rules = rules or DEFAULT_PARAM_RULES
    spec = []
    used: set[str] = set()
    for dim, name in zip(shape, logical_axes):
        axes = _mesh_axes_present(mesh, rules.get(name or "none"))
        if axes is not None:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            if any(a in used for a in flat):
                axes = None  # a mesh axis may appear once per spec
        if axes is not None and dim % _axis_size(mesh, axes) != 0:
            axes = None  # non-divisible: replicate instead (adaptation)
        if axes is not None:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            used.update(flat)
        spec.append(axes)
    return P(*spec)


def constrain(x, *logical):
    """`with_sharding_constraint` by logical activation-axis names.

    No-op outside a mesh context, so model code can call it
    unconditionally (CPU tests / single-device runs are unaffected).
    Used at GSPMD propagation weak points — after the embedding gather
    (a gather from a vocab-sharded table loses the batch sharding) and
    before the LM head (§Perf/internvl2-train iteration 2)."""
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - private API moved
        return x
    if mesh.empty:
        return x
    spec = spec_for(logical, x.shape, mesh, DEFAULT_ACT_RULES)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def logical_to_sharding(
    axes_tree: Any,
    params_or_shapes: Any,
    mesh: Mesh,
    rules: Mapping[str, Any] | None = None,
) -> Any:
    """Map a tree of logical-axes tuples + arrays/ShapeDtypeStructs to a
    matching tree of NamedShardings."""

    def one(axes, arr):
        return NamedSharding(mesh, spec_for(axes, arr.shape, mesh, rules))

    return jax.tree.map(
        one, axes_tree, params_or_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
