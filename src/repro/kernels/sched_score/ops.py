"""Public jit'd wrapper: interpret=True on CPU, compiled on TPU.

Pads the queue axis to a lane-aligned block multiple (mask=False
padding) so callers can hand in any N — e.g. the 10^5-deep queues of
the batch-dispatch benchmark — while the kernel always sees TPU-tileable
block shapes.  Padding is shape-static, so jit specializes once per
(N, blk).
"""
import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.sched_score.sched_score import (
    sched_compact_topb as _compact_topb_kernel,
    sched_score_argmax as _argmax_kernel,
    sched_score_topb as _topb_kernel,
)

_LANE = 128  # TPU lane width: block shapes must stay a multiple of this


def _pad_queue(wait, cost, urgency, mask, blk: int, route=None):
    """Pad the queue axis to a block multiple with inert lanes
    (mask=False, unit cost, zero route).  Padding is shape-static, so
    jit specializes once per (n, blk)."""
    n = wait.shape[0]
    # shrink the block for short queues without losing lane alignment
    blk = min(blk, max(_LANE, -(-n // _LANE) * _LANE))
    pad = (-n) % blk
    if pad:
        zf = jnp.zeros((pad,), wait.dtype)
        wait = jnp.concatenate([wait, zf])
        cost = jnp.concatenate([cost, jnp.ones((pad,), cost.dtype)])
        urgency = jnp.concatenate([urgency, zf])
        mask = jnp.concatenate([mask, jnp.zeros((pad,), bool)])
        if route is not None:
            route = jnp.concatenate([route, jnp.zeros((pad,), route.dtype)])
    return wait, cost, urgency, mask, route, blk


def sched_score_argmax(wait, cost, urgency, mask, weights, route=None, *,
                       blk: int = 2048):
    """wait/cost/urgency: (n,) f32; mask: (n,) bool; weights: (4,)
    [w_wait, w_size, w_urg, ref_tokens]. Returns (best_idx i32, best_score).
    Any n is accepted — the queue is padded internally to a lane-aligned
    block multiple with mask=False lanes.  `route` (n,) f32 enables the
    fleet route term with a (5,) weights vector [..., w_route]."""
    wait, cost, urgency, mask, route, blk = _pad_queue(
        wait, cost, urgency, mask, blk, route)
    return _argmax_kernel(wait, cost, urgency, mask, weights, route, blk=blk,
                          interpret=interpret_mode())


def sched_score_topb(wait, cost, urgency, mask, weights, b: int, route=None,
                     *, blk: int = 2048):
    """Fused score + partial top-B over a queue of any length n >= b.

    Returns (idx (b,) i32, score (b,) f32) in release order, matching
    `lax.top_k` over the masked scores including first-occurrence
    tie-breaking.  Padding lanes are mask=False: their NEG scores rank
    after every real lane's (real masked lanes share the NEG value but
    precede the padding in index order), so with b <= n a padded index
    can never reach the output.  `route` (n,) f32 enables the fleet
    route term with a (5,) weights vector [..., w_route].
    """
    n = wait.shape[0]
    b = min(int(b), n)
    wait, cost, urgency, mask, route, blk = _pad_queue(
        wait, cost, urgency, mask, blk, route)
    return _topb_kernel(wait, cost, urgency, mask, weights, route, b=b,
                        blk=blk, interpret=interpret_mode())


def sched_compact_topb(slot_req, alive, wait, cost, urgency, weights, b: int,
                       route=None, *, blk: int = 128,
                       interpret: bool | None = None):
    """Fused tick megakernel: compaction scatter + score + partial top-B
    in one VMEM pass over a slot pool of any width w >= 1.

    slot_req: (w,) int request ids (slot order, pre-compaction); alive:
    (w,) bool survivors; wait/cost/urgency: (w,) f32 score features in
    the same slot order; weights: (4,).  Returns (compacted (w,) i32
    with -1 tail sentinels, n_live () i32, idx (b,) i32 in compacted
    coordinates, score (b,) f32) — bit-exact with the two-pass path
    (XLA cumsum-scatter compaction, then `sched_score_topb` over the
    compacted pool), including first-occurrence ties and the exhausted
    region when b exceeds the live count.  Padding lanes are
    alive=False at the tail: they never shift compacted positions and
    rank with the other dead slots, which the exhausted-region rule
    replaces with (rank, NEG) sentinels either way.  `route` (w,) f32
    enables the fleet route term with a (5,) weights vector
    [..., w_route]."""
    w = slot_req.shape[0]
    b = min(int(b), w)
    wait, cost, urgency, alive, route, blk = _pad_queue(
        wait, cost, urgency, alive, blk, route)
    pad = wait.shape[0] - w
    if pad:
        slot_req = jnp.concatenate(
            [slot_req.astype(jnp.int32), jnp.full((pad,), -1, jnp.int32)])
    interp = interpret_mode() if interpret is None else interpret
    comp, n_live, idx, score = _compact_topb_kernel(
        slot_req, alive, wait, cost, urgency, weights, route, b=b, blk=blk,
        interpret=interp)
    return comp[:w], n_live, idx, score
