"""Public jit'd wrapper: interpret=True on CPU, compiled on TPU."""
import functools

from repro.kernels import interpret_mode
from repro.kernels.sched_score.sched_score import (
    sched_score_argmax as _kernel_call,
)


@functools.wraps(_kernel_call)
def sched_score_argmax(wait, cost, urgency, mask, weights, *, blk: int = 2048):
    return _kernel_call(wait, cost, urgency, mask, weights, blk=blk,
                        interpret=interpret_mode())
