"""Pallas ordering kernels — the scheduler's production score backend.

Three fused kernels over a `(nf, N)` feature matrix (rows: wait, cost,
urgency[, route]; the eligibility mask is always the LAST row) and an
`(nf + 1,)` weight vector:

* `sched_score_argmax` — scores every candidate and returns the
  (score, index) of the best eligible one in a single pass.
* `sched_score_topb` — the top-B scores/indices for batched dispatch.
* `sched_compact_topb` — fused gather-compact + top-B over a windowed
  `(W,)` slot pool: one kernel from slot pool to ranked grants.

The optional fourth feature row is the fleet route cost (DESIGN.md
§10); `has_route` is trace-static, so the four-row program compiled
for single-provider runs is untouched when routing is off.

Contract (RPL005, enforced by reprolint + tests/test_kernels.py):
every kernel has a jnp oracle in `ref.py` that must match
**bit-exactly**, not approximately — score floats and tie-breaking
index order both. The oracles are jitted so both sides share XLA's
instruction selection (see ref.py's docstring for why eager oracles
drift by one ulp). Import surface: `ops` picks the backend
(Pallas on accelerators, interpret mode on CPU), `ref` holds the
oracles.
"""
from repro.kernels.sched_score import ops, ref  # noqa: F401
