from repro.kernels.sched_score import ops, ref  # noqa: F401
