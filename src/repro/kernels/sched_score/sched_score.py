"""Ordering-layer scoring kernel (the paper's §3.1.2 hot spot at
production queue depths).

Fuses the feasible-set score

    score = w1 * (wait / cost) - w2 * (cost / ref) + w3 * urgency

with the masked argmax reduction in a single VMEM pass over the queue —
at 10^5+ pending requests the jnp version materializes the score vector
in HBM and reads it back for the argmax; the fused kernel streams each
block once.  Grid = (num_blocks,) with the running (best_score, best_idx)
pair in scratch, written out on the last block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(arr_ref, w_ref, out_idx_ref, out_score_ref, best_ref, *,
            blk: int, nb: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        best_ref[0, 0] = NEG
        best_ref[0, 1] = -1.0

    wait = arr_ref[0, :]
    cost = arr_ref[1, :]
    urg = arr_ref[2, :]
    mask = arr_ref[3, :]
    w1, w2, w3, ref_tok = w_ref[0, 0], w_ref[0, 1], w_ref[0, 2], w_ref[0, 3]

    c = jnp.maximum(cost, 1.0)
    score = w1 * (wait / c) - w2 * (c / ref_tok) + w3 * urg
    score = jnp.where(mask > 0, score, NEG)

    j = jnp.argmax(score)
    s = score[j]
    prev_s = best_ref[0, 0]
    take = s > prev_s
    best_ref[0, 0] = jnp.where(take, s, prev_s)
    best_ref[0, 1] = jnp.where(
        take, (bi * blk + j).astype(jnp.float32), best_ref[0, 1])

    @pl.when(bi == nb - 1)
    def _finish():
        out_idx_ref[0] = best_ref[0, 1].astype(jnp.int32)
        out_score_ref[0] = best_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def sched_score_argmax(wait, cost, urgency, mask, weights, *,
                       blk: int = 2048, interpret: bool = False):
    """wait/cost/urgency: (n,) f32; mask: (n,) bool; weights: (4,)
    [w_wait, w_size, w_urg, ref_tokens]. Returns (best_idx i32, best_score).
    n must be a multiple of blk (callers pad with mask=False)."""
    n = wait.shape[0]
    blk = min(blk, n)
    assert n % blk == 0, "pad the queue to a block multiple"
    nb = n // blk
    arr = jnp.stack([wait, cost, urgency, mask.astype(jnp.float32)])  # (4, n)
    w = weights.astype(jnp.float32)[None, :]                          # (1, 4)

    kernel = functools.partial(_kernel, blk=blk, nb=nb)
    idx, score = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((4, blk), lambda b: (0, b)),
            pl.BlockSpec((1, 4), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 2), jnp.float32)],
        interpret=interpret,
    )(arr, w)
    return idx[0], score[0]
