"""Ordering-layer scoring kernels (the paper's §3.1.2 hot spot at
production queue depths).

`sched_score_argmax` fuses the feasible-set score

    score = w1 * (wait / cost) - w2 * (cost / ref) + w3 * urgency

with the masked argmax reduction in a single VMEM pass over the queue —
at 10^5+ pending requests the jnp version materializes the score vector
in HBM and reads it back for the argmax; the fused kernel streams each
block once.  Grid = (num_blocks,) with the running (best_score, best_idx)
pair in scratch, written out on the last block.

`sched_score_topb` generalizes it to a fused partial top-B: one tiled
pass computes each block's scores in VMEM, extracts the block's local
top-B by B successive masked argmaxes, and tree-combines into a running
best-B scratch set (a strict replace-worst merge).  The combine is
associative with the blocks processed in index order, and the strict
(`>` only) eviction rule makes ties resolve to the earliest index —
bit-identical to `lax.top_k`'s first-occurrence semantics, which the
windowed scheduler's bit-exact contract relies on.  The final block
selection-sorts the scratch set into (idx, score) rows, best first.
Compared with `lax.top_k` over the full (K, N) score matrix this
streams each element once and keeps only O(B) state.

`sched_compact_topb` is the tick megakernel: it fuses the windowed
engine's per-tick compaction scatter with the score + partial top-B
ranking in a single Pallas pass, so the slot pool is read from HBM
once per tick instead of once for the XLA cumsum-scatter and again for
the ranking kernel.  The compaction is expressed as a (blk, W) masked
max-reduction per output block (exact: a stable compaction routes at
most one live slot to each output lane, dead lanes contribute the -1
sentinel), and the scores are computed on the *uncompacted* features —
compaction only permutes values, so scoring before or after it is the
same arithmetic, and the stable order means slot-order ties are
compacted-order ties.  Ranks at or beyond the live count are
overwritten with (rank, NEG) sentinel rows, matching `lax.top_k` over
the compacted sentinel tail bit for bit.

Fleet route term (DESIGN.md §10): every kernel optionally takes a fifth
feature row `route` (per-request predicted queue delay at its best
endpoint, seconds) and a fifth weight `w_route`, subtracting
`w_route * route` from the score.  Presence is static (`has_route`),
so single-provider callers compile the exact four-row program; the
feature axis is the sublane (second-to-last) dimension, so growing it
4 -> 5 leaves the lane-aligned minor axis untouched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _score_rows(arr_ref, w_ref, has_route: bool):
    """Shared score evaluation: feature rows [wait, cost, urg(, route),
    mask] against weights [w1, w2, w3, ref_tok(, w_route)].  The route
    term is subtracted — a congested best endpoint ranks the request
    later.  `has_route` is trace-static, so the four-row program is
    unchanged byte for byte when off."""
    wait = arr_ref[0, :]
    cost = arr_ref[1, :]
    urg = arr_ref[2, :]
    mask = arr_ref[4 if has_route else 3, :]
    w1, w2, w3, ref_tok = w_ref[0, 0], w_ref[0, 1], w_ref[0, 2], w_ref[0, 3]

    c = jnp.maximum(cost, 1.0)
    score = w1 * (wait / c) - w2 * (c / ref_tok) + w3 * urg
    if has_route:
        score = score - w_ref[0, 4] * arr_ref[3, :]
    return score, mask


def _kernel(arr_ref, w_ref, out_idx_ref, out_score_ref, best_ref, *,
            blk: int, nb: int, has_route: bool):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        best_ref[0, 0] = NEG
        best_ref[0, 1] = -1.0

    score, mask = _score_rows(arr_ref, w_ref, has_route)
    score = jnp.where(mask > 0, score, NEG)

    j = jnp.argmax(score)
    s = score[j]
    prev_s = best_ref[0, 0]
    take = s > prev_s
    best_ref[0, 0] = jnp.where(take, s, prev_s)
    best_ref[0, 1] = jnp.where(
        take, (bi * blk + j).astype(jnp.float32), best_ref[0, 1])

    @pl.when(bi == nb - 1)
    def _finish():
        out_idx_ref[0] = best_ref[0, 1].astype(jnp.int32)
        out_score_ref[0] = best_ref[0, 0]


def _stack_features(wait, cost, urgency, mask, route):
    """(rows, n) feature stack: [wait, cost, urg(, route), mask].  The
    mask row stays last so `has_route` only inserts, never reorders."""
    rows = [wait, cost, urgency]
    if route is not None:
        rows.append(route)
    rows.append(mask.astype(jnp.float32))
    return jnp.stack(rows)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def sched_score_argmax(wait, cost, urgency, mask, weights, route=None, *,
                       blk: int = 2048, interpret: bool = False):
    """wait/cost/urgency: (n,) f32; mask: (n,) bool; weights: (4,)
    [w_wait, w_size, w_urg, ref_tokens]. Returns (best_idx i32, best_score).
    n must be a multiple of blk (callers pad with mask=False).
    `route` (n,) f32 enables the fleet route term with a (5,) weights
    vector [..., w_route]."""
    n = wait.shape[0]
    blk = min(blk, n)
    assert n % blk == 0, "pad the queue to a block multiple"
    nb = n // blk
    has_route = route is not None
    nf = 5 if has_route else 4
    arr = _stack_features(wait, cost, urgency, mask, route)  # (nf, n)
    w = weights.astype(jnp.float32)[None, :]                 # (1, nf)

    kernel = functools.partial(_kernel, blk=blk, nb=nb, has_route=has_route)
    idx, score = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((nf, blk), lambda b: (0, b)),
            # (1, nf) weight vector: parameter block, Mosaic pads the
            # tail lanes; not an accumulator tile (nf is the sublane-
            # padded feature count, never the lane axis)
            pl.BlockSpec((1, nf), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        # full (1, 128) lane even though only lanes 0-1 carry state:
        # a 2-wide minor axis forces Mosaic to pad the tile anyway, and
        # the explicit width keeps the scratch lane-aligned (RPL005)
        scratch_shapes=[pltpu.VMEM((1, 128), jnp.float32)],
        interpret=interpret,
    )(arr, w)
    return idx[0], score[0]


# ---------------------------------------------------------------------------
# Fused partial top-B
# ---------------------------------------------------------------------------

_BPAD = 128  # scratch lane width; entries >= b are inert (+inf/-inf guards)


def _topb_kernel(arr_ref, w_ref, out_idx_ref, out_score_ref,
                 best_s_ref, best_i_ref, *, blk: int, nb: int, b: int,
                 has_route: bool):
    bi = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, _BPAD), 1)
    in_set = lane < b

    @pl.when(bi == 0)
    def _init():
        # -inf sentinels rank below every candidate (masked lanes carry
        # the finite NEG), so real entries always displace them first
        best_s_ref[...] = jnp.full((1, _BPAD), -jnp.inf, jnp.float32)
        best_i_ref[...] = jnp.full((1, _BPAD), -1, jnp.int32)

    score, mask = _score_rows(arr_ref, w_ref, has_route)
    score = jnp.where(mask > 0, score, NEG)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)[0]

    # local top-B by successive masked argmax (first occurrence), merged
    # into the running set one candidate at a time.  Candidates arrive in
    # (score desc, idx asc) order and blocks run in index order, so a
    # candidate that merely *ties* the running worst is always the later
    # index — the strict `>` eviction below is exactly top_k's
    # first-occurrence tie-breaking.
    for _ in range(b):
        s = jnp.max(score)
        jj = jnp.argmax(score).astype(jnp.int32)
        gidx = bi * blk + jj
        score = jnp.where(iota == jj, -jnp.inf, score)

        cur = jnp.where(in_set, best_s_ref[...], jnp.inf)
        worst = jnp.min(cur)
        # evict the worst entry; among equal-score entries the one with
        # the LARGEST index (it ranks last under first-occurrence order).
        # Resolve to a single lane: -1 sentinels are not unique, so an
        # index match alone could hit several lanes at once.
        evict_i = jnp.max(jnp.where(cur == worst, best_i_ref[...], -2))
        cand = in_set & (cur == worst) & (best_i_ref[...] == evict_i)
        hit = lane == jnp.max(jnp.where(cand, lane, -1))
        take = s > worst
        best_s_ref[...] = jnp.where(hit & take, s, best_s_ref[...])
        best_i_ref[...] = jnp.where(hit & take, gidx, best_i_ref[...])

    @pl.when(bi == nb - 1)
    def _finish():
        # selection-sort the set into release order: score desc, ties by
        # ascending index (first occurrence) — lax.top_k's output order
        rem_s = best_s_ref[...]
        rem_i = best_i_ref[...]
        big = jnp.int32(2**31 - 1)
        for j in range(b):
            cur = jnp.where(in_set, rem_s, -jnp.inf)
            m = jnp.max(cur)
            sel = jnp.min(jnp.where(cur == m, rem_i, big))
            out_idx_ref[j] = sel
            out_score_ref[j] = m
            used = (cur == m) & (rem_i == sel)
            rem_s = jnp.where(used, -jnp.inf, rem_s)


# ---------------------------------------------------------------------------
# Fused compaction + score + partial top-B (the tick megakernel)
# ---------------------------------------------------------------------------


def _compact_topb_kernel(req_ref, arr_ref, w_ref, out_req_ref, out_n_ref,
                         out_idx_ref, out_score_ref, best_s_ref, best_i_ref,
                         *, blk: int, nb: int, b: int, w_total: int,
                         has_route: bool):
    """One grid step = one compacted output block.

    Every step sees the full (W,) pool in VMEM (the window is capped at
    a few thousand slots): it rebuilds the alive-prefix positions,
    scatters its own compacted block via a masked (blk, W) reduction —
    each output lane receives exactly one survivor or the -1 sentinel,
    so the max-combine is exact — scores its slot block in place, and
    merges the block's local top-B into the running scratch set with
    the same strict-eviction rule as `_topb_kernel`.  Candidate merge
    order is ascending slot index; the final step translates winners
    into compacted coordinates (compaction is stable, so slot order and
    compacted order agree and first-occurrence ties carry over)."""
    bi = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, _BPAD), 1)
    in_set = lane < b

    @pl.when(bi == 0)
    def _init():
        best_s_ref[...] = jnp.full((1, _BPAD), -jnp.inf, jnp.float32)
        best_i_ref[...] = jnp.full((1, _BPAD), -1, jnp.int32)

    alive = arr_ref[4 if has_route else 3, :] > 0.0   # (W,)
    req = req_ref[0, :]                               # (W,) i32
    cum = jnp.cumsum(alive.astype(jnp.int32))         # (W,) inclusive
    pos = cum - 1                                     # compacted slot of i
    n_live = cum[w_total - 1]
    lane_w = jax.lax.broadcasted_iota(jnp.int32, (1, w_total), 1)[0]

    # --- compaction scatter for this output block: out[j] = req[i] where
    # pos[i] == j & alive[i] (at most one i per j), else the -1 sentinel
    jg = bi * blk + jax.lax.broadcasted_iota(
        jnp.int32, (blk, w_total), 0)                 # (blk, W) target rows
    hit = alive[None, :] & (pos[None, :] == jg)
    out_req_ref[...] = jnp.max(jnp.where(hit, req[None, :], -1), axis=1)

    # --- this block's slot scores (features are pre-compaction: the
    # scatter only permutes values, so scoring before or after compaction
    # is the same arithmetic on the same f32 values)
    score, _ = _score_rows(arr_ref, w_ref, has_route)
    in_blk = (lane_w >= bi * blk) & (lane_w < (bi + 1) * blk)
    # dead slots carry the finite NEG (they may fill the exhausted region,
    # overwritten below); out-of-block lanes are -inf: not candidates here
    score = jnp.where(in_blk & alive, score, jnp.where(in_blk, NEG, -jnp.inf))

    for _ in range(b):
        s = jnp.max(score)
        jj = jnp.argmax(score).astype(jnp.int32)      # global slot index
        score = jnp.where(lane_w == jj, -jnp.inf, score)

        cur = jnp.where(in_set, best_s_ref[...], jnp.inf)
        worst = jnp.min(cur)
        evict_i = jnp.max(jnp.where(cur == worst, best_i_ref[...], -2))
        cand = in_set & (cur == worst) & (best_i_ref[...] == evict_i)
        hit_l = lane == jnp.max(jnp.where(cand, lane, -1))
        take = s > worst
        best_s_ref[...] = jnp.where(hit_l & take, s, best_s_ref[...])
        best_i_ref[...] = jnp.where(hit_l & take, jj, best_i_ref[...])

    @pl.when(bi == nb - 1)
    def _finish():
        rem_s = best_s_ref[...]
        rem_i = best_i_ref[...]
        big = jnp.int32(2**31 - 1)
        for r in range(b):
            cur = jnp.where(in_set, rem_s, -jnp.inf)
            m = jnp.max(cur)
            sel = jnp.min(jnp.where(cur == m, rem_i, big))
            # slot -> compacted coordinates (masked reduction: a dynamic
            # scalar gather would not lower on all targets)
            csel = jnp.max(jnp.where(lane_w == sel, pos, -1))
            # the exhausted region (rank >= n_live) mirrors top_k over the
            # compacted pool: the sentinel tail ties at NEG, so rank r
            # resolves to compacted index r exactly
            exhausted = r >= n_live
            out_idx_ref[r] = jnp.where(exhausted, r, csel)
            out_score_ref[r] = jnp.where(exhausted, NEG, m)
            used = (cur == m) & (rem_i == sel)
            rem_s = jnp.where(used, -jnp.inf, rem_s)
        out_n_ref[0] = n_live


@functools.partial(jax.jit, static_argnames=("b", "blk", "interpret"))
def sched_compact_topb(slot_req, alive, wait, cost, urgency, weights,
                       route=None, *,
                       b: int, blk: int = 128, interpret: bool = False):
    """Fused compaction scatter + score + partial top-B over a slot pool.

    slot_req: (w,) i32 request ids; alive: (w,) bool survivors;
    wait/cost/urgency: (w,) f32 per-slot score features (slot order,
    pre-compaction); weights: (4,) [w_wait, w_size, w_urg, ref_tokens].

    Returns (compacted (w,) i32 with -1 tail sentinels, n_live () i32,
    idx (b,) i32 in *compacted* coordinates, score (b,) f32), bit-exact
    with running the XLA cumsum-scatter compaction followed by
    `sched_score_topb` over the compacted pool (mask = index < n_live):
    stable compaction preserves first-occurrence tie order, and the
    exhausted region (rank >= n_live) yields (rank, NEG) exactly like
    `lax.top_k` over the sentinel tail.  w must be a multiple of blk
    (callers pad with alive=False); requires b <= min(w, _BPAD).
    `route` (w,) f32 enables the fleet route term with a (5,) weights
    vector [..., w_route]."""
    w = slot_req.shape[0]
    blk = min(blk, w)
    assert w % blk == 0, "pad the pool to a block multiple"
    assert 0 < b <= min(w, _BPAD), (b, w)
    nb = w // blk
    has_route = route is not None
    nf = 5 if has_route else 4
    req = slot_req.astype(jnp.int32)[None, :]                 # (1, w)
    arr = _stack_features(wait, cost, urgency, alive, route)  # (nf, w)
    wts = weights.astype(jnp.float32)[None, :]                # (1, nf)

    kernel = functools.partial(
        _compact_topb_kernel, blk=blk, nb=nb, b=b, w_total=w,
        has_route=has_route)
    comp, n_live, idx, score = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, w), lambda g: (0, 0)),
            pl.BlockSpec((nf, w), lambda g: (0, 0)),
            # (1, nf) weight vector: parameter block, padded by Mosaic
            pl.BlockSpec((1, nf), lambda g: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda g: (g,)),
            pl.BlockSpec((1,), lambda g: (0,)),
            pl.BlockSpec((b,), lambda g: (0,)),
            pl.BlockSpec((b,), lambda g: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, _BPAD), jnp.float32),
            pltpu.VMEM((1, _BPAD), jnp.int32),
        ],
        interpret=interpret,
    )(req, arr, wts)
    return comp, n_live[0], idx, score


@functools.partial(jax.jit, static_argnames=("b", "blk", "interpret"))
def sched_score_topb(wait, cost, urgency, mask, weights, route=None, *,
                     b: int, blk: int = 2048, interpret: bool = False):
    """Fused score + partial top-B.  wait/cost/urgency: (n,) f32; mask:
    (n,) bool; weights: (4,) [w_wait, w_size, w_urg, ref_tokens].
    Returns (idx (b,) i32, score (b,) f32) in release order (best
    first), matching `lax.top_k` over the masked score vector including
    first-occurrence tie-breaking.  n must be a multiple of blk (callers
    pad with mask=False); requires b <= min(blk, _BPAD) and b <= n so
    sentinels can never reach the output.  `route` (n,) f32 enables the
    fleet route term with a (5,) weights vector [..., w_route]."""
    n = wait.shape[0]
    blk = min(blk, n)
    assert n % blk == 0, "pad the queue to a block multiple"
    assert 0 < b <= min(blk, _BPAD) and b <= n, (b, blk, n)
    nb = n // blk
    has_route = route is not None
    nf = 5 if has_route else 4
    arr = _stack_features(wait, cost, urgency, mask, route)  # (nf, n)
    w = weights.astype(jnp.float32)[None, :]                 # (1, nf)

    kernel = functools.partial(_topb_kernel, blk=blk, nb=nb, b=b,
                               has_route=has_route)
    idx, score = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((nf, blk), lambda g: (0, g)),
            # (1, nf) weight vector: parameter block, padded by Mosaic
            pl.BlockSpec((1, nf), lambda g: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda g: (0,)),
            pl.BlockSpec((b,), lambda g: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, _BPAD), jnp.float32),
            pltpu.VMEM((1, _BPAD), jnp.int32),
        ],
        interpret=interpret,
    )(arr, w)
    return idx, score
