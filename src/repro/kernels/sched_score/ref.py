"""Pure-jnp oracle for the fused scheduler scoring kernel."""
import jax.numpy as jnp

NEG = -1e30


def sched_score_argmax_ref(wait, cost, urgency, mask, weights):
    w1, w2, w3, ref_tok = weights
    c = jnp.maximum(cost, 1.0)
    score = w1 * (wait / c) - w2 * (c / ref_tok) + w3 * urgency
    score = jnp.where(mask, score, NEG)
    i = jnp.argmax(score)
    return i.astype(jnp.int32), score[i]
