"""Pure-jnp oracles for the fused scheduler scoring kernels."""
import jax
import jax.numpy as jnp

NEG = -1e30


def _scores(wait, cost, urgency, mask, weights):
    w1, w2, w3, ref_tok = weights
    c = jnp.maximum(cost, 1.0)
    score = w1 * (wait / c) - w2 * (c / ref_tok) + w3 * urgency
    return jnp.where(mask, score, NEG)


def sched_score_argmax_ref(wait, cost, urgency, mask, weights):
    score = _scores(wait, cost, urgency, mask, weights)
    i = jnp.argmax(score)
    return i.astype(jnp.int32), score[i]


def sched_score_topb_ref(wait, cost, urgency, mask, weights, b: int):
    """Full-width ranking oracle: `lax.top_k` over the masked scores
    (first-occurrence tie-breaking).  Returns (idx (b,), score (b,))."""
    score = _scores(wait, cost, urgency, mask, weights)
    vals, idx = jax.lax.top_k(score, b)
    return idx.astype(jnp.int32), vals
