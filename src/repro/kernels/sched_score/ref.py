"""Pure-jnp oracles for the fused scheduler scoring kernels.

Every oracle mirrors its kernel's optional fleet route term: pass
`route` (per-request predicted queue delay, seconds) with a (5,)
weights vector [w_wait, w_size, w_urg, ref_tokens, w_route] and the
score subtracts `w_route * route`; omit it and the four-weight program
is unchanged.

The oracles are jitted: the kernels they certify are jitted wrappers,
and exact-equality parity requires both sides to see the same XLA:CPU
instruction selection.  The five-term score ends in `score - w * route`,
which XLA contracts to a single-rounded FMA under jit but not in eager
per-op dispatch (`lax.optimization_barrier` is stripped by the
optimizer, so pinning cannot force the eager shape) — an eager oracle
would sit one ulp off the kernel on ~a quarter of random inputs.
"""
import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def _scores(wait, cost, urgency, mask, weights, route=None):
    w1, w2, w3, ref_tok = weights[0], weights[1], weights[2], weights[3]
    c = jnp.maximum(cost, 1.0)
    score = w1 * (wait / c) - w2 * (c / ref_tok) + w3 * urgency
    if route is not None:
        score = score - weights[4] * route
    return jnp.where(mask, score, NEG)


@jax.jit
def sched_score_argmax_ref(wait, cost, urgency, mask, weights, route=None):
    score = _scores(wait, cost, urgency, mask, weights, route)
    i = jnp.argmax(score)
    return i.astype(jnp.int32), score[i]


@functools.partial(jax.jit, static_argnames=("b",))
def sched_score_topb_ref(wait, cost, urgency, mask, weights, b: int,
                         route=None):
    """Full-width ranking oracle: `lax.top_k` over the masked scores
    (first-occurrence tie-breaking).  Returns (idx (b,), score (b,))."""
    score = _scores(wait, cost, urgency, mask, weights, route)
    vals, idx = jax.lax.top_k(score, b)
    return idx.astype(jnp.int32), vals


@functools.partial(jax.jit, static_argnames=("b",))
def sched_compact_topb_ref(slot_req, alive, wait, cost, urgency, weights,
                           b: int, route=None):
    """Two-pass oracle for the fused tick megakernel: the engine's XLA
    cumsum-scatter compaction (stable, -1 tail sentinels) followed by
    the top-B ranking over the *compacted* pool with mask = index <
    n_live.  Returns (compacted (w,) i32, n_live () i32, idx (b,) i32
    in compacted coordinates, score (b,) f32)."""
    w = slot_req.shape[0]
    pos = jnp.cumsum(alive.astype(jnp.int32)) - 1
    target = jnp.where(alive, pos, w)
    creq = jnp.full((w,), -1, jnp.int32).at[target].set(
        slot_req.astype(jnp.int32), mode="drop")
    cwait = jnp.zeros((w,), jnp.float32).at[target].set(wait, mode="drop")
    ccost = jnp.ones((w,), jnp.float32).at[target].set(cost, mode="drop")
    curg = jnp.zeros((w,), jnp.float32).at[target].set(urgency, mode="drop")
    croute = None if route is None else \
        jnp.zeros((w,), jnp.float32).at[target].set(route, mode="drop")
    n_live = alive.sum().astype(jnp.int32)
    mask = jnp.arange(w) < n_live
    idx, score = sched_score_topb_ref(cwait, ccost, curg, mask, weights, b,
                                      croute)
    return creq, n_live, idx, score
