"""Pure-jnp oracles for the fused scheduler scoring kernels."""
import jax
import jax.numpy as jnp

NEG = -1e30


def _scores(wait, cost, urgency, mask, weights):
    w1, w2, w3, ref_tok = weights
    c = jnp.maximum(cost, 1.0)
    score = w1 * (wait / c) - w2 * (c / ref_tok) + w3 * urgency
    return jnp.where(mask, score, NEG)


def sched_score_argmax_ref(wait, cost, urgency, mask, weights):
    score = _scores(wait, cost, urgency, mask, weights)
    i = jnp.argmax(score)
    return i.astype(jnp.int32), score[i]


def sched_score_topb_ref(wait, cost, urgency, mask, weights, b: int):
    """Full-width ranking oracle: `lax.top_k` over the masked scores
    (first-occurrence tie-breaking).  Returns (idx (b,), score (b,))."""
    score = _scores(wait, cost, urgency, mask, weights)
    vals, idx = jax.lax.top_k(score, b)
    return idx.astype(jnp.int32), vals


def sched_compact_topb_ref(slot_req, alive, wait, cost, urgency, weights,
                           b: int):
    """Two-pass oracle for the fused tick megakernel: the engine's XLA
    cumsum-scatter compaction (stable, -1 tail sentinels) followed by
    the top-B ranking over the *compacted* pool with mask = index <
    n_live.  Returns (compacted (w,) i32, n_live () i32, idx (b,) i32
    in compacted coordinates, score (b,) f32)."""
    w = slot_req.shape[0]
    pos = jnp.cumsum(alive.astype(jnp.int32)) - 1
    target = jnp.where(alive, pos, w)
    creq = jnp.full((w,), -1, jnp.int32).at[target].set(
        slot_req.astype(jnp.int32), mode="drop")
    cwait = jnp.zeros((w,), jnp.float32).at[target].set(wait, mode="drop")
    ccost = jnp.ones((w,), jnp.float32).at[target].set(cost, mode="drop")
    curg = jnp.zeros((w,), jnp.float32).at[target].set(urgency, mode="drop")
    n_live = alive.sum().astype(jnp.int32)
    mask = jnp.arange(w) < n_live
    idx, score = sched_score_topb_ref(cwait, ccost, curg, mask, weights, b)
    return creq, n_live, idx, score
