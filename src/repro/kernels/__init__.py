"""Pallas TPU kernels for the serving engine's compute hot-spots.

Each kernel directory contains:
  <name>.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     jit'd public wrapper (interpret=True on CPU for validation)
  ref.py     pure-jnp oracle used by the allclose test sweeps

TPU adaptation notes (DESIGN.md §3): block shapes are MXU-aligned
(multiples of 128 on matmul dims where dtypes allow), online-softmax
carries live in VMEM scratch across the sequential grid dimension, and
GQA head-mapping happens in the index_map (no gather).
"""
import jax


def interpret_mode() -> bool:
    """Pallas interpret=True on CPU (this container); False on real TPU."""
    return jax.default_backend() != "tpu"
