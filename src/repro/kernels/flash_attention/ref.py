"""Pure-jnp oracle for the flash attention kernel."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(hd)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(k.shape[1])[None, :]
    mask = kj <= qi
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, Sq, H, hd)
