"""Public jit'd wrapper: interpret=True on CPU, compiled on TPU."""
import functools

from repro.kernels import interpret_mode
from repro.kernels.flash_attention.flash_attention import (
    flash_attention as _kernel_call,
)


@functools.wraps(_kernel_call)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512):
    return _kernel_call(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                        interpret=interpret_mode())
