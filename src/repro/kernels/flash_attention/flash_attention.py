"""Flash attention (prefill/train) Pallas TPU kernel.

Tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the last
grid dimension is sequential on TPU, so the online-softmax state
(running max / denominator / weighted accumulator) lives in VMEM scratch
and the output block is written on the final kv step.

GQA is handled in the k/v index_map (q head h reads kv head h // group),
so no head replication is materialized.  Causal + sliding-window masking
is computed from block offsets with iota — masked *inside* the exponent.

VMEM budget per program (bq = bk = 512, hd <= 256, f32 compute):
q/k/v blocks 3*512*256*4 = 1.5 MB, score tile 512*512*4 = 1 MB, scratch
~0.6 MB => ~3.1 MB, comfortably under the ~16 MB VMEM of a v5e core;
matmul dims (512, hd) are MXU-aligned for hd in {64, 128, 192, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, window: int, scale: float, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)       # (bk, hd)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd). Returns (B, Sq, H, hd).

    Sq/Skv must be divisible by bq/bk (callers pad).  `causal` must be
    True (decoder-only framework); window > 0 adds sliding-window masking.
    """
    assert causal, "only causal attention is used in this framework"
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "pad sequences to block multiples"
    nq = Sq // bq
    nk = Skv // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, window=window, scale=scale, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # weighted-value accumulator
        ],
        interpret=interpret,
    )(q, k, v)
