"""Mamba2 SSD intra-chunk Pallas TPU kernel.

Computes, per (batch, chunk, head) grid cell with chunk length Q, head
dim P, state dim N (all VMEM-resident; Q=128, P=64, N=128 => ~0.5 MB):

  decay[t,s] = exp(cum[t] - cum[s]) masked to s <= t
  W[t,s]     = (C_t . B_s) * decay[t,s] * dt[s]
  y_intra    = W @ x                       (Q,Q)@(Q,P) MXU matmul
  state      = (exp(cum[Q-1] - cum) * dt * x)^T @ B   (P,Q)@(Q,N)

The inter-chunk recurrence stays a lax.scan in repro.models.ssm (it is
O(nc) tiny matvecs — not kernel-worthy); this kernel replaces the
quadratic intra-chunk part, which dominates SSD FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, b_ref, c_ref, dt_ref, cum_ref, y_ref, st_ref, *, Q: int):
    x = x_ref[0, 0, :, 0, :]          # (Q, P) f32
    Bm = b_ref[0, 0, :, :]            # (Q, N)
    Cm = c_ref[0, 0, :, :]            # (Q, N)
    dt = dt_ref[0, 0, :, 0]           # (Q,)
    cum = cum_ref[0, 0, :, 0]         # (Q,)

    seg = cum[:, None] - cum[None, :]                       # (Qt, Qs)
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask inside the exponent (avoids inf*0 in the backward pass)
    decay = jnp.exp(jnp.where(si <= ti, seg, -1e9))

    kernel = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (Qt, Qs)
    W = kernel * decay * dt[None, :]
    y_ref[0, 0, :, 0, :] = jax.lax.dot_general(
        W, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    tail = jnp.exp(cum[-1] - cum) * dt                      # (Q,)
    xw = x * tail[:, None]                                  # (Q, P)
    st_ref[0, 0, 0, :, :] = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (P, N)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(xc, Bc, Cc, dtc, cum, *, interpret: bool = False):
    """xc: (B,nc,Q,H,P) f32; Bc/Cc: (B,nc,Q,N); dtc/cum: (B,nc,Q,H).
    Returns (y_intra: (B,nc,Q,H,P), chunk_state: (B,nc,H,P,N)), both f32."""
    B, nc, Q, H, P = xc.shape
    N = Bc.shape[-1]
    kernel = functools.partial(_kernel, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xc, Bc, Cc, dtc, cum)
