"""Pure-jnp oracle: re-export of the model-layer reference implementation
(the model's ssd_intra_ref IS the oracle; kernels must match it)."""
from repro.models.ssm import ssd_intra_ref  # noqa: F401
