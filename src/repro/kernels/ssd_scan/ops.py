"""Public jit'd wrapper: interpret=True on CPU, compiled on TPU."""
import functools

from repro.kernels import interpret_mode
from repro.kernels.ssd_scan.ssd_scan import ssd_intra as _kernel_call


@functools.wraps(_kernel_call)
def ssd_intra(xc, Bc, Cc, dtc, cum):
    return tuple(_kernel_call(xc, Bc, Cc, dtc, cum, interpret=interpret_mode()))
