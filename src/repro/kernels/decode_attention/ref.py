"""Pure-jnp oracle for the decode attention kernel."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, valid):
    """q: (B,H,hd); k/v: (B,S,KV,hd); valid: (S,) -> (B,H,hd)."""
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(hd)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v)
    return out.reshape(B, H, hd)
