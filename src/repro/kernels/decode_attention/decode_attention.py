"""Flash-decode Pallas TPU kernel: ONE query token per sequence against a
long KV cache, with an explicit validity mask (ring-buffer / linear cache
semantics come in via `valid`, computed by the serving layer).

Tiling: grid = (batch, q_heads, num_kv_blocks); kv blocks stream through
VMEM while the online-softmax state sticks in scratch. The query row is
tiny ((G, hd) after GQA folding) so the kernel is HBM-bandwidth-bound by
K/V traffic — exactly the regime the roofline analysis shows for
decode_32k, which is why this is a kernel-worthy hot spot.

A small TPU-specific twist: the single query token is broadcast to an
8-row tile so the MXU/VPU see aligned shapes (rows 1..7 are masked out of
the final write).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bk: int, scale: float, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]   # (1, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)         # (bk, hd)
    valid = valid_ref[:]                              # (bk,) bool/int32

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (1, bk)
    s = jnp.where(valid[None, :] > 0, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (1, hd)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0, :] = (acc / jnp.maximum(l_new, 1e-30))[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, valid, *, bk: int = 1024,
                     interpret: bool = False):
    """q: (B, H, hd); k/v: (B, S, KV, hd); valid: (S,) bool.
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    bk = min(bk, S)
    assert S % bk == 0, "cache length must be a multiple of the kv block"
    nk = S // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, bk=bk, scale=scale, nk=nk)
    valid_i = valid.astype(jnp.int32)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, ki: (b, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((bk,), lambda b, h, ki: (ki,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, ki: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid_i)
