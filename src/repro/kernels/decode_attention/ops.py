"""Public jit'd wrapper: interpret=True on CPU, compiled on TPU."""
import functools

from repro.kernels import interpret_mode
from repro.kernels.decode_attention.decode_attention import (
    decode_attention as _kernel_call,
)


@functools.wraps(_kernel_call)
def decode_attention(q, k, v, valid, *, bk: int = 1024):
    return _kernel_call(q, k, v, valid, bk=bk, interpret=interpret_mode())
