import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) and both production meshes
(16x16 single pod, 2x16x16 multi-pod), lower + compile the appropriate
step function with ShapeDtypeStruct inputs, record memory_analysis(),
cost_analysis(), and collective bytes parsed from the HLO, and cache the
artifact as JSON under paper_results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.config import SHAPES
from repro.configs import ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_spec

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "paper_results", "dryrun")

# HLO collective ops and the per-device traffic multiplier we assign
# (all-reduce is modeled ring-style as reduce-scatter + all-gather => 2x)
COLLECTIVE_RE = re.compile(
    r"^\s*(?:[%\w.\-]+)\s*=\s*(\((?:[^)]*)\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)",
    re.M)
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64)\[([\d,]*)\]")
DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}
MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
        "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    out = {k: 0.0 for k in MULT}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_str) * MULT[op]
    out["total"] = sum(out.values())
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str,
            microbatches: int = 1, save: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_devices": int(n_dev), "microbatches": microbatches,
           "ok": False}
    try:
        spec = build_spec(arch, shape_name, mesh, microbatches)
        rec["variant"] = spec.note
        with mesh:
            t0 = time.time()
            # RPL002 audit: donate positions come from the spec, so the
            # static rule can't resolve them — safe regardless, because
            # .lower() only traces (no buffers are consumed) and
            # spec.args are rebuilt per spec
            lowered = jax.jit(
                spec.fn, in_shardings=spec.in_shardings,
                donate_argnums=spec.donate).lower(*spec.args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                rec[k] = int(getattr(mem, k, 0) or 0)
            rec["bytes_per_device"] = (
                rec.get("argument_size_in_bytes", 0)
                + rec.get("temp_size_in_bytes", 0))
        cost = compiled.cost_analysis() or {}
        rec["hlo_flops"] = float(cost.get("flops", 0.0))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt)
        rec["n_hlo_lines"] = txt.count("\n")
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — a failed combo is a data point
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    for mk in meshes:
        for a in archs:
            for s in shapes:
                fn = os.path.join(OUT_DIR, f"{a}__{s}__{mk}.json")
                if args.skip_existing and os.path.exists(fn):
                    with open(fn) as f:
                        if json.load(f).get("ok"):
                            print(f"[skip] {a} {s} {mk}")
                            continue
                t0 = time.time()
                rec = run_one(a, s, mk, args.microbatches)
                status = "OK " if rec["ok"] else "FAIL"
                print(f"[{status}] {a:24s} {s:12s} {mk:8s} "
                      f"{time.time()-t0:6.1f}s "
                      f"flops={rec.get('hlo_flops', 0):.3g} "
                      f"coll={rec.get('collectives', {}).get('total', 0):.3g} "
                      f"{rec.get('error', '')}",
                      flush=True)


if __name__ == "__main__":
    main()
