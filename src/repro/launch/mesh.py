"""Production mesh construction.

Target: TPU v5e pods — 16x16 = 256 chips per pod ('data', 'model'), and
2 pods = 512 chips ('pod', 'data', 'model').  Defined as functions so
importing this module never touches jax device state (the dry-run sets
--xla_force_host_platform_device_count=512 before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-process debug mesh (1 device)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# Hardware constants for the roofline analysis (TPU v5e)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_PER_CHIP = 16e9           # bytes
