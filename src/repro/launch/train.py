"""Training launcher.

Single-host real training (examples/train_100m.py drives this) and the
mesh-distributed configuration used by the dry-run. On real hardware this
would be invoked per host under the same mesh config.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --smoke --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.configs import ARCHS, get, get_smoke
from repro.data import DataConfig, make_batches
from repro.models import init_model
from repro.training.train_step import init_train_state, train_step
from repro.checkpoint import save_checkpoint


def run(arch: str, smoke: bool, steps: int, batch: int, seq: int,
        lr: float, microbatches: int, ckpt_dir: str | None,
        log_every: int = 10):
    cfg = get_smoke(arch) if smoke else get(arch)
    tc = TrainConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                     total_steps=steps, microbatches=microbatches)
    model = init_model(jax.random.PRNGKey(tc.seed), cfg)
    state = init_train_state(model, tc)
    data = make_batches(DataConfig(vocab=cfg.vocab, seq_len=seq, batch=batch))

    step_fn = jax.jit(lambda s, b: train_step(s, b, cfg, tc))
    losses = []
    t0 = time.time()
    for i, batch_np in zip(range(steps), data):
        b = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.prefix_len:
            b["prefix_embeds"] = jnp.zeros(
                (batch, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
        state, metrics = step_fn(state, b)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, state.params,
                        {"arch": cfg.name, "loss": losses[-1]})
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    losses = run(args.arch, args.smoke, args.steps, args.batch, args.seq,
                 args.lr, args.microbatches, args.ckpt_dir)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
