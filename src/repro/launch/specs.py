"""Input specs + sharding construction for the multi-pod dry-run.

For each (arch, shape) this builds:
  * the step function to lower (train_step / prefill_step / serve_step),
  * ShapeDtypeStruct stand-ins for every argument (weak-type-correct, no
    device allocation),
  * in_shardings derived from the logical-axis rules.

long_500k policy (DESIGN.md §4): native for ssm/hybrid; every pure
full-attention arch is lowered as its sliding-window(8192) VARIANT —
recorded via cfg.variant_note.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, SHAPES, TrainConfig
from repro.configs import get
from repro.models import cache_axes, init_caches, init_model
from repro.models.common import dtype_of
from repro.sharding.rules import DEFAULT_ACT_RULES, logical_to_sharding
from repro.training import adamw
from repro.training.train_step import TrainState, train_step

LONG_WINDOW = 8192


class LoweringSpec(NamedTuple):
    fn: Any               # function to jit
    args: tuple           # ShapeDtypeStructs
    in_shardings: tuple
    cfg: ModelConfig
    note: str
    donate: tuple = ()    # argnums to donate. NOTE (§Perf/qwen-decode
                          # iteration 4, refuted): donating decode caches is
                          # what a real TPU serving engine does (in-place
                          # aliased update), but the CPU stand-in backend
                          # double-buffers donated while-carries instead —
                          # bytes/dev grew 146->189 GB — so the dry-run
                          # keeps donation OFF and we document the TPU-side
                          # expectation instead.


def config_for(arch: str, shape_name: str) -> ModelConfig:
    cfg = get(arch)
    if shape_name == "long_500k" and cfg.arch_type not in ("ssm", "hybrid"):
        cfg = cfg.with_sliding_window(LONG_WINDOW)
    return cfg


def _abstract_model(cfg: ModelConfig):
    """(params ShapeDtypeStruct tree, axes tree) without allocation."""
    captured = {}

    def f(key):
        m = init_model(key, cfg)
        captured["axes"] = m.axes
        return m.params

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, captured["axes"]


def _abstract_caches(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, max_seq))


def _params_shardings(axes, sds, mesh: Mesh):
    return logical_to_sharding(axes, sds, mesh)


def _act(mesh: Mesh, *logical):
    from repro.sharding.rules import spec_for
    # spec_for needs a shape; activations here only need axis mapping, so
    # use a dummy shape consistent with divisibility by construction
    spec = []
    for name in logical:
        rule = DEFAULT_ACT_RULES.get(name or "none")
        if rule is None:
            spec.append(None)
            continue
        if isinstance(rule, str):
            spec.append(rule if rule in mesh.axis_names else None)
        else:
            present = tuple(a for a in rule if a in mesh.axis_names)
            spec.append(present if present else None)
    return NamedSharding(mesh, P(*spec))


def _batch_sharding(mesh: Mesh, batch: int):
    """Shard batch over (pod, data) when divisible, else replicate."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if batch % size != 0:
        return NamedSharding(mesh, P(None))
    return NamedSharding(mesh, P(axes))


def _cache_shardings(cfg: ModelConfig, cache_sds, mesh: Mesh):
    # ACT rules, not param rules: cache_batch/cache_seq only exist there.
    # (Perf iteration 1, EXPERIMENTS.md §Perf/qwen-decode: with param rules
    # the KV cache silently replicated — 5.5 TB/device for qwen1.5-32b.)
    axes = cache_axes(cfg)
    return logical_to_sharding(axes, cache_sds, mesh, DEFAULT_ACT_RULES)


def build_spec(arch: str, shape_name: str, mesh: Mesh,
               microbatches: int = 1,
               cfg_override: ModelConfig | None = None) -> LoweringSpec:
    shape = SHAPES[shape_name]
    cfg = cfg_override if cfg_override is not None else config_for(arch, shape_name)
    dtype = dtype_of(cfg.dtype)
    params_sds, axes = _abstract_model(cfg)
    params_sh = _params_shardings(axes, params_sds, mesh)
    B, S = shape.global_batch, shape.seq_len
    tok_sh = _batch_sharding(mesh, B)
    repl = NamedSharding(mesh, P())

    # VLM/audio: the assigned seq_len covers prefix embeddings + text, so
    # the text stream is S - prefix_len tokens (total context = S exactly)
    prefix_sds = None
    S_txt = S
    if cfg.prefix_len:
        S_txt = S - cfg.prefix_len
        prefix_sds = jax.ShapeDtypeStruct((B, cfg.prefix_len, cfg.d_model), dtype)

    if shape.kind == "train":
        tc = TrainConfig(microbatches=microbatches)
        opt_sds = jax.eval_shape(adamw.init, params_sds)
        opt_sh = adamw.AdamWState(
            step=repl,
            master=params_sh, m=params_sh, v=params_sh)
        state_sds = TrainState(params=params_sds, opt=opt_sds)
        state_sh = TrainState(params=params_sh, opt=opt_sh)
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((B, S_txt), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S_txt), jnp.int32),
        }
        batch_sh = {"tokens": tok_sh, "labels": tok_sh}
        if prefix_sds is not None:
            batch_sds["prefix_embeds"] = prefix_sds
            batch_sh["prefix_embeds"] = tok_sh

        def fn(state, batch):
            return train_step(state, batch, cfg, tc)

        return LoweringSpec(fn, (state_sds, batch_sds), (state_sh, batch_sh),
                            cfg, cfg.variant_note)

    if shape.kind == "prefill":
        from repro.serving.engine import prefill_step

        tok_sds = jax.ShapeDtypeStruct((B, S_txt), jnp.int32)

        def fn(params, tokens, prefix_embeds=None):
            return prefill_step(params, cfg, tokens, max_seq=S,
                                prefix_embeds=prefix_embeds)

        args = (params_sds, tok_sds) + ((prefix_sds,) if prefix_sds is not None else ())
        shs = (params_sh, tok_sh) + ((tok_sh,) if prefix_sds is not None else ())
        return LoweringSpec(fn, args, shs, cfg, cfg.variant_note)

    # decode: ONE new token with a KV cache of seq_len
    from repro.serving.engine import serve_step

    cache_sds = _abstract_caches(cfg, B, S)
    cache_sh = _cache_shardings(cfg, cache_sds, mesh)
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, token, pos, caches):
        return serve_step(params, cfg, token, pos, caches)

    return LoweringSpec(
        fn,
        (params_sds, tok_sds, pos_sds, cache_sds),
        (params_sh, tok_sh, repl, cache_sh),
        cfg, cfg.variant_note)
