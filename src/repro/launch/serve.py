"""Serving launcher: the paper's full deployment — three-layer client
scheduler in front of the real JAX engine (reduced arch variant on CPU;
the same code paths shard over the production mesh on real hardware).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --requests 12 --policy final_adrr_olc
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig
from repro.configs import ARCHS, get_smoke
from repro.core.policy import STRATEGIES, strategy
from repro.models import init_model
from repro.client import default_p90
from repro.serving import BlackBoxProvider, Request, ScheduledClient
from repro.sim.workload import BUCKET_TOKENS


def make_requests(n: int, seed: int, rate_s: float = 2.0) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for i in range(n):
        t += rng.exponential(1.0 / rate_s)
        bucket = int(rng.choice(4, p=[0.5, 0.25, 0.15, 0.1]))
        lo, hi = np.asarray(BUCKET_TOKENS)[bucket]
        # scaled down ~64x for CPU wall-clock sanity (same bucket structure)
        true_tok = max(int(rng.uniform(lo, hi) / 64), 2)
        p50 = float(true_tok * rng.uniform(0.8, 1.2))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, 512, size=(8,)).astype(np.int32),
            max_new=true_tok,
            p50=p50,
            bucket=bucket,
            # real tail prior from the generator's bucket quantile ratio
            # (information-ladder semantics match the simulator; the old
            # client hardcoded p50 * 1.8 regardless of information level)
            p90=default_p90(p50, bucket),
            arrival_s=t,
        ))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--policy", choices=list(STRATEGIES), default="final_adrr_olc")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    print(f"loading reduced {cfg.name} ...")
    model = init_model(jax.random.PRNGKey(0), cfg)
    provider = BlackBoxProvider(model.params, cfg,
                                ServeConfig(max_seq=128, temperature=0.0))
    # the reduced CPU model is far slower per token than the provider
    # physics the deadline budgets assume; relax the timeout multiple so
    # the launcher demos scheduling rather than wholesale abandonment
    # (the shim's session — unlike the old blocking client — really
    # enforces the paper's timeout rule)
    policy = strategy(args.policy)._replace(
        timeout_mult=jnp.full((4,), 30.0, jnp.float32))
    client = ScheduledClient(provider, policy)
    reqs = make_requests(args.requests, args.seed)

    t0 = time.time()
    done = client.run(reqs)
    wall = time.time() - t0

    n_done = sum(r.status == "completed" for r in done)
    n_rej = sum(r.status == "rejected" for r in done)
    lats = [r.finish_s - r.arrival_s for r in done if r.status == "completed"]
    lat_txt = (f"mean_latency={np.mean(lats):.2f}s "
               f"p95={np.percentile(lats, 95):.2f}s" if lats
               else "mean_latency=n/a")
    print(f"policy={args.policy} completed={n_done}/{len(done)} "
          f"rejected={n_rej} {lat_txt} wall={wall:.1f}s")


if __name__ == "__main__":
    main()
