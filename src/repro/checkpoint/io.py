"""npz-based pytree checkpointing with metadata + atomic rename.

Flattening uses jax.tree_util key-paths, so any nested dict/NamedTuple
state (params, AdamWState, caches) round-trips without a schema file.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


# dtypes np.load can round-trip natively; anything else (bfloat16, fp8 ...)
# is stored viewed as a same-width unsigned int and viewed back on restore.
_NATIVE_KINDS = frozenset("fiub")


def _is_native(dtype: np.dtype) -> bool:
    return dtype.kind in _NATIVE_KINDS and dtype.type is not np.void


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        arr = np.asarray(leaf)
        if not _is_native(arr.dtype):
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, meta: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump({"step": step, "n_arrays": len(flat), **(meta or {})}, f)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure (and dtypes) of `like`."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat = _flatten(like)
    missing = set(flat) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_k, leaf in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path_k)
        arr = np.asarray(data[key])
        target = np.dtype(leaf.dtype)
        if not _is_native(target) and arr.dtype.itemsize == target.itemsize:
            arr = arr.view(target)  # stored as raw uint bits (bf16 / fp8 ...)
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_with_path[1], new_leaves)
