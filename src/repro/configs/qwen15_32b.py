"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B card family]: dense decoder with QKV
bias, full MHA (kv == heads), SiLU-gated MLP, RMSNorm, RoPE."""
import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv=40,
    d_ff=27392,
    vocab=152064,
    activation="silu_gated",
    norm="rmsnorm",
    rope=True,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen15-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv=8, d_ff=1024, vocab=512)
