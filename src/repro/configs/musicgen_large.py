"""MusicGen-large [arXiv:2306.05284]: decoder-only transformer over EnCodec
audio tokens (vocab 2048), sinusoidal absolute positions, GELU MLP,
LayerNorm. The EnCodec tokenizer + text conditioner are STUBS (assignment
carve-out): input_specs supplies 64 conditioning frame embeddings consumed
as a prefix."""
import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=2048,
    activation="gelu",
    norm="layernorm",
    rope=False,             # sinusoidal absolute positions
    prefix_len=64,          # conditioning embeddings from the stub frontend
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="musicgen-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv=8, d_ff=768, vocab=512, prefix_len=8)
