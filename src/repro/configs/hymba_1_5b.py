"""Hymba-1.5B [arXiv:2411.13676]: hybrid-head blocks — attention and Mamba
heads in parallel on the same input, outputs mean-fused after per-branch
normalization. Sliding-window attention except three global layers."""
import dataclasses

from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv=5,
    d_ff=5504,
    vocab=32001,
    activation="silu_gated",
    norm="rmsnorm",
    rope=True,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_width=4, chunk=128),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="hymba-smoke", n_layers=2, d_model=320, n_heads=5,
        n_kv=1, d_ff=512, vocab=512, sliding_window=32, global_layers=(0,),
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, conv_width=4, chunk=32))
