"""Mamba2-780M [arXiv:2405.21060]: attention-free SSD (state-space
duality) stack; 48 mixer layers, d_state=128, no FFN."""
import dataclasses

from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    activation="silu_gated",
    norm="rmsnorm",
    rope=False,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=128),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", n_layers=2, d_model=256, vocab=512,
        ssm=SSMConfig(d_state=32, head_dim=32, expand=2, conv_width=4, chunk=32))
