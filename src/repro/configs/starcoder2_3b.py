"""StarCoder2-3B [arXiv:2402.19173]: dense GQA decoder, RoPE, GELU MLP,
LayerNorm, biases on all linears, sliding-window 4096 attention."""
import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv=2,
    d_ff=12288,
    vocab=49152,
    activation="gelu",
    norm="layernorm",
    rope=True,
    qkv_bias=True,
    out_bias=True,
    mlp_bias=True,
    sliding_window=4096,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="starcoder2-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv=2, d_ff=1024, vocab=512, sliding_window=64)
