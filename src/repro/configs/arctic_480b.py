"""Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]: dense-MoE hybrid —
128-expert top-2 MoE in parallel with a dense residual MLP, GQA kv=8."""
import dataclasses

from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_ff=4864,
    vocab=32000,
    activation="silu_gated",
    norm="rmsnorm",
    rope=True,
    moe=MoEConfig(n_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual=True),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="arctic-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv=2, d_ff=512, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.25,
                      dense_residual=True))
