"""InternVL2-1B [arXiv:2404.16821]: InternViT vision encoder (STUB — the
assignment carve-out: input_specs supplies 256 patch embeddings) feeding a
Qwen2-0.5B-style LM backbone (GQA kv=2, SiLU-gated, RMSNorm, RoPE)."""
import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151655,
    activation="silu_gated",
    norm="rmsnorm",
    rope=True,
    qkv_bias=True,          # Qwen2-style attention biases
    prefix_len=256,         # ViT patch embeddings provided by the stub
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-smoke", n_layers=2, d_model=224, n_heads=14,
        n_kv=2, d_ff=512, vocab=512, prefix_len=16)
