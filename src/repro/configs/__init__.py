"""Assigned architecture registry: `get(name)` -> exact ModelConfig,
`get_smoke(name)` -> reduced same-family variant for CPU smoke tests."""
from __future__ import annotations

import importlib

ARCHS = [
    "nemotron-4-340b",
    "internvl2-1b",
    "starcoder2-3b",
    "mamba2-780m",
    "arctic-480b",
    "phi3.5-moe-42b-a6.6b",
    "hymba-1.5b",
    "qwen1.5-32b",
    "stablelm-1.6b",
    "musicgen-large",
]

_MODULES = {
    "nemotron-4-340b": "nemotron_4_340b",
    "internvl2-1b": "internvl2_1b",
    "starcoder2-3b": "starcoder2_3b",
    "mamba2-780m": "mamba2_780m",
    "arctic-480b": "arctic_480b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen1.5-32b": "qwen15_32b",
    "stablelm-1.6b": "stablelm_1_6b",
    "musicgen-large": "musicgen_large",
}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str):
    return _mod(name).CONFIG


def get_smoke(name: str):
    return _mod(name).smoke()
