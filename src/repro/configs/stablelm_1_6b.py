"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b]: dense decoder,
partial rotary (25% of head dim), LayerNorm, SiLU-gated MLP, full MHA."""
import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=5632,
    vocab=100352,
    activation="silu_gated",
    norm="layernorm",
    rope=True,
    rope_fraction=0.25,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="stablelm-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv=8, d_ff=768, vocab=512)
