"""Nemotron-4-340B [arXiv:2402.16819]: dense GQA decoder with squared-ReLU
MLP (non-gated), RoPE, LayerNorm."""
import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv=8,
    d_ff=73728,
    vocab=256000,
    activation="sq_relu",
    norm="layernorm",
    rope=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="nemotron-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv=2, d_ff=1024, vocab=512)
