"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]:
16-expert top-2 MoE, GQA kv=8."""
import dataclasses

from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=6400,
    vocab=32064,
    activation="silu_gated",
    norm="rmsnorm",
    rope=True,
    moe=MoEConfig(n_experts=16, top_k=2, capacity_factor=1.25),
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="phi35-moe-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv=2, d_ff=512, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.25))
