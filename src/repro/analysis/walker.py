"""Source-file model and AST utilities for reprolint.

A `SourceFile` owns one parsed module: its text, AST, the per-line
suppression table (`# reprolint: disable=RPL00x[,RPL00y]` and the
file-wide `# reprolint: disable-file=RPL00x`), the `# noqa` lines the
import-hygiene rule honors, and an import-alias map that resolves local
names back to canonical dotted paths (`jnp` -> `jax.numpy`, `pl` ->
`jax.experimental.pallas`), so every rule matches on canonical names
instead of whatever aliases a module happens to use.

Everything here is stdlib-only: the linter runs before the heavy
dependencies install in CI, so it must never import jax/numpy.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, Iterator, Optional

_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Z0-9_,\s]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*reprolint:\s*disable-file=([A-Z0-9_,\s]+)")
_NOQA_RE = re.compile(r"#\s*noqa(?::\s*([A-Z0-9, ]+))?", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str          # "RPL001"
    path: str          # repo-relative posix path
    line: int          # 1-based
    col: int           # 0-based
    message: str
    suppressed: bool = False

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col + 1}: {self.rule} {self.message}{tag}"


class SourceFile:
    """A parsed module plus the lint bookkeeping rules share."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text(encoding="utf-8")
        self.lines = self.text.splitlines()
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree: Optional[ast.Module] = ast.parse(self.text)
        except SyntaxError as e:  # surfaced as an RPL000 finding by the CLI
            self.tree = None
            self.parse_error = e
        self._suppress: dict[int, set[str]] = {}
        self._suppress_file: set[str] = set()
        self._noqa: dict[int, Optional[set[str]]] = {}  # None = bare noqa
        for i, line in enumerate(self.lines, start=1):
            if "#" not in line:
                continue
            m = _SUPPRESS_RE.search(line)
            if m:
                ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
                self._suppress.setdefault(i, set()).update(ids)
            m = _SUPPRESS_FILE_RE.search(line)
            if m:
                self._suppress_file.update(
                    s.strip() for s in m.group(1).split(",") if s.strip())
            m = _NOQA_RE.search(line)
            if m:
                codes = m.group(1)
                self._noqa[i] = (
                    None if codes is None
                    else {s.strip().upper() for s in codes.split(",")})
        self.aliases = (
            import_aliases(self.tree) if self.tree is not None else {})

    def is_suppressed(self, rule: str, line: int) -> bool:
        if rule in self._suppress_file:
            return True
        return rule in self._suppress.get(line, set())

    def has_noqa(self, line: int, code: str) -> bool:
        """True if the line carries a bare `# noqa` or one naming `code`
        (the flake8 convention the import-hygiene rule honors so existing
        `# noqa: F401` markers keep working)."""
        if line not in self._noqa:
            return False
        codes = self._noqa[line]
        return codes is None or code.upper() in codes

    def qualified(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of a Name/Attribute chain, with the
        module's import aliases expanded at the root."""
        d = dotted(node)
        if d is None:
            return None
        root, _, rest = d.partition(".")
        full = self.aliases.get(root, root)
        return f"{full}.{rest}" if rest else full


def dotted(node: ast.AST) -> Optional[str]:
    """`a.b.c` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def import_aliases(tree: ast.Module) -> dict[str, str]:
    """local name -> canonical dotted path, from every import statement
    in the module (any scope: kernels import inside functions)."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.partition(".")[0]] = (
                    a.name if a.asname else a.name.partition(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def call_kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def literal_int(node: ast.AST, consts: dict[str, int]) -> Optional[int]:
    """Resolve an int literal or a module-level int constant name."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name) and node.id in consts:
        return consts[node.id]
    return None


def module_int_constants(tree: ast.Module) -> dict[str, int]:
    """Top-level `NAME = <int literal>` bindings (e.g. `_BPAD = 128`)."""
    out: dict[str, int] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int) \
                and not isinstance(node.value.value, bool):
            out[node.targets[0].id] = node.value.value
    return out


def unwrap_partial(sf: SourceFile, node: ast.AST) -> ast.AST:
    """`functools.partial(f, ...)` -> `f` (transparent for the purposes
    of "which function does this jit/scan trace")."""
    while isinstance(node, ast.Call) and sf.qualified(node.func) in (
            "functools.partial", "partial") and node.args:
        node = node.args[0]
    return node


def assigned_names(target: ast.expr) -> Iterator[str]:
    """Dotted names bound by an assignment target (tuples flattened)."""
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from assigned_names(elt)
    elif isinstance(target, ast.Starred):
        yield from assigned_names(target.value)
    else:
        d = dotted(target)
        if d is not None:
            yield d


SKIP_DIRS = {"__pycache__", ".git", ".jax_cache", ".venv", "node_modules"}


def iter_source_files(paths: Iterable[Path]) -> Iterator[Path]:
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for sub in sorted(p.rglob("*.py")):
                if not any(part in SKIP_DIRS for part in sub.parts):
                    yield sub
