"""reprolint CLI: ``python -m repro.analysis.lint src tests benchmarks``.

Exit status 0 when no unsuppressed findings, 1 otherwise. Stdlib-only
(no jax import) so it can run first in CI, before dependencies install.

Options:
  --root DIR          repo root holding pyproject.toml (default: cwd,
                      walking up until a pyproject.toml is found)
  --select RPL00x,..  run only these rules
  --show-suppressed   also list findings silenced by `# reprolint:`
                      comments (informational; never affects exit code)
  --list-rules        print the registered rules and exit
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

import repro.analysis.rules  # noqa: F401  (registers the rules)
from repro.analysis.manifest import load_manifest
from repro.analysis.registry import Project, all_rules
from repro.analysis.walker import Finding, SourceFile, iter_source_files


def find_root(start: Path) -> Path:
    p = start.resolve()
    for cand in (p, *p.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return p


def build_project(root: Path, paths: list[Path]) -> Project:
    files = []
    for fp in iter_source_files(paths):
        try:
            rel = fp.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = fp.as_posix()
        files.append(SourceFile(fp, rel))
    return Project(root=root, files=files, manifest=load_manifest(root))


def run(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="reprolint: static invariant checks for the repro tree")
    ap.add_argument("paths", nargs="*", default=["src", "tests", "benchmarks"])
    ap.add_argument("--root", default=None)
    ap.add_argument("--select", default=None)
    ap.add_argument("--show-suppressed", action="store_true")
    ap.add_argument("--list-rules", action="store_true")
    ns = ap.parse_args(argv)

    if ns.list_rules:
        for rid, (summary, _fn) in sorted(all_rules().items()):
            print(f"{rid}  {summary}")
        return 0

    root = Path(ns.root) if ns.root else find_root(Path.cwd())
    paths = [Path(p) if Path(p).is_absolute() else root / p
             for p in ns.paths]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"reprolint: path not found: "
              f"{', '.join(str(m) for m in missing)}", file=sys.stderr)
        return 2

    project = build_project(root, paths)
    only = ({s.strip() for s in ns.select.split(",") if s.strip()}
            if ns.select else None)
    findings = project.run(only=only)

    # files that failed to parse are findings in their own right
    for sf in project.files:
        if sf.parse_error is not None:
            findings.append(Finding(
                "RPL000", sf.rel, sf.parse_error.lineno or 1, 0,
                f"syntax error: {sf.parse_error.msg}"))

    active = [f for f in findings if not f.suppressed]
    shown = findings if ns.show_suppressed else active
    for f in shown:
        print(f.render())
    n_sup = sum(1 for f in findings if f.suppressed)
    tail = f" ({n_sup} suppressed)" if n_sup else ""
    print(f"reprolint: {len(active)} finding(s) in "
          f"{len(project.files)} file(s){tail}")
    return 1 if active else 0


def main() -> None:
    sys.exit(run(sys.argv[1:]))


if __name__ == "__main__":
    main()
