"""Rule modules register themselves on import; lint.py imports this
package to populate the registry."""
from repro.analysis.rules import (  # noqa: F401
    rpl001_pinned,
    rpl002_donation,
    rpl003_hostsync,
    rpl004_static_args,
    rpl005_kernels,
    rpl006_imports,
)
