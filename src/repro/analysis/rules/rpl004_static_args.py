"""RPL004 — static-arg hashability for jit cache keys.

Arguments declared static via `static_argnums` / `static_argnames` are
hashed into the jit cache key. A list/dict/set/ndarray there raises
`TypeError: unhashable type` at the first call — or, for an ndarray,
sometimes later on a cache probe. The rule records every jit wrapper
with static args (decorated defs and ``NAME = jax.jit(f, static_...)``
assignments) and flags call sites / parameter defaults that pass a
value that is unhashable by construction: display literals (`[...]`,
`{...}`), comprehensions, or calls to list/dict/set/np.array-likes.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.registry import Project, rule
from repro.analysis.walker import (
    Finding, SourceFile, call_kwarg, dotted, unwrap_partial,
)

_JIT_NAMES = {"jax.jit", "jax.pmap"}
_UNHASHABLE_FACTORIES = {
    "list", "dict", "set", "bytearray",
    "numpy.array", "numpy.asarray", "numpy.zeros", "numpy.ones",
    "jax.numpy.array", "jax.numpy.asarray", "jax.numpy.zeros",
    "jax.numpy.ones",
}


def _unhashable_reason(sf: SourceFile, node: ast.expr) -> Optional[str]:
    if isinstance(node, (ast.List, ast.ListComp)):
        return "list"
    if isinstance(node, (ast.Dict, ast.DictComp)):
        return "dict"
    if isinstance(node, (ast.Set, ast.SetComp)):
        return "set"
    if isinstance(node, ast.Call):
        q = sf.qualified(node.func)
        if q in _UNHASHABLE_FACTORIES:
            return q.rpartition(".")[2]
    return None


def _static_decl(sf: SourceFile, call: ast.Call
                 ) -> Optional[tuple[tuple[int, ...], tuple[str, ...]]]:
    """(static positions, static names) if `call` is jax.jit/pmap with
    literal static_argnums/static_argnames, else None."""
    if sf.qualified(call.func) not in _JIT_NAMES:
        return None
    nums: tuple[int, ...] = ()
    names: tuple[str, ...] = ()
    kw = call_kwarg(call, "static_argnums")
    if kw is not None:
        nums = _int_tuple(kw) or ()
    kw = call_kwarg(call, "static_argnames")
    if kw is not None:
        names = _str_tuple(kw) or ()
    if not nums and not names:
        return None
    return nums, names


def _int_tuple(node: ast.expr) -> Optional[tuple[int, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                vals.append(e.value)
            else:
                return None
        return tuple(vals)
    return None


def _str_tuple(node: ast.expr) -> Optional[tuple[str, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                vals.append(e.value)
            else:
                return None
        return tuple(vals)
    return None


class _Wrapper:
    """One jit wrapper with static args: how calls map to static slots."""

    def __init__(self, nums: tuple[int, ...], names: tuple[str, ...],
                 fn: Optional[ast.FunctionDef]):
        self.nums = nums
        self.names = set(names)
        self.param_names: list[str] = []
        if fn is not None:
            self.param_names = [a.arg for a in fn.args.args]
            # static_argnames imply positions when the signature is known
            for n in names:
                if n in self.param_names:
                    self.nums = self.nums + (self.param_names.index(n),)

    def static_values(self, call: ast.Call) -> Iterator[ast.expr]:
        for i in self.nums:
            if i < len(call.args):
                yield call.args[i]
        for kw in call.keywords:
            if kw.arg is not None and kw.arg in self.names:
                yield kw.value


def _collect_wrappers(sf: SourceFile,
                      funcs: dict[str, ast.FunctionDef]
                      ) -> dict[str, _Wrapper]:
    out: dict[str, _Wrapper] = {}
    # decorated defs: @jax.jit(static_argnums=...) and
    # @functools.partial(jax.jit, static_argnames=...)
    for name, fn in funcs.items():
        for dec in fn.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            decl = _static_decl(sf, dec)
            if decl is None and sf.qualified(dec.func) in (
                    "functools.partial", "partial") and dec.args:
                inner = dec.args[0]
                if sf.qualified(inner) in _JIT_NAMES:
                    synthetic = ast.Call(func=inner, args=[],
                                         keywords=dec.keywords)
                    decl = _static_decl(sf, synthetic)
            if decl is not None:
                out[name] = _Wrapper(decl[0], decl[1], fn)
    # assignments: NAME = jax.jit(f, static_...)
    for node in ast.walk(sf.tree):  # type: ignore[arg-type]
        if not isinstance(node, ast.Assign) or not isinstance(node.value, ast.Call):
            continue
        decl = _static_decl(sf, node.value)
        if decl is None:
            continue
        target_fn = None
        if node.value.args:
            inner = unwrap_partial(sf, node.value.args[0])
            d = dotted(inner)
            if d is not None:
                target_fn = funcs.get(d)
        for t in node.targets:
            d = dotted(t)
            if d is not None:
                out[d] = _Wrapper(decl[0], decl[1], target_fn)
    return out


@rule("RPL004", "unhashable value passed/defaulted into a "
      "static_argnums/static_argnames slot")
def check(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        funcs = {n.name: n for n in ast.walk(sf.tree)
                 if isinstance(n, ast.FunctionDef)}
        wrappers = _collect_wrappers(sf, funcs)
        # defaults of the wrapped function for its static params
        for name, w in wrappers.items():
            fn = funcs.get(name)
            if fn is None or not fn.args.defaults:
                continue
            offset = len(fn.args.args) - len(fn.args.defaults)
            for i, default in enumerate(fn.args.defaults):
                pos = offset + i
                pname = fn.args.args[pos].arg
                if pos in w.nums or pname in w.names:
                    reason = _unhashable_reason(sf, default)
                    if reason is not None:
                        yield Finding(
                            "RPL004", sf.rel, default.lineno,
                            default.col_offset,
                            f"default for static arg `{pname}` of `{name}` "
                            f"is an unhashable {reason}; use a tuple / "
                            f"frozen value")
        if not wrappers:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None or d not in wrappers:
                continue
            for val in wrappers[d].static_values(node):
                reason = _unhashable_reason(sf, val)
                if reason is not None:
                    yield Finding(
                        "RPL004", sf.rel, val.lineno, val.col_offset,
                        f"unhashable {reason} passed to static arg of "
                        f"`{d}` — jit cache keys must hash; pass a tuple "
                        f"or frozen dataclass")
