"""RPL002 — use-after-donate dataflow.

`jax.jit(..., donate_argnums=...)` hands the argument buffers to XLA;
after the call the old Arrays are deleted and any host read of a stale
binding raises (or worse, silently observes freed memory under some
backends). The rule tracks, per function body in statement order, the
bindings passed in donated positions of a known donating callable; a
later load of such a binding is a finding until the name is rebound.

Donating callables come from three sources: module-level
``NAME = jax.jit(fn, donate_argnums=(...))`` assignments, immediate
``jax.jit(...)(args)`` calls, and the manifest's
``[tool.reprolint.donating-callables]`` table for callables built at
runtime (bound methods like ``self._tick``). Non-literal donate_argnums
(e.g. ``donate_argnums=spec.donate`` in launch/dryrun.py) can't be
resolved statically and are skipped — those sites are audited by hand.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.registry import Project, rule
from repro.analysis.walker import (
    Finding, SourceFile, assigned_names, call_kwarg, dotted,
)

_JIT_NAMES = {"jax.jit", "jax.api.jit"}


def _literal_positions(node: ast.expr) -> Optional[tuple[int, ...]]:
    """donate_argnums as a literal int or tuple/list of ints, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int) \
                    and not isinstance(e.value, bool):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    return None


def _donating_jit(sf: SourceFile, node: ast.AST) -> Optional[tuple[int, ...]]:
    """If `node` is a jax.jit(...) call with literal donate_argnums,
    return the donated positions."""
    if not isinstance(node, ast.Call) or sf.qualified(node.func) not in _JIT_NAMES:
        return None
    kw = call_kwarg(node, "donate_argnums")
    if kw is None:
        return None
    return _literal_positions(kw)


def _module_donators(sf: SourceFile, project: Project) -> dict[str, tuple[int, ...]]:
    """dotted name -> donated positions, seeded from the manifest and
    extended with module-level `NAME = jax.jit(..., donate_argnums=...)`
    (and `self.NAME = ...` / `fn = ...` inside function bodies)."""
    out = dict(project.manifest.donating_callables)
    if sf.tree is None:
        return out
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Assign):
            continue
        pos = _donating_jit(sf, node.value)
        if pos is None:
            continue
        for t in node.targets:
            d = dotted(t)
            if d is not None:
                out[d] = pos
    return out


def _donated_args(call: ast.Call, positions: tuple[int, ...]) -> Iterator[str]:
    for i in positions:
        if i < len(call.args):
            d = dotted(call.args[i])
            if d is not None:
                yield d


def _is_donating_call(sf: SourceFile, call: ast.Call,
                      donators: dict[str, tuple[int, ...]]
                      ) -> Optional[tuple[int, ...]]:
    """Donated positions if `call` invokes a known donating callable —
    by name, or directly as `jax.jit(f, donate_argnums=...)(args)`."""
    d = dotted(call.func)
    if d is not None and d in donators:
        return donators[d]
    pos = _donating_jit(sf, call.func)
    if pos is not None:
        return pos
    return None


class _BodyScan:
    """Statement-order walk of one function body with a taint set of
    donated dotted names. Control flow is handled conservatively:
    branches are scanned in order against the same taint set (a read in
    either arm of an `if` after a donation is a finding), and loop
    bodies are scanned twice so a donation late in the body taints a
    read early in the next iteration."""

    def __init__(self, sf: SourceFile, donators: dict[str, tuple[int, ...]]):
        self.sf = sf
        self.donators = donators
        self.taint: dict[str, int] = {}  # dotted name -> donation line
        self.findings: list[Finding] = []
        self._seen: set[tuple[int, int, str]] = set()

    def scan_body(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self.scan_stmt(stmt)

    def scan_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested defs get their own scan
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            # two passes over the loop body: pass 2 sees taint created
            # at the bottom of pass 1 (wrap-around reads)
            for _ in range(2):
                self.scan_body(stmt.body)
            self.scan_body(stmt.orelse)
            return
        if isinstance(stmt, (ast.If,)):
            self.scan_body(stmt.body)
            self.scan_body(stmt.orelse)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.scan_expr_reads(item.context_expr)
            self.scan_body(stmt.body)
            return
        if isinstance(stmt, ast.Try):
            self.scan_body(stmt.body)
            for h in stmt.handlers:
                self.scan_body(h.body)
            self.scan_body(stmt.orelse)
            self.scan_body(stmt.finalbody)
            return
        if isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                self._clear(t)
            return
        if isinstance(stmt, ast.Assign):
            self.scan_expr_reads(stmt.value)
            self.scan_value_for_donation(stmt.value)
            for t in stmt.targets:
                self._clear(t)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self.scan_expr_reads(stmt.value)
                self.scan_value_for_donation(stmt.value)
            self._clear(stmt.target)
            return
        if isinstance(stmt, ast.AugAssign):
            # `x += ...` reads x first, so it counts as a use
            self.scan_expr_reads(stmt.target)
            self.scan_expr_reads(stmt.value)
            self.scan_value_for_donation(stmt.value)
            self._clear(stmt.target)
            return
        # generic statement (Expr/Return/Assert/...): everything is a read
        self.scan_expr_reads(stmt)
        self.scan_value_for_donation(stmt)

    def scan_value_for_donation(self, node: ast.AST) -> None:
        """Find donating calls anywhere in an expression and taint their
        donated args (after reads in the same statement were checked)."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                pos = _is_donating_call(self.sf, sub, self.donators)
                if pos is not None:
                    for name in _donated_args(sub, pos):
                        self.taint[name] = sub.lineno

    def scan_expr_reads(self, node: ast.AST) -> None:
        # Taint only holds donations from *previous* statements (reads in
        # a statement are checked before its own donations register), so
        # every tainted read here is genuinely stale — including one
        # passed back into another donating call.
        if not self.taint:
            return
        for sub in ast.walk(node):
            d = dotted(sub) if isinstance(sub, (ast.Name, ast.Attribute)) else None
            if d is None:
                continue
            hit = self._tainted(d)
            if hit is None:
                continue
            key = (sub.lineno, sub.col_offset, d)
            if key in self._seen:  # loop bodies are scanned twice
                continue
            self._seen.add(key)
            self.findings.append(Finding(
                "RPL002", self.sf.rel, sub.lineno, sub.col_offset,
                f"read of `{d}` after it was donated to a jitted call at "
                f"line {hit} (use-after-donate); rebind it from the call "
                f"result before reading"))

    def _tainted(self, name: str) -> Optional[int]:
        if name in self.taint:
            return self.taint[name]
        # a read of a parent object (`self._pool.x`) through a tainted
        # dotted prefix is also stale
        for t, line in self.taint.items():
            if name.startswith(t + "."):
                return line
        return None

    def _clear(self, target: ast.expr) -> None:
        for name in assigned_names(target):
            self.taint.pop(name, None)
            for t in list(self.taint):
                if t.startswith(name + "."):
                    del self.taint[t]


@rule("RPL002", "read of a binding after it was passed in a donated "
      "position of a jitted call")
def check(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        donators = _module_donators(sf, project)
        if not donators:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scan = _BodyScan(sf, donators)
                scan.scan_body(node.body)
                yield from scan.findings
