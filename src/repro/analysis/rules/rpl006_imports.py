"""RPL006 — import hygiene (the in-tree half of the ruff baseline).

`make lint` runs ruff first when it is on PATH (CI installs it); this
rule keeps the two highest-value pyflakes checks working even on a bare
interpreter where ruff isn't installable: module-level imports that are
never used, and same-name re-imports. `# noqa` / `# noqa: F401` on the
import line is honored, matching the ruff convention, so one marker
satisfies both tools.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.registry import Project, rule
from repro.analysis.walker import Finding


def _used_names(tree: ast.Module) -> set[str]:
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
    # string entries in __all__ count as uses (re-export modules)
    for node in tree.body:
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets) \
                and isinstance(node.value, (ast.List, ast.Tuple)):
            for e in node.value.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    used.add(e.value)
    return used


@rule("RPL006", "unused or duplicate module-level import")
def check(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        is_init = sf.rel.endswith("__init__.py")
        has_all = any(
            isinstance(n, ast.Assign)
            and any(isinstance(t, ast.Name) and t.id == "__all__"
                    for t in n.targets)
            for n in sf.tree.body)
        if is_init and not has_all:
            # __init__.py without __all__: imports are the public API
            continue
        used = _used_names(sf.tree)
        bound: dict[str, int] = {}
        for node in sf.tree.body:  # module level only
            if isinstance(node, ast.Import):
                names = [(a.asname or a.name.partition(".")[0], a)
                         for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__" \
                        or any(a.name == "*" for a in node.names):
                    continue
                names = [(a.asname or a.name, a) for a in node.names]
            else:
                continue
            if sf.has_noqa(node.lineno, "F401"):
                continue
            for local, alias in names:
                # multi-line imports: the noqa rides the name's own line
                line = getattr(alias, "lineno", node.lineno)
                if sf.has_noqa(line, "F401"):
                    continue
                if local in bound:
                    yield Finding(
                        "RPL006", sf.rel, line, node.col_offset,
                        f"`{local}` re-imported (first bound at line "
                        f"{bound[local]})")
                bound[local] = line
                if local not in used:
                    yield Finding(
                        "RPL006", sf.rel, line, node.col_offset,
                        f"`{local}` imported but unused")
