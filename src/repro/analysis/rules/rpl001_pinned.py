"""RPL001 — pinned-float discipline in bit-exactness-critical modules.

Cross-program parity (windowed vs dense engine, live client vs sim)
depends on severity/score/EMA arithmetic routing through
`core.numerics.pinned`: a bare `jnp.sum` or an FMA-contractible
`a*b + c` leaves XLA free to re-associate or fuse, and a 1-ulp drift
flips overload thresholds. In modules the manifest marks critical, any
reduction (`jnp.sum`/`jnp.mean`/`.sum()`/`.mean()`) or mul-add whose
operands touch a sensitive name must sit inside a `pinned(...)` call.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.registry import Project, rule
from repro.analysis.walker import Finding, SourceFile, dotted

_REDUCTION_FUNCS = {
    "jax.numpy.sum", "jax.numpy.mean", "numpy.sum", "numpy.mean",
}
_REDUCTION_METHODS = {"sum", "mean"}


def _mentions_sensitive(node: ast.AST, sensitive: tuple[str, ...]) -> bool:
    """Does any Name/Attribute segment in the subtree match a sensitive
    name? Matching is per-segment so `self.ema_latency_ratio` and
    `carry.scores` both count."""
    for sub in ast.walk(node):
        segs: tuple[str, ...] = ()
        if isinstance(sub, ast.Name):
            segs = (sub.id,)
        elif isinstance(sub, ast.Attribute):
            segs = (sub.attr,)
        for seg in segs:
            if seg in sensitive:
                return True
    return False


def _pinned_spans(sf: SourceFile, tree: ast.AST,
                  pinned_names: tuple[str, ...]) -> list[ast.Call]:
    """All `pinned(...)` call nodes (matched on the final name segment,
    so `numerics.pinned(x)` and `pinned(x)` both count)."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d is not None and d.rpartition(".")[2] in pinned_names:
                out.append(node)
    return out


def _inside_any(node: ast.AST, containers: list[ast.Call]) -> bool:
    """Is `node` lexically inside one of the container calls' argument
    subtrees? (Position-based: AST nodes don't carry parent links.)"""
    n0 = (node.lineno, node.col_offset)            # type: ignore[attr-defined]
    n1 = (node.end_lineno, node.end_col_offset)    # type: ignore[attr-defined]
    for c in containers:
        c0 = (c.lineno, c.col_offset)
        c1 = (c.end_lineno, c.end_col_offset)
        if c0 <= n0 and n1 <= c1 and node is not c:
            return True
    return False


def _sensitive_target(stmt_targets: dict[int, bool], node: ast.AST) -> bool:
    return stmt_targets.get(getattr(node, "lineno", -1), False)


@rule("RPL001", "bare float reduction / mul-add bypasses numerics.pinned "
      "in a bit-exactness-critical module")
def check(project: Project) -> Iterator[Finding]:
    man = project.manifest
    sensitive = man.sensitive_names
    if not sensitive:
        return
    for sf in project.files:
        if sf.tree is None or not man.is_critical(sf.rel):
            continue
        pins = _pinned_spans(sf, sf.tree, man.pinned_names)
        # assignment lines whose *target* is sensitive: `score = a*b + c`
        # is a violation even if the RHS names aren't sensitive
        tgt_lines: dict[int, bool] = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assign):
                names = [n for t in node.targets
                         for n in _iter_target_segs(t)]
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                names = list(_iter_target_segs(node.target))
            else:
                continue
            if any(n in sensitive for n in names):
                tgt_lines[node.lineno] = True

        for node in ast.walk(sf.tree):
            hit = None
            if isinstance(node, ast.Call):
                q = sf.qualified(node.func)
                if q in _REDUCTION_FUNCS:
                    hit = f"bare {q.rpartition('.')[2]}()"
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _REDUCTION_METHODS \
                        and not node.args and not node.keywords:
                    # zero-arg .sum()/.mean() method — axis= reductions on
                    # bool masks (counting) are not float-sensitive
                    hit = f"bare .{node.func.attr}()"
            elif isinstance(node, ast.BinOp) \
                    and isinstance(node.op, (ast.Add, ast.Sub)) \
                    and (isinstance(node.left, ast.BinOp)
                         and isinstance(node.left.op, ast.Mult)
                         or isinstance(node.right, ast.BinOp)
                         and isinstance(node.right.op, ast.Mult)):
                hit = "FMA-contractible a*b + c"
            if hit is None:
                continue
            if not (_mentions_sensitive(node, sensitive)
                    or _sensitive_target(tgt_lines, node)):
                continue
            if _inside_any(node, pins):
                continue
            yield Finding(
                "RPL001", sf.rel, node.lineno, node.col_offset,
                f"{hit} on sensitive value bypasses numerics.pinned — "
                f"wrap the subgraph in pinned(...) or suppress with a "
                f"justification")


def _iter_target_segs(target: ast.expr) -> Iterator[str]:
    if isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            yield from _iter_target_segs(e)
    elif isinstance(target, ast.Starred):
        yield from _iter_target_segs(target.value)
    elif isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, ast.Attribute):
        yield target.attr
