"""RPL003 — host synchronization inside traced (jitted / scan-body)
functions.

`.item()`, `float()`, `int()`, `bool()`, `np.asarray(...)` on a traced
value force a device→host transfer at trace time — inside `jax.jit` or
a `lax.scan` body they either fail (ConcretizationTypeError) or, when
they happen to succeed on a constant, silently bake a recompile +
transfer hazard into the hot path that the runtime `transfer_guard`
tests only catch when that exact branch executes. Shape/dtype reads
(`x.shape[0]`, `int(x.ndim)`, `len(xs)`) are static and exempt.

Traced functions are found module-locally: `@jax.jit`-style decorators
(through `functools.partial`), callables passed at the traced positions
of jit/vmap/pmap/scan/fori_loop/while_loop/cond/pallas_call, and the
transitive closure over module-local helpers called from traced bodies.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.registry import Project, rule
from repro.analysis.walker import Finding, SourceFile, dotted, unwrap_partial

# transform -> positional indices whose argument is traced as a function
_TRACED_POSITIONS = {
    "jax.jit": (0,),
    "jax.vmap": (0,),
    "jax.pmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.fori_loop": (2,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": (1, 2, 3, 4, 5),
    "jax.experimental.pallas.pallas_call": (0,),
}
_DECORATOR_TRANSFORMS = {"jax.jit", "jax.vmap", "jax.pmap", "jax.grad",
                         "jax.value_and_grad", "jax.checkpoint"}

_HOST_CASTS = {"float", "int", "bool", "complex"}
_HOST_METHODS = {"item", "tolist", "block_until_ready"}
_HOST_FUNCS = {"numpy.asarray", "numpy.array", "numpy.float32",
               "numpy.float64", "numpy.int32", "numpy.int64"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}


def _local_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    """Named defs at any nesting level (scan bodies are usually nested
    closures)."""
    out: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)  # first wins on collision
    return out


def _traced_roots(sf: SourceFile, tree: ast.Module,
                  funcs: dict[str, ast.FunctionDef]) -> set[str]:
    roots: set[str] = set()
    for name, fn in funcs.items():
        for dec in fn.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            q = sf.qualified(target)
            if q in _DECORATOR_TRANSFORMS:
                roots.add(name)
            elif q in ("functools.partial", "partial") \
                    and isinstance(dec, ast.Call) and dec.args:
                inner = sf.qualified(dec.args[0])
                if inner in _DECORATOR_TRANSFORMS:
                    roots.add(name)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        q = sf.qualified(node.func)
        if q not in _TRACED_POSITIONS:
            continue
        for i in _TRACED_POSITIONS[q]:
            if i < len(node.args):
                target = unwrap_partial(sf, node.args[i])
                d = dotted(target)
                if d is not None and d in funcs:
                    roots.add(d)
    return roots


def _transitive(sf: SourceFile, funcs: dict[str, ast.FunctionDef],
                roots: set[str]) -> set[str]:
    closed = set(roots)
    frontier = list(roots)
    while frontier:
        name = frontier.pop()
        fn = funcs.get(name)
        if fn is None:
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                if d in funcs and d not in closed:
                    closed.add(d)
                    frontier.append(d)
    return closed


def _is_static_read(node: ast.Call) -> bool:
    """True when the call's arguments only touch static metadata —
    shapes, dtypes, len(), or plain constants — so the cast never sees
    a traced value."""
    args = list(node.args) + [kw.value for kw in node.keywords]
    if not args:
        return True
    for arg in args:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
                return True
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id == "len":
                return True
    return all(isinstance(a, ast.Constant) for a in args)


def _host_sync_hit(sf: SourceFile, node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Name) and node.func.id in _HOST_CASTS \
            and len(node.args) == 1 and not node.keywords:
        if _is_static_read(node):
            return None
        return f"{node.func.id}()"
    if isinstance(node.func, ast.Attribute) and node.func.attr in _HOST_METHODS:
        return f".{node.func.attr}()"
    q = sf.qualified(node.func)
    if q in _HOST_FUNCS:
        if _is_static_read(node):
            return None
        return f"{q.rpartition('.')[2]}() [numpy]"
    return None


@rule("RPL003", "host-synchronizing call inside a jitted / scan-body "
      "function")
def check(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        funcs = _local_functions(sf.tree)
        traced = _transitive(
            sf, funcs, _traced_roots(sf, sf.tree, funcs))
        for name in sorted(traced):
            fn = funcs[name]
            for node in ast.walk(fn):
                # nested defs inside a traced fn are traced too (they
                # are in `funcs` and reachable, so they get their own
                # pass); don't double-report their bodies here
                if isinstance(node, ast.Call):
                    if _owner_function(fn, funcs, node) is not fn:
                        continue
                    hit = _host_sync_hit(sf, node)
                    if hit is not None:
                        yield Finding(
                            "RPL003", sf.rel, node.lineno, node.col_offset,
                            f"{hit} inside traced function `{name}` forces "
                            f"a host sync (transfer / recompile hazard); "
                            f"hoist it out of the traced region")


def _owner_function(current: ast.FunctionDef,
                    funcs: dict[str, ast.FunctionDef],
                    node: ast.AST) -> ast.FunctionDef:
    """Innermost named def containing `node` (by position), so a call
    in a nested def isn't attributed to the outer traced fn as well."""
    best = current
    n0 = (node.lineno, node.col_offset)  # type: ignore[attr-defined]
    for fn in funcs.values():
        if fn is current or fn is best:
            continue
        f0 = (fn.lineno, fn.col_offset)
        f1 = (fn.end_lineno, fn.end_col_offset)
        b0 = (best.lineno, best.col_offset)
        b1 = (best.end_lineno, best.end_col_offset)
        if f0 <= n0 <= f1 and b0 <= f0 and f1 <= b1:
            best = fn
    return best
