"""RPL005 — Pallas kernel contract.

Every kernel package under the manifest's ``kernels-root`` must ship:

* a ``ref.py`` with at least one ``*_ref`` oracle function (the pure
  jnp reference the parity tests compare against), and
* a parity test: the manifest's ``kernel-test-file`` must import at
  least one ``*_ref`` symbol from that package.

Inside kernel modules, literal ``pl.BlockSpec`` / ``pltpu.VMEM`` shapes
must be lane-aligned — the minor (last) axis a multiple of the manifest
lane width (128) or exactly 1 — and VMEM scratch must not accumulate in
half precision (f32 accumulators are part of the bit-exactness story).
Module-level int constants (``_BPAD = 128``) resolve; variable shapes
are skipped (they're checked at runtime by the parity tests).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterator

from repro.analysis.registry import Project, rule
from repro.analysis.walker import (
    Finding, SourceFile, literal_int, module_int_constants,
)

_SHAPE_CALLS = {
    "jax.experimental.pallas.BlockSpec": 0,       # shape is arg 0
    "jax.experimental.pallas.tpu.VMEM": 0,
    "jax.experimental.pallas.tpu.SMEM": 0,
}
_HALF_DTYPES = {"float16", "bfloat16"}


def _check_shape_call(sf: SourceFile, node: ast.Call, lane: int,
                      consts: dict[str, int]) -> Iterator[Finding]:
    q = sf.qualified(node.func)
    if q not in _SHAPE_CALLS:
        return
    idx = _SHAPE_CALLS[q]
    shape = node.args[idx] if idx < len(node.args) else None
    if isinstance(shape, (ast.Tuple, ast.List)) and shape.elts:
        minor = literal_int(shape.elts[-1], consts)
        if minor is not None and minor != 1 and minor % lane != 0:
            yield Finding(
                "RPL005", sf.rel, shape.lineno, shape.col_offset,
                f"{q.rpartition('.')[2]} minor axis {minor} is not "
                f"lane-aligned (must be 1 or a multiple of {lane}); "
                f"Mosaic pads or mis-tiles unaligned minor dims")
    if q.endswith(".VMEM") and len(node.args) > 1:
        dtype = node.args[1]
        seg = None
        if isinstance(dtype, ast.Attribute):
            seg = dtype.attr
        elif isinstance(dtype, ast.Name):
            seg = dtype.id
        if seg in _HALF_DTYPES:
            yield Finding(
                "RPL005", sf.rel, dtype.lineno, dtype.col_offset,
                f"VMEM scratch in {seg}: accumulate in float32 and cast "
                f"on the way out (half-precision accumulation breaks "
                f"bit-exactness)")


@rule("RPL005", "Pallas kernel package missing ref oracle / parity test, "
      "or mis-aligned BlockSpec/VMEM shape")
def check(project: Project) -> Iterator[Finding]:
    man = project.manifest
    kroot = project.root / man.kernels_root
    test_sf = project.file(man.kernel_test_file)

    # --- package-structure half: ref.py + parity-test reference ---
    if kroot.is_dir():
        for pkg in sorted(p for p in kroot.iterdir() if p.is_dir()):
            if not (pkg / "__init__.py").is_file():
                continue
            pkg_rel = f"{man.kernels_root}/{pkg.name}".replace("\\", "/")
            init_rel = f"{pkg_rel}/__init__.py"
            ref = pkg / "ref.py"
            ref_names: set[str] = set()
            if not ref.is_file():
                yield Finding(
                    "RPL005", init_rel, 1, 0,
                    f"kernel package `{pkg.name}` has no ref.py oracle "
                    f"module (every Pallas kernel needs a jnp reference)")
            else:
                try:
                    rtree = ast.parse(ref.read_text(encoding="utf-8"))
                    # defs and re-exports both count: an oracle shared
                    # with the model stack lives once and is re-exported
                    ref_names = {n.name for n in ast.walk(rtree)
                                 if isinstance(n, ast.FunctionDef)
                                 and n.name.endswith("_ref")}
                    for n in ast.walk(rtree):
                        if isinstance(n, ast.ImportFrom):
                            ref_names.update(
                                (a.asname or a.name) for a in n.names
                                if (a.asname or a.name).endswith("_ref"))
                except SyntaxError:
                    ref_names = set()
                if not ref_names:
                    yield Finding(
                        "RPL005", f"{pkg_rel}/ref.py", 1, 0,
                        f"ref.py in `{pkg.name}` defines no `*_ref` "
                        f"oracle function")
            if test_sf is not None and test_sf.tree is not None:
                imported = _ref_imports_from(
                    test_sf.tree, pkg_module=_pkg_module(man.kernels_root,
                                                        pkg.name))
                if ref.is_file() and ref_names and not (imported & ref_names):
                    yield Finding(
                        "RPL005", init_rel, 1, 0,
                        f"no `*_ref` oracle from `{pkg.name}` is imported "
                        f"by {man.kernel_test_file} — kernel has no parity "
                        f"test")

    # --- shape-alignment half: scan kernel modules ---
    prefix = man.kernels_root.rstrip("/") + "/"
    for sf in project.files:
        if sf.tree is None or not sf.rel.startswith(prefix):
            continue
        consts = module_int_constants(sf.tree)
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                yield from _check_shape_call(sf, node, man.lane, consts)


def _pkg_module(kernels_root: str, pkg_name: str) -> str:
    """`src/repro/kernels` + `sched_score` -> `repro.kernels.sched_score`."""
    parts = Path(kernels_root).parts
    if parts and parts[0] == "src":
        parts = parts[1:]
    return ".".join(parts + (pkg_name,))


def _ref_imports_from(tree: ast.Module, pkg_module: str) -> set[str]:
    """Names ending in `_ref` imported (directly or via the package's
    ref module) from `pkg_module` anywhere in the test file."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module and \
                (node.module == pkg_module
                 or node.module.startswith(pkg_module + ".")):
            for a in node.names:
                if a.name.endswith("_ref"):
                    out.add(a.name)
    return out
