"""Load the ``[tool.reprolint]`` manifest from pyproject.toml.

The manifest declares which modules are bit-exactness-critical (RPL001
only fires there), the names `pinned`-discipline applies to, where the
Pallas kernel packages live, and callables whose donated positions the
dataflow rule can't see locally (bound methods built at runtime).

Parsing prefers tomllib (3.11+), falls back to tomli, and finally to a
minimal line-oriented parser good enough for the subset this manifest
uses — the linter must run on a bare CI interpreter with no installs.
"""
from __future__ import annotations

import dataclasses
import re
from pathlib import Path
from typing import Any

try:  # Python 3.11+
    import tomllib as _toml
except ImportError:  # pragma: no cover - depends on interpreter
    try:
        import tomli as _toml  # type: ignore[no-redef]
    except ImportError:
        _toml = None  # type: ignore[assignment]

DEFAULTS: dict[str, Any] = {
    "critical-modules": [],
    "pinned-names": ["pinned"],
    "sensitive-names": [],
    "kernels-root": "src/repro/kernels",
    "kernel-test-file": "tests/test_kernels.py",
    "lane": 128,
    "donating-callables": {},
}


@dataclasses.dataclass(frozen=True)
class Manifest:
    critical_modules: tuple[str, ...]
    pinned_names: tuple[str, ...]
    sensitive_names: tuple[str, ...]
    kernels_root: str
    kernel_test_file: str
    lane: int
    # dotted callable name -> donated positional indices, for donating
    # call sites the per-module analysis can't resolve statically
    donating_callables: dict[str, tuple[int, ...]]

    def is_critical(self, rel: str) -> bool:
        return any(rel.endswith(m) for m in self.critical_modules)


def _fallback_parse(text: str) -> dict[str, Any]:
    """Minimal TOML subset: [section] headers, key = value with string /
    int / flat array-of-{string,int} values. Enough for [tool.reprolint]
    when no real TOML parser is importable."""
    data: dict[str, Any] = {}
    section: dict[str, Any] = data
    buf = ""
    key = ""
    for raw in text.splitlines():
        line = raw.strip()
        if buf:  # continuation of a multi-line array
            buf += " " + line
            if _balanced(buf):
                section[key] = _parse_value(buf)
                buf = ""
            continue
        if not line or line.startswith("#"):
            continue
        m = re.match(r"\[([^\]]+)\]$", line)
        if m:
            section = data
            for part in m.group(1).split("."):
                section = section.setdefault(part.strip().strip('"'), {})
            continue
        if "=" in line:
            key, _, val = line.partition("=")
            key = key.strip().strip('"')
            val = val.strip()
            if val.startswith("[") and not _balanced(val):
                buf = val
            else:
                section[key] = _parse_value(val)
    return data


def _balanced(s: str) -> bool:
    return s.count("[") == s.count("]")


def _parse_value(val: str) -> Any:
    val = val.split("#", 1)[0].strip() if not val.startswith('"') else val
    if val.startswith("["):
        inner = val.strip()[1:-1]
        items = [s.strip() for s in inner.split(",") if s.strip()]
        return [_parse_value(s) for s in items]
    if val.startswith('"') or val.startswith("'"):
        return val[1:-1]
    if val in ("true", "false"):
        return val == "true"
    try:
        return int(val)
    except ValueError:
        return val


def load_manifest(root: Path) -> Manifest:
    """Read [tool.reprolint] from <root>/pyproject.toml (defaults if
    absent)."""
    pyproject = root / "pyproject.toml"
    table: dict[str, Any] = {}
    if pyproject.is_file():
        text = pyproject.read_text(encoding="utf-8")
        if _toml is not None:
            data = _toml.loads(text)
        else:  # pragma: no cover - no-TOML interpreter
            data = _fallback_parse(text)
        table = data.get("tool", {}).get("reprolint", {})
    cfg = dict(DEFAULTS)
    cfg.update(table)
    donating: dict[str, tuple[int, ...]] = {}
    for name, positions in dict(cfg["donating-callables"]).items():
        donating[name] = tuple(int(p) for p in positions)
    return Manifest(
        critical_modules=tuple(cfg["critical-modules"]),
        pinned_names=tuple(cfg["pinned-names"]),
        sensitive_names=tuple(cfg["sensitive-names"]),
        kernels_root=str(cfg["kernels-root"]),
        kernel_test_file=str(cfg["kernel-test-file"]),
        lane=int(cfg["lane"]),
        donating_callables=donating,
    )


def manifest_for_tests(**overrides: Any) -> Manifest:
    """Construct a Manifest from keyword overrides (fixture tests)."""
    cfg = dict(DEFAULTS)
    for k, v in overrides.items():
        cfg[k.replace("_", "-")] = v
    donating = {n: tuple(p) for n, p in dict(cfg["donating-callables"]).items()}
    return Manifest(
        critical_modules=tuple(cfg["critical-modules"]),
        pinned_names=tuple(cfg["pinned-names"]),
        sensitive_names=tuple(cfg["sensitive-names"]),
        kernels_root=str(cfg["kernels-root"]),
        kernel_test_file=str(cfg["kernel-test-file"]),
        lane=int(cfg["lane"]),
        donating_callables=donating,
    )
