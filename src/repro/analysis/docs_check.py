"""Docs-freshness gate: README claims must match the repo.

Documentation rots in two predictable ways: a quickstart names a make
target that was renamed, or a subsystem map points at a module that
moved.  This checker (wired into ``make lint``) parses README.md and
fails on either:

* every `` `make <target>` `` mentioned in README.md must be a real
  target in the Makefile;
* every backticked module/file path (``src/...``, ``tests/...``,
  ``benchmarks/...``, ``docs/...``, or a dotted ``repro.*`` module)
  must exist on disk.

Stdlib only — no third-party imports — so it runs in any environment
the test suite runs in.  Exit 0 when fresh, 1 with a per-claim report
when stale.
"""
from __future__ import annotations

import os
import re
import sys

_REPO = os.path.abspath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", ".."))

_MAKE_RE = re.compile(r"`make\s+([A-Za-z0-9_.-]+)`")
_PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|docs)/[A-Za-z0-9_./-]+|[A-Za-z0-9_/.-]+\.md)`")
_MODULE_RE = re.compile(r"`(repro(?:\.[A-Za-z0-9_]+)+)`")
_TARGET_RE = re.compile(r"^([A-Za-z0-9_.-]+):", re.MULTILINE)


def _module_exists(dotted: str) -> bool:
    stem = os.path.join(_REPO, "src", *dotted.split("."))
    return os.path.isfile(stem + ".py") or os.path.isdir(stem)


def check(readme: str = "README.md") -> list[str]:
    """Returns the list of stale claims (empty means fresh)."""
    readme_path = os.path.join(_REPO, readme)
    if not os.path.isfile(readme_path):
        return [f"{readme} does not exist"]
    with open(readme_path) as f:
        text = f.read()
    with open(os.path.join(_REPO, "Makefile")) as f:
        targets = set(_TARGET_RE.findall(f.read()))
    stale = []
    for t in _MAKE_RE.findall(text):
        if t not in targets:
            stale.append(f"{readme}: `make {t}` is not a Makefile target")
    for p in _PATH_RE.findall(text):
        if not os.path.exists(os.path.join(_REPO, p)):
            stale.append(f"{readme}: path `{p}` does not exist")
    for m in _MODULE_RE.findall(text):
        if not _module_exists(m):
            stale.append(f"{readme}: module `{m}` does not exist")
    return stale


def main() -> int:
    stale = check()
    if stale:
        print("docs_check: stale documentation claims:")
        for s in stale:
            print(f"  {s}")
        return 1
    print("docs_check: README claims match the repo")
    return 0


if __name__ == "__main__":
    sys.exit(main())
