"""Rule registry for reprolint.

A rule is a callable ``(project: Project) -> Iterable[Finding]``
registered under its ID with the `rule` decorator. Project-scope rules
see every file at once (RPL005 checks package structure across the
tree); most rules just loop over ``project.files``.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Iterable, Optional

from repro.analysis.manifest import Manifest
from repro.analysis.walker import Finding, SourceFile

RuleFn = Callable[["Project"], Iterable[Finding]]

_RULES: dict[str, tuple[str, RuleFn]] = {}


def rule(rule_id: str, summary: str) -> Callable[[RuleFn], RuleFn]:
    def deco(fn: RuleFn) -> RuleFn:
        if rule_id in _RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        _RULES[rule_id] = (summary, fn)
        return fn
    return deco


def all_rules() -> dict[str, tuple[str, RuleFn]]:
    return dict(_RULES)


@dataclasses.dataclass
class Project:
    """Everything one lint invocation sees: parsed files + manifest."""

    root: Path
    files: list[SourceFile]
    manifest: Manifest

    def file(self, rel: str) -> Optional[SourceFile]:
        for sf in self.files:
            if sf.rel == rel:
                return sf
        return None

    def run(self, only: Optional[set[str]] = None) -> list[Finding]:
        """Run every registered rule; suppression is applied here so
        rules never have to think about it. Suppressed findings are
        kept (marked) so --show-suppressed can list them."""
        out: list[Finding] = []
        for rule_id, (_summary, fn) in sorted(_RULES.items()):
            if only is not None and rule_id not in only:
                continue
            for f in fn(self):
                sf = self.file(f.path)
                if sf is not None and sf.is_suppressed(f.rule, f.line):
                    f = dataclasses.replace(f, suppressed=True)
                out.append(f)
        out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
        return out
