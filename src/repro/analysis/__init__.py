"""reprolint: AST-based invariant checker for the repro codebase.

Entry point: ``python -m repro.analysis.lint src tests benchmarks``.
Rules live in `repro.analysis.rules`; configuration in the
``[tool.reprolint]`` table of pyproject.toml. Stdlib-only by design —
the lint pass runs in CI before jax/numpy install.
"""
from repro.analysis.walker import Finding, SourceFile

__all__ = ["Finding", "SourceFile"]
