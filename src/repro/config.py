"""Framework configuration system.

`ModelConfig` is the single source of truth for an architecture; every
assigned arch in `repro.configs` constructs one (exact) plus a reduced
`smoke()` variant.  `ShapeConfig` describes the assigned input shapes
(train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False      # Arctic: dense MLP in parallel w/ MoE
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2                   # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128                  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attention-free
    n_kv: int
    d_ff: int
    vocab: int
    # attention flavor
    rope: bool = True
    rope_fraction: float = 1.0        # stablelm: rotary on 25% of head dim
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    out_bias: bool = False
    mlp_bias: bool = False
    sliding_window: int = 0           # 0 = full attention
    global_layers: tuple = ()         # hybrid: layers that keep full attn
    # body flavor
    activation: str = "silu_gated"    # silu_gated | sq_relu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    # mixtures / state-space
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # modality frontend stub: number of prefix embedding positions the
    # (unimplemented, per assignment carve-out) encoder would provide
    prefix_len: int = 0
    # numerics
    dtype: str = "bfloat16"
    # cost-accounting aid: unroll the layer scan so XLA's cost_analysis
    # counts every layer (lax.scan bodies are otherwise counted ONCE);
    # used by the roofline layer probes, never in production lowering
    scan_unroll: bool = False
    # variant bookkeeping (e.g. long_500k sliding-window variants)
    variant_note: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the embedding / LM-head
        can shard over a 16-way tensor axis with MXU-aligned tiles.
        (§Perf/internvl2-train: vocab 151,655 is odd — unshardable logits
        made the LM head dominate per-device bytes AND collectives.)
        Padded logit columns are masked to -inf in the head."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return dataclasses.replace(
            self,
            sliding_window=window,
            variant_note=f"sliding-window({window}) variant for long-context decode",
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if not self.attention_free and self.arch_type != "hybrid":
            hd = self.head_dim
            per_layer += d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd)
            per_layer += (self.n_heads * hd) * d
        gate_mult = 3 if self.activation == "silu_gated" else 2
        if self.moe:
            expert = gate_mult * d * ff
            per_layer += self.moe.n_experts * expert + d * self.moe.n_experts
            if self.moe.dense_residual:
                per_layer += gate_mult * d * ff
        elif ff > 0:
            per_layer += gate_mult * d * ff
        if self.ssm:
            di, ds = self.d_inner, self.ssm.d_state
            nh = self.n_ssm_heads
            per_layer += d * (2 * di + 2 * ds + nh) + di * d
            per_layer += self.ssm.conv_width * (di + 2 * ds)
        if self.arch_type == "hybrid":
            hd = self.head_dim
            per_layer += d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd)
            per_layer += (self.n_heads * hd) * d
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top_k experts."""
        if not self.moe:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        gate_mult = 3 if self.activation == "silu_gated" else 2
        inactive = L * (self.moe.n_experts - self.moe.top_k) * gate_mult * d * ff
        return self.param_count() - inactive


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# --------------------------------------------------------------------------
# Training / serving knobs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1             # grad accumulation (perf knob)
    remat: bool = True
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 2048
    max_batch: int = 8
    temperature: float = 0.0
    eos_id: int = 1
