from repro.data.pipeline import DataConfig, synthetic_stream, make_batches  # noqa: F401
