"""Synthetic LM data pipeline: seeded structured token streams (Zipf
unigram + local bigram structure so the loss actually decreases), packed
into (tokens, labels) batches, host-shardable by rank."""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    zipf_a: float = 1.2
    rank: int = 0
    world: int = 1


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def synthetic_stream(cfg: DataConfig) -> Iterator[np.ndarray]:
    """Yields (batch, seq_len+1) int32 arrays. Structure: Zipf-distributed
    unigrams with a deterministic "grammar" (each token is followed by a
    fixed successor 60% of the time) so next-token prediction has signal."""
    rng = np.random.default_rng(cfg.seed + 1009 * cfg.rank)
    probs = _zipf_probs(cfg.vocab, cfg.zipf_a)
    successor = rng.permutation(cfg.vocab)
    while True:
        u = rng.choice(cfg.vocab, size=(cfg.batch, cfg.seq_len + 1), p=probs)
        out = u.copy()
        follow = rng.random((cfg.batch, cfg.seq_len)) < 0.6
        out[:, 1:] = np.where(follow, successor[out[:, :-1]], u[:, 1:])
        yield out.astype(np.int32)


def make_batches(cfg: DataConfig) -> Iterator[dict]:
    """(tokens, labels) next-token pairs, host-sharded by (rank, world)."""
    for chunk in synthetic_stream(cfg):
        yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
