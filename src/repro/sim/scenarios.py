"""Scenario registry: named nonstationary workload + provider regimes.

The paper's headline claims are regime-dependent — balanced vs
high-congestion vs heavy-dominated mixes separate the policies on
completion, tail, and shedding — and related work (adaptively robust
inference optimization; queueing with predictions) argues nonstationary
arrivals are where prediction-aware policies earn their keep.  A
`Scenario` is a *static, hashable* spec composing:

  * **arrival shape** — piecewise-constant phases `(frac, rate_mult,
    mix)` over the scenario's arrival span: burst trains, diurnal ramps,
    flash crowds, heavy-dominated phase shifts;
  * **provider dynamics** — brownout windows (comfort-concurrency drops
    mid-run) and per-class token-bucket rate limits with 429-style
    bounces (sim/provider.ProviderDynamics), optionally with
    time-varying refill (`tb_windows`: the sustained rate itself
    tightens and recovers mid-run).

Because the spec is hashable (tuples of floats/strings) it rides jit as
a static argument; `build()` materializes the `(T,)`-shaped schedule
arrays *inside* the jit boundary, so the engine's `lax.scan` shape is
O(1) in scenario complexity and which mechanisms exist is decided at
trace time (None = off).

Phases are laid over the scenario's expected stationary arrival span
(`n_requests / base_rate`), not the raw sim horizon — the horizon
includes drain time, and phases must land on the traffic.  Registry
scenarios keep the frac-weighted mean rate multiplier at 1.0 so every
phase is populated in expectation and total offered work matches the
stationary regime of the same name.

The `balanced` scenario is the stationary anchor: its schedule is the
trivial one-phase identity and it configures no provider dynamics, so
it reproduces plain `generate` + `run_sim` *bit-exactly*
(tests/test_scenarios.py pins this).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.sim.faults import FaultSchedule
from repro.sim.provider import (
    Fleet,
    FleetDynamics,
    ProviderDynamics,
    ProviderPhysics,
    availability_schedule,
    brownout_schedule,
    fleet_brownout_schedule,
    token_bucket_schedule,
    token_bucket_windows,
    uniform_fleet_physics,
)
from repro.sim.workload import (
    MIXES,
    ArrivalSchedule,
    WorkloadConfig,
    arrival_rate,
    n_classes_of,
)


class Phase(NamedTuple):
    """One arrival phase: a fraction of the arrival span at a rate
    multiplier, optionally overriding the bucket mix."""

    frac: float
    rate_mult: float = 1.0
    mix: Optional[str] = None  # None = the scenario's base mix


class FleetSpec(NamedTuple):
    """Static (P,) fleet spec riding a `Scenario` (hashable, all tuples).

    Describes the endpoint axis: how many endpoints, how their physics
    skew, and the per-endpoint incidents — failure windows (the
    failover mechanism), brownouts, and a per-endpoint rate limiter.
    `build_fleet` materializes the `(T, P)` schedules inside the jit
    boundary, mirroring `build_dynamics`.
    """

    p: int = 4
    # per-endpoint ms/token multiplier (< 1 = faster) and comfort-knee
    # multiplier; None = uniform fleet
    speed_mult: Optional[tuple[float, ...]] = None
    comfort_mult: Optional[tuple[float, ...]] = None
    # (endpoint, start_frac, end_frac) hard-down windows over the
    # arrival span: in-flight work is killed and requeued (failover)
    fail_windows: tuple[tuple[int, float, float], ...] = ()
    # (endpoint, start_frac, end_frac, comfort_scale) per-endpoint
    # brownouts
    brownouts: tuple[tuple[int, float, float, float], ...] = ()
    # per-endpoint per-class sustained grant rate; None disables the
    # (P, K) bucket grid
    tb_rate_rps: Optional[float] = None
    tb_burst: float = 6.0
    retry_after_ms: float = 1500.0


class Scenario(NamedTuple):
    """Static scenario spec.  Hashable — usable as a jit static arg."""

    name: str
    mix: str = "balanced"
    congestion: str = "medium"
    phases: tuple[Phase, ...] = (Phase(1.0),)
    # brownout windows: (start_frac, end_frac, comfort_scale) over the
    # arrival span; comfort_scale < 1 shrinks provider capacity inside
    brownouts: tuple[tuple[float, float, float], ...] = ()
    # per-class token-bucket rate limit (sustained grants/sec); a scalar
    # applies to every class, None disables the limiter
    tb_rate_rps: Optional[float | tuple[float, ...]] = None
    tb_burst: float = 6.0
    retry_after_ms: float = 1500.0
    # time-varying refill: (start_frac, end_frac, rate_mult) windows over
    # the arrival span scaling the sustained rate (0 = refill freeze);
    # overlaps compound by minimum — see provider.token_bucket_windows
    tb_windows: tuple[tuple[float, float, float], ...] = ()
    # (P,) provider fleet (DESIGN.md §10); None = single provider.
    # Fleet scenarios use FleetDynamics, not ProviderDynamics, so
    # `has_dynamics` stays False and `fleet`/`dynamics` never coexist.
    fleet: Optional[FleetSpec] = None
    # contract-breaking transport faults (sim/faults.py): silent drops,
    # stuck requests, duplicate deliveries, lying Retry-After.  Live-path
    # only — MockProvider/FleetProvider inject them; the engine's closed
    # simulator keeps the honest transport.  None = honest provider.
    fault_schedule: Optional[FaultSchedule] = None

    @property
    def faults(self) -> Optional[FaultSchedule]:
        """Injecting fault schedule, or None (a schedule whose knobs are
        all neutral is treated as absent — the provider then builds the
        exact pre-fault program)."""
        fs = self.fault_schedule
        return fs if fs is not None and fs.injects else None

    @property
    def has_dynamics(self) -> bool:
        return bool(self.brownouts) or self.tb_rate_rps is not None


def arrival_span_ms(sc: Scenario, n_requests: int,
                    arrival_scale: float = 1.0) -> float:
    """Expected stationary arrival span the phases are laid over.
    `arrival_scale` multiplies the offered rate (scale runs compress a
    large population into the nominal span instead of stretching the
    horizon with N)."""
    return n_requests / (
        arrival_rate(sc.mix, sc.congestion) * arrival_scale) * 1000.0


def phase_edges_ms(sc: Scenario, n_requests: int,
                   arrival_scale: float = 1.0) -> jnp.ndarray:
    """(P+1,) wall-clock phase boundaries — the metric windows."""
    span = arrival_span_ms(sc, n_requests, arrival_scale)
    fracs = jnp.asarray([p.frac for p in sc.phases], jnp.float32)
    return jnp.concatenate(
        [jnp.zeros((1,), jnp.float32), jnp.cumsum(fracs) * span]
    )


def build_arrival_schedule(sc: Scenario, n_requests: int,
                           arrival_scale: float = 1.0) -> ArrivalSchedule:
    """Materialize the piecewise schedule arrays from the static spec."""
    total = sum(p.frac for p in sc.phases)
    if abs(total - 1.0) > 1e-6:
        raise ValueError(
            f"scenario {sc.name!r}: phase fracs must sum to 1, got {total}")
    span = arrival_span_ms(sc, n_requests, arrival_scale)
    t0, cum_work = [], []
    t = w = 0.0
    for p in sc.phases:
        if p.rate_mult <= 0:
            raise ValueError(
                f"scenario {sc.name!r}: rate_mult must be > 0, got "
                f"{p.rate_mult}")
        t0.append(t)
        cum_work.append(w)
        t += p.frac * span
        w += p.rate_mult * p.frac * span
    mix_w = jnp.stack(
        [MIXES[p.mix if p.mix is not None else sc.mix] for p in sc.phases]
    )
    return ArrivalSchedule(
        t0_ms=jnp.asarray(t0, jnp.float32),
        cum_work_ms=jnp.asarray(cum_work, jnp.float32),
        rate_mult=jnp.asarray([p.rate_mult for p in sc.phases], jnp.float32),
        mix_w=mix_w,
        mix_varies=any(p.mix is not None and p.mix != sc.mix
                       for p in sc.phases),
    )


def build_dynamics(
    sc: Scenario, n_ticks: int, dt_ms: float, n_requests: int, k: int,
    arrival_scale: float = 1.0,
) -> ProviderDynamics | None:
    """Materialize the (T,)-shaped provider schedules; None when the
    scenario configures no dynamics (the engine then compiles the exact
    stationary program)."""
    if not sc.has_dynamics:
        return None
    span = arrival_span_ms(sc, n_requests, arrival_scale)
    comfort = (
        brownout_schedule(n_ticks, dt_ms, sc.brownouts, span)
        if sc.brownouts else None
    )
    refill = capacity = retry = None
    if sc.tb_rate_rps is not None:
        rate = sc.tb_rate_rps
        rate_k = tuple([float(rate)] * k) if isinstance(rate, (int, float)) \
            else tuple(float(r) for r in rate)
        if len(rate_k) != k:
            raise ValueError(
                f"scenario {sc.name!r}: tb_rate_rps has {len(rate_k)} "
                f"classes but the run carries {k}")
        if sc.tb_windows:
            refill, capacity = token_bucket_windows(
                n_ticks, dt_ms, rate_k, sc.tb_burst, sc.tb_windows, span)
        else:
            refill, capacity = token_bucket_schedule(
                n_ticks, dt_ms, rate_k, sc.tb_burst)
        retry = jnp.float32(sc.retry_after_ms)
    return ProviderDynamics(
        comfort_scale=comfort,
        tb_refill=refill,
        tb_capacity=capacity,
        retry_after_ms=retry,
    )


def build_fleet(
    sc: Scenario, phys: ProviderPhysics, n_ticks: int, dt_ms: float,
    n_requests: int, k: int, arrival_scale: float = 1.0,
) -> Fleet | None:
    """Materialize the (T, P)-shaped fleet schedules from the static
    spec; None when the scenario carries no fleet (the engine then
    compiles the exact single-provider program).  `phys` is the base
    physics the fleet skews from — the same reference physics the
    tail EMA is computed against."""
    fs = sc.fleet
    if fs is None:
        return None
    span = arrival_span_ms(sc, n_requests, arrival_scale)
    fphys = uniform_fleet_physics(phys, fs.p, fs.speed_mult, fs.comfort_mult)
    avail = (
        availability_schedule(n_ticks, dt_ms, fs.fail_windows, span, fs.p)
        if fs.fail_windows else None
    )
    comfort = (
        fleet_brownout_schedule(n_ticks, dt_ms, fs.brownouts, span, fs.p)
        if fs.brownouts else None
    )
    refill = capacity = None
    if fs.tb_rate_rps is not None:
        refill1, cap1 = token_bucket_schedule(
            n_ticks, dt_ms, (float(fs.tb_rate_rps),) * k, fs.tb_burst)
        # every endpoint gets its own copy of the per-class budget — the
        # fleet-wide sustained rate is P times the single-provider one
        refill = jnp.broadcast_to(
            refill1[:, None, :], (n_ticks, fs.p, k))
        capacity = jnp.broadcast_to(cap1[None, :], (fs.p, k))
    return Fleet(
        phys=fphys,
        dyn=FleetDynamics(
            avail=avail,
            comfort_scale=comfort,
            tb_refill=refill,
            tb_capacity=capacity,
            retry_after_ms=jnp.float32(fs.retry_after_ms),
        ),
    )


def build(
    sc: Scenario,
    n_requests: int,
    n_ticks: int,
    dt_ms: float,
    class_map: str = "paper2",
    information: str = "coarse",
    limiter_classes: int | None = None,
    arrival_scale: float = 1.0,
) -> tuple[WorkloadConfig, ArrivalSchedule, ProviderDynamics | None,
           jnp.ndarray]:
    """One-stop materialization: (workload cfg, arrival schedule,
    provider dynamics, metric phase edges).  Call inside the jit
    boundary with a static `sc`.

    `limiter_classes` sizes the token-bucket vectors; pass the *policy*
    class count when it exceeds the lane scheme's (the engine's bucket
    state is sized by the policy).  Defaults to the lane scheme's K.

    `arrival_scale` multiplies the offered rate uniformly: the arrival
    span, phase edges, brownout windows, and token-bucket schedules all
    compress together, so a population of N at scale s sees the same
    scenario shape over span/s — the knob the N=1e6 scale sweep uses to
    keep the horizon fixed while the population grows.
    """
    wl_cfg = WorkloadConfig(
        n_requests=n_requests,
        mix=sc.mix,
        congestion=sc.congestion,
        information=information,
        class_map=class_map,
        arrival_scale=arrival_scale,
    )
    sched = build_arrival_schedule(sc, n_requests, arrival_scale)
    k = limiter_classes if limiter_classes is not None \
        else n_classes_of(class_map)
    dynamics = build_dynamics(sc, n_ticks, dt_ms, n_requests, k,
                              arrival_scale)
    return wl_cfg, sched, dynamics, phase_edges_ms(sc, n_requests,
                                                   arrival_scale)


# ---------------------------------------------------------------------------
# The registry.  Mean rate multiplier is 1.0 in every scenario (offered
# work matches the stationary regime; all phases populated in
# expectation); burstiness lives in the phase-to-phase ratios.
# ---------------------------------------------------------------------------

_QUIET, _BURST = 0.4, 1.6  # burst train: 4x rate swing, mean 1.0

SCENARIOS: dict[str, Scenario] = {
    # stationary anchors — `balanced` is pinned bit-exact vs run_sim
    "balanced": Scenario("balanced"),
    "high_congestion": Scenario("high_congestion", congestion="high"),
    # alternating quiet/burst epochs (queueing-with-predictions style)
    "burst_train": Scenario(
        "burst_train",
        phases=tuple(
            Phase(0.125, m) for m in (_QUIET, _BURST) * 4
        ),
    ),
    # diurnal ramp: trough -> peak -> trough, peak 5x the trough rate
    "diurnal": Scenario(
        "diurnal",
        phases=tuple(
            Phase(1.0 / 7.0, m)
            for m in (0.4, 0.8, 1.3, 2.0, 1.3, 0.8, 0.4)
        ),
    ),
    # heavy-dominated phase shift: token mix flips mid-run while the
    # request rate holds, overloading the provider through work, not count
    "heavy_shift": Scenario(
        "heavy_shift",
        phases=(
            Phase(0.4, 1.0),
            Phase(0.3, 1.0, mix="heavy"),
            Phase(0.3, 1.0),
        ),
    ),
    # flash crowd: short 4.3x spike over a calm baseline
    "flash_crowd": Scenario(
        "flash_crowd",
        phases=(Phase(0.45, 0.75), Phase(0.1, 3.25), Phase(0.45, 0.75)),
    ),
    # brownout: stationary high congestion, provider loses 60% of its
    # comfort capacity for the middle third of the run
    "brownout": Scenario(
        "brownout",
        congestion="high",
        phases=(Phase(1 / 3), Phase(1 / 3), Phase(1 / 3)),
        brownouts=((1 / 3, 2 / 3, 0.4),),
    ),
    # provider-boundary rate limit: sustained per-class grant budget well
    # under the offered rate, bursts absorbed by the bucket then 429'd
    "rate_limited": Scenario(
        "rate_limited",
        congestion="high",
        phases=(Phase(0.25, _QUIET), Phase(0.25, _BURST),
                Phase(0.25, _QUIET), Phase(0.25, _BURST)),
        tb_rate_rps=0.5,
        tb_burst=6.0,
    ),
    # rate crunch: steady traffic into a limiter whose *sustained* rate
    # collapses to 10% for the middle third of the run (ROADMAP's
    # time-varying token-bucket item) — unlike `rate_limited`, where the
    # clients outrun a fixed budget, here the provider moves the budget:
    # the bucket drains on the old rhythm, 429s spike, and recovery
    # behavior after the window lifts is what separates retry policies
    "rate_crunch": Scenario(
        "rate_crunch",
        congestion="high",
        phases=(Phase(1 / 3), Phase(1 / 3), Phase(1 / 3)),
        tb_rate_rps=1.2,
        tb_burst=6.0,
        tb_windows=((1 / 3, 2 / 3, 0.1),),
    ),
    # the perfect storm: flash crowd into a browned-out, rate-limited
    # provider — every mechanism at once
    "storm": Scenario(
        "storm",
        congestion="high",
        phases=(Phase(0.3, 0.7), Phase(0.2, 2.2), Phase(0.5, 0.7)),
        brownouts=((0.3, 0.5, 0.5),),
        tb_rate_rps=0.8,
        tb_burst=8.0,
    ),
    # endpoint failure mid-run: a 4-endpoint fleet loses endpoint 0 for
    # the middle third of the traffic — its in-flight work is killed and
    # requeued, the router steers around the hole, and the fleet_sweep
    # benchmark's recovery bar (post-failover completion >= 99% of
    # pre-failover) rides this scenario
    "fleet_failover": Scenario(
        "fleet_failover",
        congestion="high",
        phases=(Phase(0.35), Phase(0.30), Phase(0.35)),
        fleet=FleetSpec(p=4, fail_windows=((0, 0.35, 0.65),)),
    ),
    # skewed fleet: one fast endpoint, two nominal, one slow (2x
    # ms/token) — the routing layer's cost model, not round-robin,
    # decides how load splits
    "fleet_skew": Scenario(
        "fleet_skew",
        congestion="high",
        fleet=FleetSpec(p=4, speed_mult=(0.5, 1.0, 1.0, 2.0)),
    ),
    # per-endpoint brownout: two endpoints lose most of their comfort
    # capacity in staggered windows while the others hold — latency
    # pressure the router can only see through its own inflight counts
    "fleet_brownout": Scenario(
        "fleet_brownout",
        congestion="high",
        phases=(Phase(1 / 3), Phase(1 / 3), Phase(1 / 3)),
        fleet=FleetSpec(
            p=4,
            brownouts=((0, 1 / 3, 2 / 3, 0.3), (1, 0.5, 0.85, 0.3)),
        ),
    ),
    # ---- chaos scenarios (live-path only; benchmarks/fault_sweep.py).
    # The provider breaks the transport contract and the fault_sweep
    # recovery bar (resilience-on completion >= 0.99, resilience-off
    # demonstrably degraded) rides these.  scenario_sweep skips them:
    # the engine's closed simulator models an honest transport.
    #
    # silent drop: 15% of accepted requests never produce a completion —
    # without the watchdog each drop pins an INFLIGHT window slot forever
    "silent_drop": Scenario(
        "silent_drop",
        fault_schedule=FaultSchedule(seed=11, drop_frac=0.15),
    ),
    # stuck tail: 12% of accepted requests take 400x their honest
    # service time — far past any timeout horizon, so an un-watched
    # session just waits; a resubmitted attempt races the stuck one
    # and wins
    "stuck_tail": Scenario(
        "stuck_tail",
        fault_schedule=FaultSchedule(seed=15, stuck_frac=0.12,
                                     stuck_mult=400.0),
    ),
    # dup storm: 30% of completions delivered 2 extra times with skewed
    # finish stamps, on top of a rate limiter whose Retry-After hints lie
    # low (0.25x) — exercises dup-safe ingestion and hint sanitization
    "dup_storm": Scenario(
        "dup_storm",
        tb_rate_rps=1.5,
        tb_burst=6.0,
        fault_schedule=FaultSchedule(seed=13, dup_frac=0.3, dup_extra=2,
                                     dup_delay_ms=120.0, dup_jitter_ms=7.0,
                                     retry_lie_mult=0.25),
    ),
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}"
        ) from None


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)
