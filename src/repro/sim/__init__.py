"""JAX discrete-event simulation of the black-box provider boundary."""
from repro.sim.engine import SimConfig, run_sim  # noqa: F401
from repro.sim.faults import FaultSchedule, fault_draw  # noqa: F401
from repro.sim.metrics import (  # noqa: F401
    PhaseMetrics,
    SimMetrics,
    compute_metrics,
    compute_phase_metrics,
)
from repro.sim.provider import (  # noqa: F401
    Fleet,
    FleetDynamics,
    FleetPhysics,
    ProviderDynamics,
    ProviderPhysics,
    default_physics,
    uniform_fleet_physics,
)
from repro.sim.runner import (  # noqa: F401
    run_cell,
    run_scenario_cell,
    summarize,
    window_for,
)
from repro.sim.scenarios import (  # noqa: F401
    SCENARIOS,
    FleetSpec,
    Phase,
    Scenario,
    build_fleet,
    get_scenario,
    list_scenarios,
)
from repro.sim.workload import REGIMES, WorkloadConfig, generate  # noqa: F401
