"""JAX discrete-event simulation of the black-box provider boundary."""
from repro.sim.engine import SimConfig, run_sim  # noqa: F401
from repro.sim.metrics import SimMetrics, compute_metrics  # noqa: F401
from repro.sim.provider import ProviderPhysics, default_physics  # noqa: F401
from repro.sim.runner import run_cell, summarize  # noqa: F401
from repro.sim.workload import REGIMES, WorkloadConfig, generate  # noqa: F401
