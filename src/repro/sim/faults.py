"""FaultSchedule: the contract-breaking provider, as a static spec.

Every provider in the repo is perfectly honest by default: completions
arrive exactly once, Retry-After hints are truthful, nothing ever gets
stuck.  The paper's client sits at a *black-box* boundary, though, so
the stack's headline claims have to survive a provider that lies.  A
`FaultSchedule` is a static, hashable pytree of scalar knobs — the same
`None`-means-off pattern as `ProviderDynamics` — that `MockProvider`
and `FleetProvider` thread through their submit/poll paths to inject
four fault families:

  * **silent drops** — the completion is computed server-side but never
    delivered to the client (`drop_frac` of landed completions vanish);
  * **stuck requests** — service time inflated by `stuck_mult` (default
    40x), pushing the completion past any sane timeout horizon until
    the client resubmits;
  * **duplicate completions** — the same ticket delivered `1 +
    dup_extra` times, redeliveries lagging by `dup_delay_ms` each and
    carrying payloads whose finish stamp diverges by `dup_jitter_ms`
    per copy (at-least-once delivery with disagreeing copies);
  * **lying Retry-After** — 429 hints scaled by `retry_lie_mult`
    (under- or overstating the real token-bucket refill; negative or
    non-finite values model outright hostile hints — see
    `client.provider.sanitize_retry_after_ms`).

Fault draws are keyed deterministically per **ticket** (per RPC
attempt), not per request: a resubmitted request gets fresh draws, so
bounded-budget resubmission drives the per-request failure probability
to `frac^(1 + max_resubmits)`.  `fault_salt` decorrelates the streams
of a fleet's child endpoints.  `FaultSchedule() == no faults`;
providers built with `faults=None` trace/execute the exact pre-fault
code path (the byte-identity criterion the parity tests pin).

The recovery machinery lives in `repro.client.resilience`; the
registry scenarios riding these knobs (`silent_drop`, `stuck_tail`,
`dup_storm`) are in `sim/scenarios.py`, measured by
`benchmarks/fault_sweep.py`.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class FaultSchedule(NamedTuple):
    """Static fault spec (all scalars — hashable, usable inside a
    `Scenario`).  The default instance injects nothing."""

    seed: int = 0
    # silent drops: fraction of landed completions never delivered
    drop_frac: float = 0.0
    # stuck requests: fraction of accepted submits whose service time is
    # inflated by `stuck_mult`
    stuck_frac: float = 0.0
    stuck_mult: float = 40.0
    # duplicate completions: fraction of delivered completions redelivered
    # `dup_extra` more times, each copy `dup_delay_ms` later than the
    # last with a payload finish stamp skewed by `dup_jitter_ms` per copy
    dup_frac: float = 0.0
    dup_extra: int = 1
    dup_delay_ms: float = 100.0
    dup_jitter_ms: float = 0.0
    # lying Retry-After: multiplier on the hint a 429 bounce carries;
    # 1.0 is honest, < 1 understates the refill (clients retry too early
    # and re-bounce), > 1 overstates it (clients idle past recovery)
    retry_lie_mult: float = 1.0

    @property
    def injects(self) -> bool:
        """Whether any fault family is active (an all-default schedule
        is equivalent to `faults=None` up to dead draws)."""
        return (self.drop_frac > 0.0 or self.stuck_frac > 0.0
                or self.dup_frac > 0.0 or self.retry_lie_mult != 1.0)


class FaultDraw(NamedTuple):
    """Per-ticket fault verdicts, deterministic in
    (schedule.seed, salt, ticket)."""

    drop: bool
    stuck: bool
    dup: bool


def fault_draw(fs: FaultSchedule, salt: int, ticket: int) -> FaultDraw:
    """Draw the per-attempt fault verdicts for one ticket.

    Keyed by (seed, salt, ticket) through a `SeedSequence`, so replays
    are deterministic across platforms and independent of draw order —
    the provider may evaluate tickets in any sequence and a resubmitted
    request (fresh ticket) gets independent draws.
    """
    u = np.random.default_rng(
        np.random.SeedSequence((fs.seed, salt, ticket))).random(3)
    return FaultDraw(
        drop=bool(u[0] < fs.drop_frac),
        stuck=bool(u[1] < fs.stuck_frac),
        dup=bool(u[2] < fs.dup_frac),
    )
