"""Joint metrics (paper §4.3), with per-class vectors for K-class runs.

The paper insists these be read together: tails alone can improve "for
the wrong reason" (withheld work), so every run reports short P95,
global P95, completion rate, deadline satisfaction, useful goodput
(completed AND SLO-meeting requests per second), makespan, and the
overload action counts that make shedding legible.

The K-class generalization adds (K,)-shaped per-class vectors — P95,
completion rate, deadline satisfaction, goodput — computed with one
masked reduction over a (K, N) class mask (vmap'd percentile), keeping
the block O(1) in K inside the trace.  The seed's bucket-keyed scalars
(short/long P95 etc.) are retained so every existing table reads the
same.

Masked percentiles are computed by sorting with +inf fill so the whole
metric block stays inside jit/vmap.

The windowed extension (`compute_phase_metrics`, DESIGN.md §5) slices
every joint metric by scenario phase: requests are assigned to the
phase their *arrival* falls in, and each phase reports per-class P95,
deadline satisfaction, shed counts by ladder rung (the bucket-keyed
cost ladder), abandonment, and provider 429 bounces.  The (P, N) and
(P, K, N) masks reduce under one nested vmap, so the block stays O(1)
in P and K inside the trace.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import (
    ABANDONED,
    COMPLETED,
    REJECTED,
    RequestBatch,
    SHORT,
    SimState,
)


def masked_percentile(values: jnp.ndarray, mask: jnp.ndarray, q: float) -> jnp.ndarray:
    """Percentile of values[mask] with linear index (nearest-rank,
    matching numpy's 'lower' flavor closely enough for P95 on ~10^2
    samples). Returns NaN when mask is empty."""
    n = mask.sum()
    filled = jnp.where(mask, values, jnp.inf)
    s = jnp.sort(filled)
    idx = jnp.clip(jnp.ceil(q * n).astype(jnp.int32) - 1, 0, values.shape[0] - 1)
    out = s[idx]
    return jnp.where(n > 0, out, jnp.nan)


class SimMetrics(NamedTuple):
    short_p95_ms: jnp.ndarray
    short_p90_ms: jnp.ndarray
    long_p90_ms: jnp.ndarray      # long+xlong (paper Table 4)
    global_p95_ms: jnp.ndarray
    global_std_ms: jnp.ndarray
    completion_rate: jnp.ndarray
    satisfaction: jnp.ndarray
    goodput_rps: jnp.ndarray
    makespan_ms: jnp.ndarray
    n_rejects: jnp.ndarray
    n_defer_events: jnp.ndarray
    n_abandoned: jnp.ndarray
    mean_severity_proxy: jnp.ndarray
    # --- per-class joint metrics (K-class runs; K=2 -> lane 0 = short,
    # lane 1 = heavy under the paper2 scheme) ---
    class_p95_ms: jnp.ndarray          # (K,) f32 completed-latency P95
    class_completion_rate: jnp.ndarray # (K,) f32 over the accepted set
    class_satisfaction: jnp.ndarray    # (K,) f32 deadline-met fraction
    class_goodput_rps: jnp.ndarray     # (K,) f32 met requests / makespan
    class_n_requests: jnp.ndarray      # (K,) int32 offered per class


def compute_metrics(
    batch: RequestBatch, final: SimState, n_classes: int | None = None
) -> SimMetrics:
    if n_classes is None:
        # the deficit vector carries the run's static K — infer it so a
        # direct call can't silently merge lanes into a 2-class view
        n_classes = final.sched.deficit.shape[-1]
    req = final.req
    done = (req.status == COMPLETED) & batch.valid
    latency = req.finish_ms - batch.arrival_ms

    short_mask = done & (batch.bucket == SHORT)
    long_mask = done & (batch.bucket >= 2)

    # Explicitly rejected work is legible, client-declared shedding (paper
    # Fig. 5); CR and satisfaction are reported over the *accepted* set and
    # the reject count is carried alongside — matching the paper's cells
    # where CR = 1.00 coexists with ~5 rejects.
    rejected = (req.status == REJECTED) & batch.valid
    n_accepted = (batch.valid & ~rejected).sum()
    n_done = done.sum()
    deadline_abs = batch.arrival_ms + batch.deadline_budget_ms
    met = done & (req.finish_ms <= deadline_abs)
    n_met = met.sum()

    first_arrival = jnp.min(jnp.where(batch.valid, batch.arrival_ms, jnp.inf))
    last_finish = jnp.max(jnp.where(done, req.finish_ms, -jnp.inf))
    makespan = jnp.maximum(last_finish - first_arrival, 1.0)

    glob_lat = jnp.where(done, latency, jnp.nan)
    glob_mean = jnp.nanmean(glob_lat)
    glob_std = jnp.sqrt(jnp.nanmean((glob_lat - glob_mean) ** 2))

    # --- per-class vectors: one (K, N) masked reduction, O(1) in K ---
    cls = jnp.clip(batch.cls, 0, n_classes - 1)
    cls_kn = (
        cls[None, :] == jnp.arange(n_classes, dtype=jnp.int32)[:, None]
    ) & batch.valid[None, :]
    done_kn = cls_kn & done[None, :]
    met_kn = cls_kn & met[None, :]
    accepted_k = (cls_kn & ~rejected[None, :]).sum(axis=1)
    done_k = done_kn.sum(axis=1)
    met_k = met_kn.sum(axis=1)
    class_p95 = jax.vmap(
        lambda m: masked_percentile(latency, m, 0.95)
    )(done_kn)

    return SimMetrics(
        short_p95_ms=masked_percentile(latency, short_mask, 0.95),
        short_p90_ms=masked_percentile(latency, short_mask, 0.90),
        long_p90_ms=masked_percentile(latency, long_mask, 0.90),
        global_p95_ms=masked_percentile(latency, done, 0.95),
        global_std_ms=glob_std,
        completion_rate=n_done / jnp.maximum(n_accepted, 1),
        satisfaction=n_met / jnp.maximum(n_accepted, 1),
        goodput_rps=n_met / (makespan / 1000.0),
        makespan_ms=makespan,
        n_rejects=((req.status == REJECTED) & batch.valid).sum(),
        n_defer_events=jnp.where(batch.valid, req.n_defers, 0).sum(),
        n_abandoned=((req.status == ABANDONED) & batch.valid).sum(),
        mean_severity_proxy=final.sched.ema_latency_ratio,
        class_p95_ms=class_p95,
        class_completion_rate=done_k / jnp.maximum(accepted_k, 1),
        class_satisfaction=met_k / jnp.maximum(accepted_k, 1),
        class_goodput_rps=met_k / (makespan / 1000.0),
        class_n_requests=cls_kn.sum(axis=1).astype(jnp.int32),
    )


class PhaseMetrics(NamedTuple):
    """Per-phase joint metrics for a scenario run (leading axis = phase).

    Requests belong to the phase their arrival falls in (arrivals past
    the last edge clip into the final phase).  Counts are over offered
    requests; rates are over the phase's accepted set, mirroring the
    aggregate `SimMetrics` conventions.
    """

    phase_start_ms: jnp.ndarray       # (P,) f32 window left edges
    n_arrived: jnp.ndarray            # (P,) int32 offered per phase
    n_completed: jnp.ndarray          # (P,) int32
    n_abandoned: jnp.ndarray          # (P,) int32 implicit failures
    n_throttled: jnp.ndarray          # (P,) int32 provider 429 bounces
    shed_by_bucket: jnp.ndarray       # (P, 4) int32 rejects per ladder rung
    satisfaction: jnp.ndarray         # (P,) f32 deadline-met / accepted
    p95_ms: jnp.ndarray               # (P,) f32 completed-latency P95
    class_p95_ms: jnp.ndarray         # (P, K) f32
    class_satisfaction: jnp.ndarray   # (P, K) f32


def compute_phase_metrics(
    batch: RequestBatch,
    final: SimState,
    edges_ms: jnp.ndarray,
    n_classes: int | None = None,
) -> PhaseMetrics:
    """Windowed metrics over the (P+1,) phase boundaries `edges_ms`."""
    if n_classes is None:
        n_classes = final.sched.deficit.shape[-1]
    n_phases = edges_ms.shape[0] - 1
    req = final.req
    done = (req.status == COMPLETED) & batch.valid
    rejected = (req.status == REJECTED) & batch.valid
    abandoned = (req.status == ABANDONED) & batch.valid
    latency = req.finish_ms - batch.arrival_ms
    met = done & (req.finish_ms <= batch.arrival_ms + batch.deadline_budget_ms)

    phase = jnp.clip(
        jnp.searchsorted(edges_ms, batch.arrival_ms, side="right") - 1,
        0,
        n_phases - 1,
    )
    # (P, N) membership, then (P, K, N) for the class split
    in_p = (
        phase[None, :] == jnp.arange(n_phases, dtype=jnp.int32)[:, None]
    ) & batch.valid[None, :]
    cls = jnp.clip(batch.cls, 0, n_classes - 1)
    cls_kn = cls[None, :] == jnp.arange(n_classes, dtype=jnp.int32)[:, None]
    in_pk = in_p[:, None, :] & cls_kn[None, :, :]

    accepted_p = (in_p & ~rejected[None, :]).sum(axis=1)
    done_pk = in_pk & done[None, None, :]
    met_pk = in_pk & met[None, None, :]
    accepted_pk = (in_pk & ~rejected[None, None, :]).sum(axis=2)

    bucket_oh = (
        batch.bucket[None, :] == jnp.arange(4, dtype=jnp.int32)[:, None]
    )  # (4, N)
    shed = (
        in_p[:, None, :] & bucket_oh[None, :, :] & rejected[None, None, :]
    ).sum(axis=2)

    p95 = jax.vmap(lambda m: masked_percentile(latency, m, 0.95))(
        in_p & done[None, :]
    )
    class_p95 = jax.vmap(
        jax.vmap(lambda m: masked_percentile(latency, m, 0.95))
    )(done_pk)

    return PhaseMetrics(
        phase_start_ms=edges_ms[:-1],
        n_arrived=in_p.sum(axis=1).astype(jnp.int32),
        n_completed=(in_p & done[None, :]).sum(axis=1).astype(jnp.int32),
        n_abandoned=(in_p & abandoned[None, :]).sum(axis=1).astype(jnp.int32),
        n_throttled=jnp.where(in_p, req.n_throttles[None, :], 0)
        .sum(axis=1)
        .astype(jnp.int32),
        shed_by_bucket=shed.astype(jnp.int32),
        satisfaction=(in_p & met[None, :]).sum(axis=1)
        / jnp.maximum(accepted_p, 1),
        p95_ms=p95,
        class_p95_ms=class_p95,
        class_satisfaction=met_pk.sum(axis=2) / jnp.maximum(accepted_pk, 1),
    )
