"""Workload generation (paper §4.2, §4.4, §4.10, §4.1 ShareGPT mix).

Produces a `RequestBatch` (struct-of-arrays) for one seed:
  * Poisson arrivals whose rate encodes the congestion level,
  * bucket mix per regime (balanced 50/25/15/10, heavy 20/20/30/30,
    sharegpt 12/42/46/1 — the paper's published ShareGPT-English split),
  * realized output tokens per bucket,
  * a service-class id per request under one of the lane schemes
    (`class_map`): the paper's 2-lane short/heavy split (`paper2`),
    a per-bucket 4-lane scheme (`bucket4`), or K symmetric tenants
    assigned independently of bucket (`tenant<K>`, e.g. `tenant8`),
  * policy-facing p50/p90 priors at one of the four information-ladder
    levels (no_info / class_only / coarse / oracle),
  * optional multiplicative predictor noise L (paper §4.10): priors are
    multiplied by U[1-L, 1+L] *after* the coarse prior is formed, leaving
    mock physics untouched.

All randomness is materialized here; the simulator itself is
deterministic given a RequestBatch, which keeps the lax.scan engine
replayable and the experiments seed-exact.  The `paper2` random stream
is bit-identical to the seed generator (tenant assignment draws from a
folded key, never perturbing the base streams).

Nonstationary arrivals (DESIGN.md §5): `generate` optionally takes an
`ArrivalSchedule` — a piecewise-constant rate multiplier + bucket mix
over phases of the horizon.  Arrivals are produced by time-warping the
stationary Poisson stream (inverse of the cumulative-work function, one
vectorized searchsorted), so the trivial schedule (one phase, unit
multiplier) is *bit-exact* with the stationary generator: the warp is
`t = 0 + (u - 0) / 1.0`, an IEEE identity.  Per-phase bucket mixes use
inverse-CDF sampling on the same bucket key only when the mix actually
varies (a static property of the schedule), so constant-mix scenarios
keep the seed bucket stream bit-exact too.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (
    CLS_HEAVY,
    CLS_INTERACTIVE,
    RequestBatch,
    SHORT,
)

# bucket -> (token_low, token_high): paper's short<=64, medium 65-256,
# long 257-1024, xlong 1025-4096
BUCKET_TOKENS = jnp.asarray(
    [[16.0, 64.0], [65.0, 256.0], [257.0, 1024.0], [1025.0, 4096.0]],
    jnp.float32,
)

# per-bucket deadline budgets (ms): roughly SLO_mult x unloaded latency of
# the bucket's p90 token count under the default provider physics
# (90ms + 6.5ms/token; multiples shrink with bucket size like real SLOs)
DEADLINE_BUDGET_MS = jnp.asarray([3600.0, 11000.0, 35000.0, 100000.0], jnp.float32)

# Exact per-bucket p90/p50 quantile ratio of the realized token
# distribution: tokens are log-uniform within [lo, hi], whose quantile
# function is lo * (hi/lo)^q, so p90/p50 = (hi/lo)^0.4.  This is the
# generator-truth tail ratio the live client's `default_p90` uses in
# place of the old hardcoded 1.8 (repro.client.request).
P90_OVER_P50 = (BUCKET_TOKENS[:, 1] / BUCKET_TOKENS[:, 0]) ** 0.4
P90_OVER_P50_NP = np.asarray(P90_OVER_P50)

MIXES = {
    "balanced": jnp.asarray([0.50, 0.25, 0.15, 0.10], jnp.float32),
    "heavy": jnp.asarray([0.20, 0.20, 0.30, 0.30], jnp.float32),
    # fair-queuing experiment (paper §4.6): 70% long/xlong
    "heavy70": jnp.asarray([0.20, 0.10, 0.40, 0.30], jnp.float32),
    # ShareGPT-English published split (paper §4.1): 12/42/46/<1
    "sharegpt": jnp.asarray([0.12, 0.42, 0.455, 0.005], jnp.float32),
}

# Congestion level = offered load as a multiple of the provider's
# comfortable capacity on the given mix (erlang-normalized, so
# "high" stresses the balanced and heavy mixes *equally* relative to the
# knee — the paper's regimes cross mix and congestion independently).
# capacity_mix = comfort_concurrency / mean_service_s(mix) under the
# default physics (90ms + 6.5ms/token, comfort 4).
CONGESTION_MULT = {"medium": 0.85, "high": 1.2}

# mean tokens per mix (log-uniform within buckets; see BUCKET_TOKENS)
_MEAN_TOKENS = {
    "balanced": 357.0,
    "heavy": 866.0,    # 20/20/30/30
    "heavy70": 908.0,  # 20/10/40/30 (fair-queuing experiment)
    "sharegpt": 326.0,
}


def arrival_rate(mix: str, congestion: str,
                 base_ms: float = 90.0, ms_per_token: float = 6.5,
                 comfort: float = 4.0) -> float:
    mean_service_s = (base_ms + ms_per_token * _MEAN_TOKENS[mix]) / 1000.0
    capacity = comfort / mean_service_s
    return CONGESTION_MULT[congestion] * capacity

REGIMES = [
    ("balanced", "medium"),
    ("balanced", "high"),
    ("heavy", "medium"),
    ("heavy", "high"),
]

NEUTRAL_P50 = 300.0  # neutral prior for no_info / class_only conditions
NEUTRAL_P90 = 700.0


class WorkloadConfig(NamedTuple):
    n_requests: int = 192
    mix: str = "balanced"
    congestion: str = "medium"
    information: str = "coarse"   # no_info | class_only | coarse | oracle
    predictor_noise: float = 0.0  # L in paper §4.10
    coarse_rel_err: float = 0.25  # intrinsic coarseness of the predictor
    arrival_scale: float = 1.0    # multiplies the arrival rate; used by
                                  # per-arch physics sweeps to renormalize
                                  # offered load to a slower/faster provider
    class_map: str = "paper2"     # lane scheme: paper2 | bucket4 | tenant<K>


class ArrivalSchedule(NamedTuple):
    """Piecewise-constant arrival shaping over P phases.

    Build from a static `Scenario` spec (sim/scenarios.py) *inside* the
    jit boundary: `mix_varies` is a plain Python bool and must stay
    concrete at trace time.  Phase p covers `[t0_ms[p], t0_ms[p+1])`
    (the last phase extends to +inf) with arrival-rate multiplier
    `rate_mult[p]` and bucket mix `mix_w[p]`.  `cum_work_ms[p]` is the
    stationary-equivalent work consumed before phase p — the running
    integral of the rate multiplier — which makes the Poisson time-warp
    a single searchsorted.
    """

    t0_ms: jnp.ndarray        # (P,) f32 phase start times
    cum_work_ms: jnp.ndarray  # (P,) f32 warped work at each phase start
    rate_mult: jnp.ndarray    # (P,) f32 arrival-rate multiplier per phase
    mix_w: jnp.ndarray        # (P, 4) f32 bucket mix per phase
    mix_varies: bool          # static: any phase deviates from the base mix


def phase_index(sched: ArrivalSchedule, t_ms: jnp.ndarray) -> jnp.ndarray:
    """Phase id of each time point (clipped into [0, P))."""
    p = jnp.searchsorted(sched.t0_ms, t_ms, side="right") - 1
    return jnp.clip(p, 0, sched.t0_ms.shape[0] - 1).astype(jnp.int32)


def warp_arrivals(work_ms: jnp.ndarray, sched: ArrivalSchedule) -> jnp.ndarray:
    """Invert the cumulative-work function: map stationary-equivalent
    work coordinates onto wall-clock arrival times.

    A phase with multiplier m compresses its arrivals by 1/m (m > 1 =
    burst).  Work beyond the last boundary extrapolates with the last
    phase's multiplier.  With the trivial schedule this reduces to the
    identity `0 + (u - 0) / 1.0` — bit-exact with the stationary path.
    """
    p = jnp.clip(
        jnp.searchsorted(sched.cum_work_ms, work_ms, side="right") - 1,
        0,
        sched.cum_work_ms.shape[0] - 1,
    )
    return sched.t0_ms[p] + (work_ms - sched.cum_work_ms[p]) / sched.rate_mult[p]


def _sample_bucket_per_request(key: jax.Array, p: jnp.ndarray) -> jnp.ndarray:
    """Inverse-CDF categorical draw with per-request probabilities (N, 4).

    Only used when the schedule's mix actually varies — the constant-mix
    path keeps `jax.random.choice` so its bucket stream stays bit-exact
    with the seed generator.
    """
    cdf = jnp.cumsum(p, axis=-1)
    cdf = cdf / cdf[..., -1:]  # renormalize against float drift
    r = jax.random.uniform(key, (p.shape[0], 1))
    return (r >= cdf[..., :-1]).sum(axis=-1).astype(jnp.int32)


def bucket_to_class(bucket: jnp.ndarray) -> jnp.ndarray:
    """Interactive lane = short bucket; heavy lane = everything else."""
    return jnp.where(bucket == SHORT, CLS_INTERACTIVE, CLS_HEAVY).astype(jnp.int32)


def n_classes_of(class_map: str) -> int:
    """Static class count implied by a lane scheme."""
    if class_map == "paper2":
        return 2
    if class_map == "bucket4":
        return 4
    if class_map.startswith("tenant"):
        suffix = class_map[len("tenant"):]
        if not suffix.isdigit() or int(suffix) < 1:
            raise ValueError(
                f"tenant scheme must be 'tenant<K>' with K >= 1 "
                f"(e.g. 'tenant8'), got {class_map!r}")
        return int(suffix)
    raise ValueError(f"unknown class_map: {class_map!r}")


def assign_class(
    key: jax.Array, bucket: jnp.ndarray, class_map: str
) -> jnp.ndarray:
    """Service-class id per request under the given lane scheme.

    `tenant<K>` draws ids from a key folded off the workload key, so the
    base random streams (arrivals/buckets/tokens/priors) stay bit-exact
    with the seed `paper2` generator.
    """
    if class_map == "paper2":
        return bucket_to_class(bucket)
    if class_map == "bucket4":
        return bucket.astype(jnp.int32)
    k = n_classes_of(class_map)  # validates the scheme string
    k_tenant = jax.random.fold_in(key, 7)
    return jax.random.randint(k_tenant, bucket.shape, 0, k, jnp.int32)


def generate(
    key: jax.Array,
    cfg: WorkloadConfig,
    sched: ArrivalSchedule | None = None,
) -> tuple[RequestBatch, jnp.ndarray]:
    """Returns (batch, jitter) — jitter is the provider-side noise vector.

    `sched` shapes the arrival process (and optionally the bucket mix)
    nonstationarily; None is the stationary path.  The trivial schedule
    produces bit-identical batches to None (see module docstring).
    """
    n = cfg.n_requests
    k_arr, k_bkt, k_tok, k_prior, k_noise, k_jit = jax.random.split(key, 6)

    rate = arrival_rate(cfg.mix, cfg.congestion) * cfg.arrival_scale
    gaps_ms = jax.random.exponential(k_arr, (n,)) * (1000.0 / rate)
    work = jnp.cumsum(gaps_ms)
    arrival = work if sched is None else warp_arrivals(work, sched)

    mix = MIXES[cfg.mix]
    if sched is not None and sched.mix_varies:
        bucket = _sample_bucket_per_request(
            k_bkt, sched.mix_w[phase_index(sched, arrival)]
        )
    else:
        bucket = jax.random.choice(k_bkt, 4, (n,), p=mix).astype(jnp.int32)

    lo = BUCKET_TOKENS[bucket, 0]
    hi = BUCKET_TOKENS[bucket, 1]
    # log-uniform within the bucket: long buckets are right-skewed like
    # real generation lengths
    u = jax.random.uniform(k_tok, (n,))
    true_tokens = jnp.exp(jnp.log(lo) + u * (jnp.log(hi) - jnp.log(lo)))

    # --- information ladder -------------------------------------------------
    if cfg.information == "oracle":
        p50 = true_tokens
        p90 = true_tokens
    elif cfg.information == "coarse":
        # coarse predictor: unbiased in log-space with relative error
        rel = cfg.coarse_rel_err
        eps = jax.random.uniform(k_prior, (n,), minval=1.0 - rel, maxval=1.0 + rel)
        p50 = true_tokens * eps
        p90 = p50 * 1.8
    elif cfg.information in ("class_only", "no_info"):
        p50 = jnp.full((n,), NEUTRAL_P50, jnp.float32)
        p90 = jnp.full((n,), NEUTRAL_P90, jnp.float32)
    else:
        raise ValueError(f"unknown information level {cfg.information}")

    # --- predictor-noise sweep (paper §4.10): applied AFTER the coarse
    # prior is formed; physics untouched
    if cfg.predictor_noise > 0:
        L = cfg.predictor_noise
        f = jax.random.uniform(k_noise, (n,), minval=1.0 - L, maxval=1.0 + L)
        p50 = p50 * f
        p90 = p90 * f

    cls = assign_class(key, bucket, cfg.class_map)
    jitter = jax.random.uniform(k_jit, (n,), minval=0.95, maxval=1.05)

    batch = RequestBatch(
        arrival_ms=arrival.astype(jnp.float32),
        bucket=bucket,
        cls=cls,
        true_tokens=true_tokens.astype(jnp.float32),
        p50=p50.astype(jnp.float32),
        p90=p90.astype(jnp.float32),
        deadline_budget_ms=DEADLINE_BUDGET_MS[bucket],
        valid=jnp.ones((n,), bool),
    )
    return batch, jitter
