"""Experiment runner: vmap over seeds, strategy registry, result frames.

`run_cell` executes one (policy, workload-config) cell over S seeds in a
single jit'd vmap — the unit every benchmark is built from.
`run_scenario_cell` is the nonstationary counterpart: one (policy,
scenario) cell, with the static `Scenario` spec materialized into
schedule arrays inside the jit boundary and per-phase windowed metrics
returned alongside the aggregates.

Both cells accept the active-window engine transparently: pass
`sim_cfg=SimConfig(..., window=W)` and every seed's scan runs the O(W)
per-tick path (DESIGN.md §6) instead of the dense O(N) one, with
identical results whenever W covers the peak live queue — the window is
an execution strategy, not a modeling change, so metrics and phase
tables read the same.  `window_for` picks a W with headroom for a
target population when callers don't want to reason about live-queue
peaks.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PolicyConfig, n_classes
from repro.sim import scenarios as scn
from repro.sim.engine import SimConfig, run_sim
from repro.sim.metrics import (
    PhaseMetrics,
    SimMetrics,
    compute_metrics,
    compute_phase_metrics,
)
from repro.sim.provider import ProviderPhysics, default_physics
from repro.sim.workload import WorkloadConfig, generate, n_classes_of


@functools.partial(
    jax.jit, static_argnames=("wl_cfg", "sim_cfg")
)
def _run_seeds(
    policy: PolicyConfig,
    phys: ProviderPhysics,
    keys: jax.Array,
    wl_cfg: WorkloadConfig,
    sim_cfg: SimConfig,
) -> SimMetrics:
    def one(key):
        batch, jitter = generate(key, wl_cfg)
        final = run_sim(policy, batch, jitter, phys, sim_cfg)
        return compute_metrics(batch, final, n_classes(policy))

    return jax.vmap(one)(keys)


def window_for(n_requests: int, *, fraction: float = 0.25,
               floor: int = 256, cap: int = 4096) -> int:
    """Heuristic active-window capacity for a population of N.

    The bit-exactness condition is W >= the peak live queue, which the
    overload layer keeps far below N under any policy that sheds —
    a quarter of the population, clamped to [floor, cap], has held
    comfortable headroom across every regime in the scenario registry.
    Callers that drive sustained overload with shedding disabled should
    size W explicitly (an undersized window stays correct but queues
    admissions FIFO, which is no longer the dense engine's behavior).
    """
    return int(min(max(floor, fraction * n_requests), cap))


def run_cell(
    policy: PolicyConfig,
    wl_cfg: WorkloadConfig,
    *,
    seeds: int = 5,
    seed0: int = 0,
    phys: ProviderPhysics | None = None,
    sim_cfg: SimConfig = SimConfig(),
) -> SimMetrics:
    """Metrics stacked over `seeds` runs (leading axis = seed)."""
    phys = phys if phys is not None else default_physics()
    wl_k = n_classes_of(wl_cfg.class_map)
    pol_k = n_classes(policy)
    if wl_k > pol_k:
        raise ValueError(
            f"workload lane scheme {wl_cfg.class_map!r} needs {wl_k} classes "
            f"but the policy carries {pol_k}; build it with kclass_policy({wl_k})"
        )
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(seed0, seed0 + seeds))
    return _run_seeds(policy, phys, keys, wl_cfg, sim_cfg)


@functools.partial(
    jax.jit,
    static_argnames=("scenario", "sim_cfg", "n_requests", "class_map",
                     "information", "arrival_scale"),
)
def _run_scenario_seeds(
    policy: PolicyConfig,
    phys: ProviderPhysics,
    keys: jax.Array,
    scenario: scn.Scenario,
    sim_cfg: SimConfig,
    n_requests: int,
    class_map: str,
    information: str,
    arrival_scale: float,
) -> tuple[SimMetrics, PhaseMetrics]:
    k = n_classes(policy)
    wl_cfg, sched, dynamics, edges = scn.build(
        scenario, n_requests, sim_cfg.n_ticks, sim_cfg.dt_ms,
        class_map=class_map, information=information,
        limiter_classes=k, arrival_scale=arrival_scale,
    )
    # fleet scenarios materialize (T, P) schedules instead of (T,) ones;
    # build() guarantees dynamics is None for them (disjoint mechanisms)
    fleet = scn.build_fleet(
        scenario, phys, sim_cfg.n_ticks, sim_cfg.dt_ms, n_requests, k,
        arrival_scale,
    )

    def one(key):
        batch, jitter = generate(key, wl_cfg, sched)
        final = run_sim(policy, batch, jitter, phys, sim_cfg, dynamics,
                        fleet=fleet)
        return (
            compute_metrics(batch, final, k),
            compute_phase_metrics(batch, final, edges, k),
        )

    return jax.vmap(one)(keys)


def run_scenario_cell(
    policy: PolicyConfig,
    scenario: scn.Scenario | str,
    *,
    seeds: int = 5,
    seed0: int = 0,
    n_requests: int = 160,
    class_map: str = "paper2",
    information: str = "coarse",
    phys: ProviderPhysics | None = None,
    sim_cfg: SimConfig = SimConfig(),
    arrival_scale: float = 1.0,
) -> tuple[SimMetrics, PhaseMetrics]:
    """One (policy, scenario) cell over S seeds in a single jit'd vmap.

    Returns (aggregate metrics, per-phase metrics), both stacked over
    the leading seed axis.  The scenario spec is static: each distinct
    scenario compiles once and its schedule arrays are trace constants.
    `arrival_scale` compresses the scenario's span by offering the same
    population at a higher rate (see `scenarios.build`).
    """
    if isinstance(scenario, str):
        scenario = scn.get_scenario(scenario)
    phys = phys if phys is not None else default_physics()
    wl_k = n_classes_of(class_map)
    pol_k = n_classes(policy)
    if wl_k > pol_k:
        raise ValueError(
            f"lane scheme {class_map!r} needs {wl_k} classes but the "
            f"policy carries {pol_k}; build it with kclass_policy({wl_k})"
        )
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(seed0, seed0 + seeds))
    return _run_scenario_seeds(
        policy, phys, keys, scenario, sim_cfg, n_requests, class_map,
        information, arrival_scale,
    )


def summarize(m: SimMetrics) -> Mapping[str, tuple[float, float]]:
    """mean ± std over the seed axis, NaN-safe."""
    out = {}
    for name, v in m._asdict().items():
        arr = np.asarray(v, np.float64)
        out[name] = (float(np.nanmean(arr)), float(np.nanstd(arr)))
    return out


def fmt_cell(summary: Mapping[str, tuple[float, float]], keys=None) -> str:
    keys = keys or list(summary)
    parts = [f"{k}={summary[k][0]:.1f}±{summary[k][1]:.1f}" for k in keys]
    return " ".join(parts)
