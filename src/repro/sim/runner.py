"""Experiment runner: vmap over seeds, strategy registry, result frames.

`run_cell` executes one (policy, workload-config) cell over S seeds in a
single jit'd vmap — the unit every benchmark is built from.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PolicyConfig, n_classes
from repro.sim.engine import SimConfig, run_sim
from repro.sim.metrics import SimMetrics, compute_metrics
from repro.sim.provider import ProviderPhysics, default_physics
from repro.sim.workload import WorkloadConfig, generate, n_classes_of


@functools.partial(
    jax.jit, static_argnames=("wl_cfg", "sim_cfg")
)
def _run_seeds(
    policy: PolicyConfig,
    phys: ProviderPhysics,
    keys: jax.Array,
    wl_cfg: WorkloadConfig,
    sim_cfg: SimConfig,
) -> SimMetrics:
    def one(key):
        batch, jitter = generate(key, wl_cfg)
        final = run_sim(policy, batch, jitter, phys, sim_cfg)
        return compute_metrics(batch, final, n_classes(policy))

    return jax.vmap(one)(keys)


def run_cell(
    policy: PolicyConfig,
    wl_cfg: WorkloadConfig,
    *,
    seeds: int = 5,
    seed0: int = 0,
    phys: ProviderPhysics | None = None,
    sim_cfg: SimConfig = SimConfig(),
) -> SimMetrics:
    """Metrics stacked over `seeds` runs (leading axis = seed)."""
    phys = phys if phys is not None else default_physics()
    wl_k = n_classes_of(wl_cfg.class_map)
    pol_k = n_classes(policy)
    if wl_k > pol_k:
        raise ValueError(
            f"workload lane scheme {wl_cfg.class_map!r} needs {wl_k} classes "
            f"but the policy carries {pol_k}; build it with kclass_policy({wl_k})"
        )
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(seed0, seed0 + seeds))
    return _run_seeds(policy, phys, keys, wl_cfg, sim_cfg)


def summarize(m: SimMetrics) -> Mapping[str, tuple[float, float]]:
    """mean ± std over the seed axis, NaN-safe."""
    out = {}
    for name, v in m._asdict().items():
        arr = np.asarray(v, np.float64)
        out[name] = (float(np.nanmean(arr)), float(np.nanstd(arr)))
    return out


def fmt_cell(summary: Mapping[str, tuple[float, float]], keys=None) -> str:
    keys = keys or list(summary)
    parts = [f"{k}={summary[k][0]:.1f}±{summary[k][1]:.1f}" for k in keys]
    return " ".join(parts)
