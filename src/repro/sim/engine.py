"""Tick-driven discrete-event engine, written as one `lax.scan`.

Hardware-adaptation note (DESIGN.md §3): the paper's simulator is an
implicit Python event loop; re-expressing it as a fixed-shape JAX scan
makes every policy sweep a single compiled program that `vmap`s over
seeds, regimes and stacked PolicyConfigs — this is what lets the full
benchmark suite (hundreds of runs) execute in seconds on one host and
would let a TPU host run thousands of what-if schedules per second
alongside the serving mesh.

Each tick:
  1. completions  (finish_ms <= now)  -> COMPLETED, update tail EMA
  2. timeouts     (pending too long)  -> ABANDONED (the implicit failure
                                         mode explicit shedding replaces)
  3. ONE batched dispatch pass (`schedule_batch`, DESIGN.md §3): up to
     `k_slots` grants from a single vectorized allocation -> ordering ->
     overload evaluation, applied as one scatter.  The per-tick policy
     cost is O(K·N + B·K) instead of the O(B·K·N) the former sequential
     slot loop paid; with k_slots=1 the tick is bit-exact with the
     sequential `schedule_slot` path.

Nonstationary provider dynamics (DESIGN.md §5): `run_sim` optionally
takes a `ProviderDynamics` whose (T,)-shaped schedules ride the scan as
xs — brownout comfort scaling applied to the tick's admissions, and a
per-class token-bucket rate limiter at the provider boundary whose
429-style bounces return the request to PENDING with a client-visible
retry-after.  Presence of each mechanism is pytree structure (None =
off), so scenario complexity costs nothing at trace time: the whole
horizon stays one `lax.scan` with no Python per-tick branching, and
`dynamics=None` compiles the exact stationary program.

Active window (DESIGN.md §6): with `SimConfig.window = W` the scan
carries a compacted `(W,)` slot pool (`WindowCarry`) holding exactly the
live queue — arrived, non-terminal requests.  Each tick retires
completed/rejected/abandoned slots (scattering their terminal outcome
into the dense `(N,)` result arrays, which stay in the carry and are
updated in place), compacts the survivors, admits newly-arrived
requests off the arrival-sorted stream with one O(log N) bisect, and
runs the *same* `schedule_batch` on the `(K, W)` window view.  Per-tick
policy cost is O(W), independent of the horizon population N; with
W >= the peak live queue the decision stream and final request arrays
are bit-exact with the dense engine (the pinned contract —
tests/test_window_engine.py).

Fleet axis (DESIGN.md §10): `run_sim(..., fleet=Fleet(phys, dyn))`
stacks provider physics along a `(P,)` axis and runs the layer-0
routing pass (`core.routing`) before dispatch — every grant carries an
endpoint, service is priced against that endpoint's own inflight load,
the rate limiter becomes a `(P, K)` bucket grid, and a dead endpoint's
in-flight work is requeued (PENDING + Retry-After defer + throttle
bump) before completions are computed.  Like every other optional
mechanism, `fleet=None` is pytree structure: the fleet-free program is
byte-identical to the pre-fleet engine, and at static P=1 the fleet
engine takes scalar-gather branches that reproduce the single-provider
arithmetic bit-for-bit (tests/test_fleet.py pins both).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import overload as olc
from repro.core.numerics import pinned
from repro.core.policy import ALLOC_ADRR, PolicyConfig, n_classes
from repro.core.scheduler import BatchDecision, schedule_batch
from repro.core.routing import route_requests
from repro.core.types import (
    ABANDONED,
    COMPLETED,
    INFLIGHT,
    PENDING,
    REJECTED,
    RequestBatch,
    RequestState,
    SimState,
    WindowCarry,
    init_fleet_state,
    init_sim_state,
    init_window_carry,
)
from repro.sim.provider import (
    Fleet,
    ProviderDynamics,
    ProviderPhysics,
    service_time_ms,
    unloaded_latency_ms,
)

EMA_ALPHA = 0.15

# Canonical width of the per-tick EMA completion sample (see
# `_completed_ratio_sum`).  Far above per-tick completion counts any
# regime produces; both engine representations truncate identically.
EMA_SAMPLE_CAP = 128


class SimConfig(NamedTuple):
    dt_ms: float = 25.0
    n_ticks: int = 6000
    k_slots: int = 4  # max grants per tick (batch dispatch width B)
    ordering_backend: str = "jnp"  # "jnp" | "pallas" (large-N path)
    window: Optional[int] = None  # active-window capacity W; None = dense
                                  # O(N) scan (requires arrival-sorted
                                  # batches when set — the generator's
                                  # native order)


def _completed_ratio_sum(
    phys: ProviderPhysics,
    done_now: jnp.ndarray,
    finish_ms: jnp.ndarray,
    arrival_ms: jnp.ndarray,
    tokens: jnp.ndarray,
):
    """Shape-canonical tail-EMA contribution of this tick's completions.

    The windowed and dense engines hold the completions in
    different-width arrays ((W,) vs (N,)), and both XLA's reduction tree
    and its instruction selection for fused elementwise chains (FMA
    contraction, reciprocal-based division) depend on the surrounding
    program — so computing `sum(e2e / expected)` over the wide arrays
    rounds differently in the two engines and breaks their bit-exact
    contract.  Both engines therefore extract the completing entries
    into fixed `(EMA_SAMPLE_CAP,)` buffers in index order (request-id
    order in both: the window is compaction-sorted by request id) and
    run the *entire* ratio arithmetic on those — the optimization
    barrier cuts fusion with the differently-shaped producers, so the
    subgraph between gather and sum is the same program in both engines
    and rounds identically by construction.  Past the cap both
    representations truncate to the same first `EMA_SAMPLE_CAP`
    completions (the cap is far above per-tick completion counts any
    regime produces).  Returns (ratio_sum, count).
    """
    c = EMA_SAMPLE_CAP
    idx, = jnp.nonzero(done_now, size=c, fill_value=0)
    k = done_now.sum()
    fin, arr, tok, live = pinned((
        finish_ms[idx], arrival_ms[idx], tokens[idx], jnp.arange(c) < k,
    ))
    e2e = fin - arr
    expected = unloaded_latency_ms(phys, tok)
    ratio = jnp.where(live, e2e / jnp.maximum(expected, 1.0), 0.0)
    # the inputs above are already routed through pinned(), so this sum
    # runs inside the isolated subgraph; wrapping it again would change
    # the fused HLO and break the committed windowed/dense parity pins
    return ratio.sum(), k  # reprolint: disable=RPL001


def _complete_and_timeout(
    cfg: PolicyConfig,
    phys: ProviderPhysics,
    batch: RequestBatch,
    state: SimState,
    avail_t=None,
    retry_after_ms=None,
) -> SimState:
    req = state.req
    now = state.now_ms

    finish_ms = req.finish_ms
    defer_until = req.defer_until
    n_throttles = req.n_throttles
    status0 = req.status
    n_requeue_ep = None
    if avail_t is not None:
        # fleet failover: a down endpoint kills its in-flight work before
        # any of it can land this tick — the client observes the drop and
        # requeues with the provider's Retry-After backoff.  (The live
        # `FleetProvider` drains gracefully instead; the engine models
        # the harsher abrupt-kill failure, see DESIGN.md §10.)
        ep = req.endpoint
        down = jnp.asarray(avail_t, jnp.float32)[ep] < 0.5
        requeue = (status0 == INFLIGHT) & down
        status0 = jnp.where(requeue, PENDING, status0)
        finish_ms = jnp.where(requeue, jnp.inf, finish_ms)
        defer_until = jnp.where(requeue, now + retry_after_ms, defer_until)
        n_throttles = n_throttles + requeue.astype(jnp.int32)
        p = state.fleet.inflight.shape[0]
        ep_oh = ep[None, :] == jnp.arange(p, dtype=jnp.int32)[:, None]
        n_requeue_ep = (ep_oh & requeue[None, :]).sum(axis=1).astype(
            jnp.int32)

    landed = (status0 == INFLIGHT) & (finish_ms <= now)
    # hard provider/application timeout: a request whose end-to-end latency
    # blew past timeout_mult x its deadline budget is a *failure*, not a
    # completion — this is the implicit failure mode (paper §2) that
    # explicit overload shedding exists to replace.
    e2e = finish_ms - batch.arrival_ms
    timed_out = landed & (
        e2e > cfg.timeout_mult[batch.bucket] * batch.deadline_budget_ms)
    done_now = landed & ~timed_out
    status = jnp.where(done_now, COMPLETED, jnp.where(timed_out, ABANDONED, status0))

    # tail signal: observed end-to-end latency vs unloaded expectation
    ratio_sum, k = _completed_ratio_sum(
        phys, done_now, finish_ms, batch.arrival_ms, batch.true_tokens)
    # divide by the SAMPLE size: past the cap ratio_sum covers only the
    # first EMA_SAMPLE_CAP completions, and dividing by the full k would
    # bias the tail signal toward 0 (the drain tick routinely lands
    # hundreds of completions at once)
    k_sample = jnp.minimum(k, EMA_SAMPLE_CAP)
    mean_ratio = jnp.where(k > 0, ratio_sum / jnp.maximum(k_sample, 1), 0.0)
    # the barrier pins the EMA's scalar rounding: without it XLA is free
    # to contract the mul+add into an FMA in one compilation and not the
    # other (the windowed and dense engines compile differently-shaped
    # programs around this identical scalar subgraph), and a 1-ulp EMA
    # drift eventually shifts severity — breaking the bit-exact contract
    delta = pinned(EMA_ALPHA * (mean_ratio - state.sched.ema_latency_ratio))
    ema = jnp.where(
        k > 0,
        state.sched.ema_latency_ratio + delta,
        state.sched.ema_latency_ratio,
    )

    # implicit client abandonment of stale pending work
    waited = now - batch.arrival_ms
    stale = (
        (status == PENDING)
        & (batch.arrival_ms <= now)
        & (waited > cfg.timeout_mult[batch.bucket] * batch.deadline_budget_ms)
    )
    status = jnp.where(stale, ABANDONED, status)

    inflight = (status == INFLIGHT).sum().astype(jnp.int32)
    inflight_tokens = jnp.where(status == INFLIGHT, batch.p50, 0.0).sum()

    fleet = state.fleet
    if fleet is not None:
        # per-endpoint recount: every INFLIGHT request carries its
        # endpoint, so the split is an exact one-hot masked sum — the
        # same recount-over-status discipline as the global counters
        # (and like them, exact in the windowed engine because every
        # INFLIGHT request lives in the window)
        p = fleet.inflight.shape[0]
        ep_oh = req.endpoint[None, :] == jnp.arange(p, dtype=jnp.int32)[:, None]
        live = ep_oh & (status == INFLIGHT)[None, :]
        fleet = fleet._replace(
            inflight=live.sum(axis=1).astype(jnp.int32),
            inflight_tokens=jnp.where(live, batch.p50[None, :], 0.0).sum(
                axis=1),
        )
        if n_requeue_ep is not None:
            fleet = fleet._replace(
                n_requeued=fleet.n_requeued + n_requeue_ep)

    return state._replace(
        req=req._replace(
            status=status,
            finish_ms=finish_ms,
            defer_until=defer_until,
            n_throttles=n_throttles,
        ),
        sched=state.sched._replace(
            ema_latency_ratio=ema,
            n_completed_obs=state.sched.n_completed_obs
            + k.astype(jnp.int32),
        ),
        provider=state.provider._replace(
            inflight=inflight, inflight_tokens=inflight_tokens
        ),
        fleet=fleet,
    )


def _apply_batch(
    cfg: PolicyConfig,
    phys: ProviderPhysics,
    batch: RequestBatch,
    jitter: jnp.ndarray,
    state: SimState,
    d: BatchDecision,
    comfort_scale=None,
    limiter: ProviderDynamics | None = None,
    fleet: Fleet | None = None,
) -> SimState:
    """State transition for up to B grants, as one set of scatters.

    Grants target distinct requests by construction (each consumes a
    distinct entry of the ranked candidate lists), so the scatters never
    collide; idle rows are routed to the out-of-range index N and
    dropped.

    `comfort_scale` is this tick's brownout value (None = stationary);
    `limiter` enables the provider-boundary token bucket: an ADMIT whose
    class bucket is out of grants bounces 429-style — the request stays
    PENDING with `defer_until = now + retry_after` (the client-visible
    retry) and the DRR charge is refunded like any blocked release.
    Grants later in the same batch were decided against the optimistic
    inflight count (the client only observes the bounce after the send),
    which matches a real async client racing its own rate limit.

    `fleet` (mutually exclusive with `limiter`) switches to the (P,)
    provider axis: each grant lands on its `d.provider_idx` endpoint —
    service physics gather that endpoint's curve at *its* outstanding
    count, the rate limiter becomes the (P, K) per-endpoint bucket grid
    (rank arithmetic over the flattened P*K keys), and the request
    records its endpoint for the failover requeue.  At P == 1 the
    gathers collapse to endpoint 0 and the arithmetic is the exact
    single-provider program (the fleet P=1 bit-exactness contract).
    """
    n = batch.n
    req = state.req
    admit = d.actions == olc.ADMIT
    defer = d.actions == olc.DEFER
    reject = d.actions == olc.REJECT
    idx = d.req_idx
    deficit = d.deficit

    if limiter is not None:
        k = state.provider.tb_tokens.shape[0]
        gcls = jnp.clip(batch.cls[idx], 0, k - 1)
        # g-th grant's rank among this batch's admits of the same class:
        # admit is allowed iff the bucket holds that many grants
        take = (gcls[:, None] == jnp.arange(k, dtype=jnp.int32)) & admit[:, None]
        rank = (jnp.cumsum(take, axis=0) * take).sum(axis=-1)  # (B,) 1-based
        allowed = rank.astype(jnp.float32) <= state.provider.tb_tokens[gcls] + 1e-6
        throttled = admit & ~allowed
        admit = admit & allowed

    fl_limited = False
    if fleet is not None:
        p = fleet.phys.base_ms.shape[0]
        ep = jnp.clip(d.provider_idx, 0, p - 1)
        # optimistic admits (pre-bounce): the per-endpoint service load
        # mirrors d.inflight_at's optimism — the client only observes a
        # 429 after the send
        admit0 = admit
        if fleet.dyn is not None and fleet.dyn.tb_refill is not None:
            fl_limited = True
            k = state.fleet.tb_tokens.shape[1]
            gcls = jnp.clip(batch.cls[idx], 0, k - 1)
            # same rank-vs-bucket rule as the single-provider limiter,
            # over the flattened (P*K,) bucket keys
            key = ep * k + gcls
            take = (key[:, None] == jnp.arange(p * k, dtype=jnp.int32)) \
                & admit[:, None]
            rank = (jnp.cumsum(take, axis=0) * take).sum(axis=-1)
            allowed = rank.astype(jnp.float32) <= \
                state.fleet.tb_tokens.reshape(p * k)[key] + 1e-6
            throttled = admit & ~allowed
            admit = admit & allowed

    # per-grant service physics at the inflight level the grant saw —
    # identical floats to the sequential one-admit-at-a-time path.
    # NOTE: XLA:CPU contracts the trailing `service * jitter + now` into
    # an FMA here (a barrier does not stop LLVM-level contraction inside
    # one fusion); the live client's MockProvider reproduces that
    # rounding explicitly (repro.client.provider._fma32) to keep
    # session-vs-engine finish floats bit-identical.
    if fleet is None:
        service = service_time_ms(
            phys, batch.true_tokens[idx], d.inflight_at, jitter[idx],
            comfort_scale
        )
    elif p == 1:
        # endpoint 0 scalar gathers: () leaves and the global inflight,
        # exactly the single-provider arithmetic
        phys_g = ProviderPhysics(*(a[0] for a in fleet.phys))
        comfort_g = None if comfort_scale is None else \
            jnp.asarray(comfort_scale, jnp.float32)[0]
        service = service_time_ms(
            phys_g, batch.true_tokens[idx], d.inflight_at, jitter[idx],
            comfort_g
        )
    else:
        # (B,)-leaf physics gathered per grant; the load each grant sees
        # is its endpoint's outstanding count plus the same-endpoint
        # admits granted earlier in this batch (exclusive cumsum)
        phys_g = ProviderPhysics(*(a[ep] for a in fleet.phys))
        ep_oh = jax.nn.one_hot(ep, p, dtype=jnp.int32) * admit0[:, None]
        prior = jnp.cumsum(ep_oh, axis=0) - ep_oh
        infl_ep = state.fleet.inflight[ep] + (
            prior * jax.nn.one_hot(ep, p, dtype=jnp.int32)).sum(axis=1)
        comfort_g = None if comfort_scale is None else \
            jnp.asarray(comfort_scale, jnp.float32)[ep]
        service = service_time_ms(
            phys_g, batch.true_tokens[idx], infl_ep, jitter[idx], comfort_g
        )
    finish = state.now_ms + service
    backoff = olc.defer_backoff(cfg, d.severity, req.n_defers[idx])

    drop = jnp.int32(n)  # out-of-range => mode="drop" makes the row a no-op
    adm_i = jnp.where(admit, idx, drop)
    def_i = jnp.where(defer, idx, drop)
    rej_i = jnp.where(reject, idx, drop)

    status = req.status.at[adm_i].set(INFLIGHT, mode="drop")
    status = status.at[rej_i].set(REJECTED, mode="drop")
    submit = req.submit_ms.at[adm_i].set(state.now_ms, mode="drop")
    finish_ms = req.finish_ms.at[adm_i].set(finish, mode="drop")
    defer_until = req.defer_until.at[def_i].set(
        state.now_ms + backoff, mode="drop")
    n_defers = req.n_defers.at[def_i].add(1, mode="drop")
    n_throttles = req.n_throttles

    provider = state.provider
    if limiter is not None:
        thr_i = jnp.where(throttled, idx, drop)
        defer_until = defer_until.at[thr_i].set(
            state.now_ms + limiter.retry_after_ms, mode="drop")
        n_throttles = n_throttles.at[thr_i].add(1, mode="drop")
        consumed = (take & admit[:, None]).sum(axis=0).astype(jnp.float32)
        provider = provider._replace(
            tb_tokens=provider.tb_tokens - consumed,
            n_throttled=provider.n_throttled
            + throttled.sum().astype(jnp.int32),
        )
        # deficit conservation: the allocation layer charged for these
        # sends inside schedule_batch; the 429 blocked the release, so
        # credit it back exactly like a defer/reject refund (ADRR only).
        refund = (
            jax.nn.one_hot(gcls, k)
            * batch.p50[idx][:, None]
            * throttled[:, None]
        ).sum(axis=0) * (cfg.alloc_mode == ALLOC_ADRR)
        deficit = jnp.where(jnp.isfinite(deficit + refund),
                            deficit + refund, deficit)

    fstate = state.fleet
    endpoint = req.endpoint
    if fleet is not None:
        # record where each admit went (the failover requeue and the
        # per-endpoint recount both read this) and split the aggregate
        # updates along the endpoint axis
        endpoint = endpoint.at[adm_i].set(ep, mode="drop")
        adm_oh = jax.nn.one_hot(ep, p, dtype=jnp.int32) * admit[:, None]
        fstate = fstate._replace(
            inflight=fstate.inflight + adm_oh.sum(axis=0).astype(jnp.int32),
            inflight_tokens=fstate.inflight_tokens
            + (adm_oh.astype(jnp.float32) * batch.p50[idx][:, None]).sum(
                axis=0),
        )
        if fl_limited:
            thr_i = jnp.where(throttled, idx, drop)
            defer_until = defer_until.at[thr_i].set(
                state.now_ms + fleet.dyn.retry_after_ms, mode="drop")
            n_throttles = n_throttles.at[thr_i].add(1, mode="drop")
            consumed = (take & admit[:, None]).sum(axis=0).astype(
                jnp.float32).reshape(p, k)
            thr_oh = jax.nn.one_hot(ep, p, dtype=jnp.int32) \
                * throttled[:, None]
            fstate = fstate._replace(
                tb_tokens=fstate.tb_tokens - consumed,
                n_throttled=fstate.n_throttled
                + thr_oh.sum(axis=0).astype(jnp.int32),
            )
            # deficit conservation — same refund as the single-provider
            # limiter: the 429 blocked a charged release (ADRR only)
            refund = (
                jax.nn.one_hot(gcls, k)
                * batch.p50[idx][:, None]
                * throttled[:, None]
            ).sum(axis=0) * (cfg.alloc_mode == ALLOC_ADRR)
            deficit = jnp.where(jnp.isfinite(deficit + refund),
                                deficit + refund, deficit)
            provider = provider._replace(
                n_throttled=provider.n_throttled
                + throttled.sum().astype(jnp.int32),
            )

    inflight = provider.inflight + admit.sum().astype(jnp.int32)
    inflight_tokens = provider.inflight_tokens + jnp.where(
        admit, batch.p50[idx], 0.0
    ).sum()

    return state._replace(
        req=req._replace(
            status=status,
            submit_ms=submit,
            finish_ms=finish_ms,
            defer_until=defer_until,
            n_defers=n_defers,
            n_throttles=n_throttles,
            endpoint=endpoint,
        ),
        sched=state.sched._replace(deficit=deficit, rr_turn=d.rr_turn),
        provider=provider._replace(
            inflight=inflight, inflight_tokens=inflight_tokens
        ),
        fleet=fstate,
    )


def _window_view(
    batch: RequestBatch, req: RequestState, slot_req: jnp.ndarray
) -> tuple[RequestBatch, RequestState, jnp.ndarray]:
    """Gather the window's (W,)-shaped view of the batch and request
    state.  Empty slots (sentinel id n) clamp their gathers to a real
    row but are neutralized: valid=False (never eligible), terminal
    status (never counted live), finish=inf (never landing).  Returns
    (win_batch, win_req, occupied)."""
    n = batch.n
    occ = slot_req < n
    safe = jnp.minimum(slot_req, n - 1)
    win_batch = RequestBatch(
        arrival_ms=batch.arrival_ms[safe],
        bucket=batch.bucket[safe],
        cls=batch.cls[safe],
        true_tokens=batch.true_tokens[safe],
        p50=batch.p50[safe],
        p90=batch.p90[safe],
        deadline_budget_ms=batch.deadline_budget_ms[safe],
        valid=batch.valid[safe] & occ,
    )
    win_req = RequestState(
        status=jnp.where(occ, req.status[safe], jnp.int32(REJECTED)),
        submit_ms=req.submit_ms[safe],
        finish_ms=jnp.where(occ, req.finish_ms[safe], jnp.inf),
        defer_until=req.defer_until[safe],
        n_defers=req.n_defers[safe],
        n_throttles=req.n_throttles[safe],
        endpoint=None if req.endpoint is None else req.endpoint[safe],
    )
    return win_batch, win_req, occ


def _retire_window(
    cfg: PolicyConfig,
    phys: ProviderPhysics,
    batch: RequestBatch,
    state: SimState,
    win: WindowCarry,
    avail_t=None,
    retry_after_ms=None,
) -> tuple[SimState, jnp.ndarray]:
    """Windowed completion/timeout/stale pass: run the *dense* transition
    on the (W,) window view — one code path, so the formulas cannot
    drift — then scatter the updated statuses into the dense result
    arrays.  The EMA update inside is bit-exact with the dense engine
    because `_completed_ratio_sum` reduces a canonical fixed-width
    buffer in request-id order (the window's compaction invariant).
    Returns (state, alive) where alive marks slots still live (PENDING
    or INFLIGHT) after retirement."""
    n = batch.n
    win_batch, win_req, occ = _window_view(batch, state.req, win.slot_req)
    win_state = state._replace(req=win_req)
    win_state = _complete_and_timeout(cfg, phys, win_batch, win_state,
                                      avail_t=avail_t,
                                      retry_after_ms=retry_after_ms)
    status_w = win_state.req.status
    sidx = jnp.where(occ, win.slot_req, n)
    req = state.req
    if avail_t is not None:
        # the failover requeue rewrote more than status: scatter the
        # reset finish/backoff/throttle fields into the dense arrays too
        req = req._replace(
            finish_ms=req.finish_ms.at[sidx].set(
                win_state.req.finish_ms, mode="drop"),
            defer_until=req.defer_until.at[sidx].set(
                win_state.req.defer_until, mode="drop"),
            n_throttles=req.n_throttles.at[sidx].set(
                win_state.req.n_throttles, mode="drop"),
        )
    status = req.status.at[sidx].set(status_w, mode="drop")
    state = state._replace(
        req=req._replace(status=status),
        sched=win_state.sched,
        # inflight is an exact recount (every INFLIGHT request lives in
        # the window); inflight_tokens is a diagnostics-only float whose
        # reduction width differs from the dense engine's (not pinned)
        provider=win_state.provider,
        fleet=win_state.fleet,
    )
    alive = occ & ((status_w == PENDING) | (status_w == INFLIGHT))
    return state, alive


def _compact_and_admit(
    batch: RequestBatch, win: WindowCarry, alive: jnp.ndarray, now
) -> WindowCarry:
    """Reclaim retired slots and admit newly-arrived requests.

    Reclamation is a stable compaction (cumsum scatter): survivors keep
    their relative order, so the window stays sorted by request id and
    the free region is the tail.  Admission pops the arrival-sorted
    stream — `searchsorted` finds how many requests have arrived by
    `now` in O(log N), and the first `free` of the not-yet-admitted
    prefix append behind the survivors.  When the live queue exceeds W
    the overflow waits (FIFO by arrival) — correct but no longer
    bit-exact with the dense engine, which has no admission gate."""
    n = batch.n
    w = win.slot_req.shape[0]
    iota = jnp.arange(w, dtype=jnp.int32)
    pos = jnp.cumsum(alive.astype(jnp.int32)) - 1
    target = jnp.where(alive, pos, w)
    slot_req = jnp.full((w,), n, jnp.int32).at[target].set(
        win.slot_req, mode="drop")
    n_live = alive.sum().astype(jnp.int32)

    n_arrived = jnp.searchsorted(
        batch.arrival_ms, now, side="right").astype(jnp.int32)
    avail = jnp.maximum(n_arrived - win.arr_ptr, 0)
    n_admit = jnp.minimum(avail, w - n_live)
    new_req = win.arr_ptr + iota - n_live
    admit_here = (iota >= n_live) & (iota < n_live + n_admit)
    slot_req = jnp.where(admit_here, new_req, slot_req)
    return WindowCarry(
        slot_req=slot_req,
        arr_ptr=win.arr_ptr + n_admit,
        n_live=n_live + n_admit,
    )


def sim_tick(
    policy: PolicyConfig,
    phys: ProviderPhysics,
    batch: RequestBatch,
    jitter: jnp.ndarray,
    state: SimState,
    win: WindowCarry | None,
    xs: tuple,
    *,
    dt_ms: float,
    k_slots: int,
    backend: str,
    dynamics: ProviderDynamics | None = None,
    fleet: Fleet | None = None,
    collect_decisions: bool = False,
):
    """One decision epoch of the engine as a single traceable body:

      retire -> compact + admit -> limiter refill -> route -> dispatch
      -> apply

    This is THE per-tick program — `run_sim` scans it, and the live
    `ClientSession` fused tick is its transport-boundary sibling
    (retire/compact/dispatch are the same functions there; apply is
    split across the provider round-trip).  Module-level and explicit
    so the two paths share one definition of the tick, not two copies
    that drift.  `win=None` runs the dense O(N) transition; a
    `WindowCarry` runs the O(W) active-window path.  `fleet` switches
    every stage to the (P,) provider axis: the retire pass requeues
    in-flight work on down endpoints, the refill feeds the (P, K)
    bucket grid, and `routing.route_requests` fixes each request's
    endpoint (and route score term) before dispatch.  At the static
    P == 1 the route term is absent and the tick is the exact
    single-provider program.  Returns (state, win, ys) with ys the
    per-tick decision trace row (or None).
    """
    windowed = win is not None
    has_limiter = dynamics is not None and dynamics.tb_refill is not None
    fl_dyn = fleet.dyn if fleet is not None else None
    has_fleet_limiter = fl_dyn is not None and fl_dyn.tb_refill is not None
    t_idx, comfort_t, refill_t, avail_t = xs
    retry_ms = fl_dyn.retry_after_ms if avail_t is not None else None
    now = (t_idx + 1).astype(jnp.float32) * dt_ms
    state = state._replace(now_ms=now)
    if windowed:
        state, alive = _retire_window(policy, phys, batch, state, win,
                                      avail_t=avail_t,
                                      retry_after_ms=retry_ms)
        win = _compact_and_admit(batch, win, alive, now)
    else:
        state = _complete_and_timeout(policy, phys, batch, state,
                                      avail_t=avail_t,
                                      retry_after_ms=retry_ms)
    if has_limiter:
        state = state._replace(
            provider=state.provider._replace(
                tb_tokens=jnp.minimum(
                    state.provider.tb_tokens + refill_t,
                    dynamics.tb_capacity,
                )
            )
        )
    if has_fleet_limiter:
        state = state._replace(
            fleet=state.fleet._replace(
                tb_tokens=jnp.minimum(
                    state.fleet.tb_tokens + refill_t,
                    fl_dyn.tb_capacity,
                )
            )
        )
    if windowed:
        win_batch, win_req, _ = _window_view(batch, state.req, win.slot_req)
        d_batch, d_state = win_batch, state._replace(req=win_req)
    else:
        d_batch, d_state = batch, state
    route = endpoint = None
    if fleet is not None:
        p = fleet.phys.base_ms.shape[0]
        if p > 1:
            endpoint, route = route_requests(
                fleet.phys, state.fleet, d_batch.p50,
                comfort_t=comfort_t, avail_t=avail_t,
                retry_after_ms=fl_dyn.retry_after_ms
                if has_fleet_limiter else None,
            )
        else:
            # static P == 1: no routing choice exists — endpoint is an
            # integer constant and route stays None, so the scored
            # ordering program is exactly the single-provider one
            endpoint = jnp.zeros((d_batch.p50.shape[0],), jnp.int32)
    d = schedule_batch(
        policy, d_batch, d_state,
        max_grants=k_slots,
        backend=backend,
        route=route,
        endpoint=endpoint,
    )
    if windowed:
        # slot-local decision -> global request ids; empty slots
        # translate to the out-of-range n and fall into the scatter
        # drop path (IDLE rows never carry a release anyway).
        # d.provider_idx is already endpoint-valued — no translation.
        w = win.slot_req.shape[0]
        d = d._replace(
            req_idx=win.slot_req[jnp.clip(d.req_idx, 0, w - 1)])
    state = _apply_batch(
        policy, phys, batch, jitter, state, d,
        comfort_scale=comfort_t,
        limiter=dynamics if has_limiter else None,
        fleet=fleet,
    )
    ys = (d.actions, d.req_idx, d.severity) if collect_decisions else None
    return state, win, ys


def run_sim(
    policy: PolicyConfig,
    batch: RequestBatch,
    jitter: jnp.ndarray,
    phys: ProviderPhysics,
    sim_cfg: SimConfig = SimConfig(),
    dynamics: ProviderDynamics | None = None,
    collect_decisions: bool = False,
    fleet: Fleet | None = None,
) -> SimState | tuple[SimState, tuple]:
    """Run the full horizon; returns the final SimState (jit-friendly).

    `dynamics` threads time-varying provider schedules through the scan
    as (T,)-shaped xs (DESIGN.md §5).  Which mechanisms exist is pytree
    structure — `dynamics=None` (or all-None fields) traces exactly the
    stationary program, and schedule *content* never changes trace size:
    scenario complexity is O(1) at compile time.

    `sim_cfg.window = W` switches the scan to the active-window engine
    (DESIGN.md §6): per-tick cost O(W) instead of O(N·K), bit-exact with
    the dense path whenever W covers the peak live queue.  Windowed mode
    requires `batch.arrival_ms` sorted ascending (the workload
    generator's native order).

    `collect_decisions=True` (static) additionally returns the per-tick
    decision trace `(actions (T,B), req_idx (T,B), severity (T,))` with
    req_idx in *global* request ids on both engines — the hook the
    per-decision bit-exactness pins compare.

    `fleet` (mutually exclusive with `dynamics`) switches to the (P,)
    provider axis (DESIGN.md §10): per-endpoint physics/schedules drive
    service and failover, `routing.route_requests` fixes each request's
    endpoint before dispatch, and `SimState.fleet` carries the
    per-endpoint split.  `phys` remains the *reference* physics the
    tail-EMA expectation is computed against (one canonical
    expectation, independent of which endpoint served the request).
    With P == 1 and no fleet dynamics the decision sequence is
    bit-exact with the single-provider engine.
    """
    n = batch.n
    if fleet is not None and dynamics is not None:
        raise ValueError(
            "fleet and dynamics are mutually exclusive: use "
            "FleetDynamics for per-endpoint schedules")
    windowed = sim_cfg.window is not None
    state0 = init_sim_state(n, n_classes(policy))
    has_brownout = dynamics is not None and dynamics.comfort_scale is not None
    has_limiter = dynamics is not None and dynamics.tb_refill is not None
    if has_limiter:
        # buckets start full: the burst capacity is available at t=0
        state0 = state0._replace(
            provider=state0.provider._replace(tb_tokens=dynamics.tb_capacity)
        )
    fl_dyn = fleet.dyn if fleet is not None else None
    has_fleet_limiter = fl_dyn is not None and fl_dyn.tb_refill is not None
    if fleet is not None:
        p = fleet.phys.base_ms.shape[0]
        fstate0 = init_fleet_state(p, n_classes(policy))
        if has_fleet_limiter:
            fstate0 = fstate0._replace(tb_tokens=fl_dyn.tb_capacity)
        state0 = state0._replace(
            req=state0.req._replace(endpoint=jnp.zeros((n,), jnp.int32)),
            fleet=fstate0,
        )

    def tick(carry, xs):
        state, win = carry
        state, win, ys = sim_tick(
            policy, phys, batch, jitter, state, win, xs,
            dt_ms=sim_cfg.dt_ms,
            k_slots=sim_cfg.k_slots,
            backend=sim_cfg.ordering_backend,
            dynamics=dynamics,
            fleet=fleet,
            collect_decisions=collect_decisions,
        )
        return (state, win), ys

    win0 = init_window_carry(sim_cfg.window, n) if windowed else None
    xs = (
        jnp.arange(sim_cfg.n_ticks),
        fl_dyn.comfort_scale if fl_dyn is not None
        else (dynamics.comfort_scale if has_brownout else None),
        fl_dyn.tb_refill if has_fleet_limiter
        else (dynamics.tb_refill if has_limiter else None),
        fl_dyn.avail if fl_dyn is not None else None,
    )
    (final, win), trace = jax.lax.scan(tick, (state0, win0), xs)
    # drain bookkeeping: completions that land exactly at/after the horizon
    final = final._replace(now_ms=final.now_ms + 1e9)
    if windowed:
        # retire through the window first (completions land here; the
        # canonical EMA sample stays bit-exact with the dense drain),
        # then run the full dense transition once: after _retire_window
        # nothing anywhere is INFLIGHT, so it reduces to exactly the
        # stale-abandonment pass — reaching requests the window never
        # admitted (arrived past the horizon, or overflow still queued)
        # with the one and only definition of the timeout rule.  O(N),
        # but once per run, not per tick.
        final, _ = _retire_window(policy, phys, batch, final, win)
    final = _complete_and_timeout(policy, phys, batch, final)
    if collect_decisions:
        return final, trace
    return final
