"""Tick-driven discrete-event engine, written as one `lax.scan`.

Hardware-adaptation note (DESIGN.md §3): the paper's simulator is an
implicit Python event loop; re-expressing it as a fixed-shape JAX scan
makes every policy sweep a single compiled program that `vmap`s over
seeds, regimes and stacked PolicyConfigs — this is what lets the full
benchmark suite (hundreds of runs) execute in seconds on one host and
would let a TPU host run thousands of what-if schedules per second
alongside the serving mesh.

Each tick:
  1. completions  (finish_ms <= now)  -> COMPLETED, update tail EMA
  2. timeouts     (pending too long)  -> ABANDONED (the implicit failure
                                         mode explicit shedding replaces)
  3. ONE batched dispatch pass (`schedule_batch`, DESIGN.md §3): up to
     `k_slots` grants from a single vectorized allocation -> ordering ->
     overload evaluation, applied as one scatter.  The per-tick policy
     cost is O(K·N + B·K) instead of the O(B·K·N) the former sequential
     slot loop paid; with k_slots=1 the tick is bit-exact with the
     sequential `schedule_slot` path.

Nonstationary provider dynamics (DESIGN.md §5): `run_sim` optionally
takes a `ProviderDynamics` whose (T,)-shaped schedules ride the scan as
xs — brownout comfort scaling applied to the tick's admissions, and a
per-class token-bucket rate limiter at the provider boundary whose
429-style bounces return the request to PENDING with a client-visible
retry-after.  Presence of each mechanism is pytree structure (None =
off), so scenario complexity costs nothing at trace time: the whole
horizon stays one `lax.scan` with no Python per-tick branching, and
`dynamics=None` compiles the exact stationary program.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import overload as olc
from repro.core.policy import ALLOC_ADRR, PolicyConfig, n_classes
from repro.core.scheduler import BatchDecision, schedule_batch
from repro.core.types import (
    ABANDONED,
    COMPLETED,
    INFLIGHT,
    PENDING,
    REJECTED,
    RequestBatch,
    SimState,
    init_sim_state,
)
from repro.sim.provider import (
    ProviderDynamics,
    ProviderPhysics,
    service_time_ms,
    unloaded_latency_ms,
)

EMA_ALPHA = 0.15


class SimConfig(NamedTuple):
    dt_ms: float = 25.0
    n_ticks: int = 6000
    k_slots: int = 4  # max grants per tick (batch dispatch width B)
    ordering_backend: str = "jnp"  # "jnp" | "pallas" (large-N path)


def _complete_and_timeout(
    cfg: PolicyConfig,
    phys: ProviderPhysics,
    batch: RequestBatch,
    state: SimState,
) -> SimState:
    req = state.req
    now = state.now_ms

    landed = (req.status == INFLIGHT) & (req.finish_ms <= now)
    # hard provider/application timeout: a request whose end-to-end latency
    # blew past timeout_mult x its deadline budget is a *failure*, not a
    # completion — this is the implicit failure mode (paper §2) that
    # explicit overload shedding exists to replace.
    e2e = req.finish_ms - batch.arrival_ms
    timed_out = landed & (
        e2e > cfg.timeout_mult[batch.bucket] * batch.deadline_budget_ms)
    done_now = landed & ~timed_out
    status = jnp.where(done_now, COMPLETED, jnp.where(timed_out, ABANDONED, req.status))

    # tail signal: observed end-to-end latency vs unloaded expectation
    expected = unloaded_latency_ms(phys, batch.true_tokens)
    ratio = jnp.where(done_now, e2e / jnp.maximum(expected, 1.0), 0.0)
    k = done_now.sum()
    mean_ratio = jnp.where(k > 0, ratio.sum() / jnp.maximum(k, 1), 0.0)
    ema = jnp.where(
        k > 0,
        state.sched.ema_latency_ratio
        + EMA_ALPHA * (mean_ratio - state.sched.ema_latency_ratio),
        state.sched.ema_latency_ratio,
    )

    # implicit client abandonment of stale pending work
    waited = now - batch.arrival_ms
    stale = (
        (status == PENDING)
        & (batch.arrival_ms <= now)
        & (waited > cfg.timeout_mult[batch.bucket] * batch.deadline_budget_ms)
    )
    status = jnp.where(stale, ABANDONED, status)

    inflight = (status == INFLIGHT).sum().astype(jnp.int32)
    inflight_tokens = jnp.where(status == INFLIGHT, batch.p50, 0.0).sum()

    return state._replace(
        req=req._replace(status=status),
        sched=state.sched._replace(
            ema_latency_ratio=ema,
            n_completed_obs=state.sched.n_completed_obs
            + k.astype(jnp.int32),
        ),
        provider=state.provider._replace(
            inflight=inflight, inflight_tokens=inflight_tokens
        ),
    )


def _apply_batch(
    cfg: PolicyConfig,
    phys: ProviderPhysics,
    batch: RequestBatch,
    jitter: jnp.ndarray,
    state: SimState,
    d: BatchDecision,
    comfort_scale=None,
    limiter: ProviderDynamics | None = None,
) -> SimState:
    """State transition for up to B grants, as one set of scatters.

    Grants target distinct requests by construction (each consumes a
    distinct entry of the ranked candidate lists), so the scatters never
    collide; idle rows are routed to the out-of-range index N and
    dropped.

    `comfort_scale` is this tick's brownout value (None = stationary);
    `limiter` enables the provider-boundary token bucket: an ADMIT whose
    class bucket is out of grants bounces 429-style — the request stays
    PENDING with `defer_until = now + retry_after` (the client-visible
    retry) and the DRR charge is refunded like any blocked release.
    Grants later in the same batch were decided against the optimistic
    inflight count (the client only observes the bounce after the send),
    which matches a real async client racing its own rate limit.
    """
    n = batch.n
    req = state.req
    admit = d.actions == olc.ADMIT
    defer = d.actions == olc.DEFER
    reject = d.actions == olc.REJECT
    idx = d.req_idx
    deficit = d.deficit

    if limiter is not None:
        k = state.provider.tb_tokens.shape[0]
        gcls = jnp.clip(batch.cls[idx], 0, k - 1)
        # g-th grant's rank among this batch's admits of the same class:
        # admit is allowed iff the bucket holds that many grants
        take = (gcls[:, None] == jnp.arange(k, dtype=jnp.int32)) & admit[:, None]
        rank = (jnp.cumsum(take, axis=0) * take).sum(axis=-1)  # (B,) 1-based
        allowed = rank.astype(jnp.float32) <= state.provider.tb_tokens[gcls] + 1e-6
        throttled = admit & ~allowed
        admit = admit & allowed

    # per-grant service physics at the inflight level the grant saw —
    # identical floats to the sequential one-admit-at-a-time path
    service = service_time_ms(
        phys, batch.true_tokens[idx], d.inflight_at, jitter[idx], comfort_scale
    )
    finish = state.now_ms + service
    backoff = olc.defer_backoff(cfg, d.severity, req.n_defers[idx])

    drop = jnp.int32(n)  # out-of-range => mode="drop" makes the row a no-op
    adm_i = jnp.where(admit, idx, drop)
    def_i = jnp.where(defer, idx, drop)
    rej_i = jnp.where(reject, idx, drop)

    status = req.status.at[adm_i].set(INFLIGHT, mode="drop")
    status = status.at[rej_i].set(REJECTED, mode="drop")
    submit = req.submit_ms.at[adm_i].set(state.now_ms, mode="drop")
    finish_ms = req.finish_ms.at[adm_i].set(finish, mode="drop")
    defer_until = req.defer_until.at[def_i].set(
        state.now_ms + backoff, mode="drop")
    n_defers = req.n_defers.at[def_i].add(1, mode="drop")
    n_throttles = req.n_throttles

    provider = state.provider
    if limiter is not None:
        thr_i = jnp.where(throttled, idx, drop)
        defer_until = defer_until.at[thr_i].set(
            state.now_ms + limiter.retry_after_ms, mode="drop")
        n_throttles = n_throttles.at[thr_i].add(1, mode="drop")
        consumed = (take & admit[:, None]).sum(axis=0).astype(jnp.float32)
        provider = provider._replace(
            tb_tokens=provider.tb_tokens - consumed,
            n_throttled=provider.n_throttled
            + throttled.sum().astype(jnp.int32),
        )
        # deficit conservation: the allocation layer charged for these
        # sends inside schedule_batch; the 429 blocked the release, so
        # credit it back exactly like a defer/reject refund (ADRR only).
        refund = (
            jax.nn.one_hot(gcls, k)
            * batch.p50[idx][:, None]
            * throttled[:, None]
        ).sum(axis=0) * (cfg.alloc_mode == ALLOC_ADRR)
        deficit = jnp.where(jnp.isfinite(deficit + refund),
                            deficit + refund, deficit)

    inflight = provider.inflight + admit.sum().astype(jnp.int32)
    inflight_tokens = provider.inflight_tokens + jnp.where(
        admit, batch.p50[idx], 0.0
    ).sum()

    return state._replace(
        req=req._replace(
            status=status,
            submit_ms=submit,
            finish_ms=finish_ms,
            defer_until=defer_until,
            n_defers=n_defers,
            n_throttles=n_throttles,
        ),
        sched=state.sched._replace(deficit=deficit, rr_turn=d.rr_turn),
        provider=provider._replace(
            inflight=inflight, inflight_tokens=inflight_tokens
        ),
    )


def run_sim(
    policy: PolicyConfig,
    batch: RequestBatch,
    jitter: jnp.ndarray,
    phys: ProviderPhysics,
    sim_cfg: SimConfig = SimConfig(),
    dynamics: ProviderDynamics | None = None,
) -> SimState:
    """Run the full horizon; returns the final SimState (jit-friendly).

    `dynamics` threads time-varying provider schedules through the scan
    as (T,)-shaped xs (DESIGN.md §5).  Which mechanisms exist is pytree
    structure — `dynamics=None` (or all-None fields) traces exactly the
    stationary program, and schedule *content* never changes trace size:
    scenario complexity is O(1) at compile time.
    """
    state0 = init_sim_state(batch.n, n_classes(policy))
    has_brownout = dynamics is not None and dynamics.comfort_scale is not None
    has_limiter = dynamics is not None and dynamics.tb_refill is not None
    if has_limiter:
        # buckets start full: the burst capacity is available at t=0
        state0 = state0._replace(
            provider=state0.provider._replace(tb_tokens=dynamics.tb_capacity)
        )

    def tick(state: SimState, xs):
        t_idx, comfort_t, refill_t = xs
        now = (t_idx + 1).astype(jnp.float32) * sim_cfg.dt_ms
        state = state._replace(now_ms=now)
        state = _complete_and_timeout(policy, phys, batch, state)
        if has_limiter:
            state = state._replace(
                provider=state.provider._replace(
                    tb_tokens=jnp.minimum(
                        state.provider.tb_tokens + refill_t,
                        dynamics.tb_capacity,
                    )
                )
            )
        d = schedule_batch(
            policy, batch, state,
            max_grants=sim_cfg.k_slots,
            backend=sim_cfg.ordering_backend,
        )
        state = _apply_batch(
            policy, phys, batch, jitter, state, d,
            comfort_scale=comfort_t,
            limiter=dynamics if has_limiter else None,
        )
        return state, None

    xs = (
        jnp.arange(sim_cfg.n_ticks),
        dynamics.comfort_scale if has_brownout else None,
        dynamics.tb_refill if has_limiter else None,
    )
    final, _ = jax.lax.scan(tick, state0, xs)
    # drain bookkeeping: completions that land exactly at/after the horizon
    final = final._replace(now_ms=final.now_ms + 1e9)
    final = _complete_and_timeout(policy, phys, batch, final)
    return final
