"""Congestion-aware mock provider (paper §4.1).

The mock preserves the causal chain the paper cares about:

    arrival shaping -> offered load -> load-dependent slowdown -> completions

Service time is linear in output tokens (the paper calibrates
latency_ms = 3294 + 18.7 * tokens against a production API, R^2 = 0.97 —
our constants differ but linearity is the property that matters; the
`benchmarks/latency_calibration.py` harness re-fits the line against our
real JAX serving engine) and is multiplied by a convex load factor when
the provider is driven past its comfortable concurrency.

The provider is intentionally *not* observable beyond completions: the
client sees latencies and its own outstanding count, matching the
black-box boundary.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class ProviderPhysics(NamedTuple):
    base_ms: jnp.ndarray          # () f32 fixed per-request overhead
    ms_per_token: jnp.ndarray     # () f32 linear generation cost
    comfort_concurrency: jnp.ndarray  # () f32 knee of the slowdown curve
    slowdown_slope: jnp.ndarray   # () f32 linear excess-load penalty
    slowdown_quad: jnp.ndarray    # () f32 quadratic excess-load penalty


def default_physics(
    base_ms: float = 90.0,
    ms_per_token: float = 6.5,
    comfort_concurrency: float = 4.0,
    slowdown_slope: float = 0.8,
    slowdown_quad: float = 0.5,
) -> ProviderPhysics:
    f = lambda x: jnp.asarray(x, jnp.float32)
    return ProviderPhysics(
        f(base_ms), f(ms_per_token), f(comfort_concurrency),
        f(slowdown_slope), f(slowdown_quad),
    )


def physics_for_arch(ms_per_token: float, base_ms: float = 90.0) -> ProviderPhysics:
    """Per-architecture provider: ms/token derived from the arch's
    roofline decode cost (see launch/dryrun.py artifacts)."""
    return default_physics(base_ms=base_ms, ms_per_token=ms_per_token)


def load_multiplier(phys: ProviderPhysics, inflight) -> jnp.ndarray:
    """Convex slowdown once offered load passes the comfort knee."""
    excess = jnp.maximum(
        jnp.asarray(inflight, jnp.float32) - phys.comfort_concurrency, 0.0
    ) / jnp.maximum(phys.comfort_concurrency, 1.0)
    return 1.0 + phys.slowdown_slope * excess + phys.slowdown_quad * excess**2


def unloaded_latency_ms(phys: ProviderPhysics, tokens) -> jnp.ndarray:
    return phys.base_ms + phys.ms_per_token * jnp.asarray(tokens, jnp.float32)


def service_time_ms(phys: ProviderPhysics, tokens, inflight, jitter) -> jnp.ndarray:
    """Realized service time for a request admitted with `inflight`
    concurrent jobs already outstanding; `jitter` is a per-request
    multiplicative noise term (~U[0.95, 1.05]) from the workload PRNG."""
    return unloaded_latency_ms(phys, tokens) * load_multiplier(phys, inflight) * jitter
