"""Congestion-aware mock provider (paper §4.1).

The mock preserves the causal chain the paper cares about:

    arrival shaping -> offered load -> load-dependent slowdown -> completions

Service time is linear in output tokens (the paper calibrates
latency_ms = 3294 + 18.7 * tokens against a production API, R^2 = 0.97 —
our constants differ but linearity is the property that matters; the
`benchmarks/latency_calibration.py` harness re-fits the line against our
real JAX serving engine) and is multiplied by a convex load factor when
the provider is driven past its comfortable concurrency.

The provider is intentionally *not* observable beyond completions: the
client sees latencies and its own outstanding count, matching the
black-box boundary.

Time-varying dynamics (DESIGN.md §5): real providers are not a fixed
curve.  `ProviderDynamics` carries (T,)-shaped per-tick schedules the
engine threads through its `lax.scan` —

  * **brownout windows**: `comfort_scale[t]` multiplies the comfort
    concurrency, so the same inflight level produces a steeper slowdown
    inside the window (capacity loss the client can only infer from
    latencies);
  * **per-class token-bucket rate limits**: `tb_refill[t]` grants/tick
    per service class against a `tb_capacity` burst; an admitted send
    that finds the bucket empty bounces with a 429-style rejection and
    a client-visible `retry_after_ms` (the request returns to PENDING
    with its defer clock set — the client observes the bounce, not the
    bucket).

Each field is None when that mechanism is off; None is pytree
*structure*, so jit specializes the engine statically without tracing a
branch per tick.  Schedules are built from a static `Scenario` spec
(sim/scenarios.py) inside the jit boundary.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core.numerics import pinned


class ProviderPhysics(NamedTuple):
    base_ms: jnp.ndarray          # () f32 fixed per-request overhead
    ms_per_token: jnp.ndarray     # () f32 linear generation cost
    comfort_concurrency: jnp.ndarray  # () f32 knee of the slowdown curve
    slowdown_slope: jnp.ndarray   # () f32 linear excess-load penalty
    slowdown_quad: jnp.ndarray    # () f32 quadratic excess-load penalty


def default_physics(
    base_ms: float = 90.0,
    ms_per_token: float = 6.5,
    comfort_concurrency: float = 4.0,
    slowdown_slope: float = 0.8,
    slowdown_quad: float = 0.5,
) -> ProviderPhysics:
    f = lambda x: jnp.asarray(x, jnp.float32)
    return ProviderPhysics(
        f(base_ms), f(ms_per_token), f(comfort_concurrency),
        f(slowdown_slope), f(slowdown_quad),
    )


def physics_for_arch(ms_per_token: float, base_ms: float = 90.0) -> ProviderPhysics:
    """Per-architecture provider: ms/token derived from the arch's
    roofline decode cost (see launch/dryrun.py artifacts)."""
    return default_physics(base_ms=base_ms, ms_per_token=ms_per_token)


def load_multiplier(
    phys: ProviderPhysics, inflight, comfort_scale=None
) -> jnp.ndarray:
    """Convex slowdown once offered load passes the comfort knee.

    `comfort_scale` (brownout schedule value) multiplies the comfort
    concurrency: scale < 1 moves the knee left, so the same inflight
    level is deeper into the convex region.  None (the stationary
    default) leaves the computation untouched.
    """
    comfort = phys.comfort_concurrency
    if comfort_scale is not None:
        comfort = comfort * jnp.asarray(comfort_scale, jnp.float32)
    excess = jnp.maximum(
        jnp.asarray(inflight, jnp.float32) - comfort, 0.0
    ) / jnp.maximum(comfort, 1.0)
    return 1.0 + phys.slowdown_slope * excess + phys.slowdown_quad * excess**2


def unloaded_latency_ms(phys: ProviderPhysics, tokens) -> jnp.ndarray:
    # the pin keeps this mul+add from FMA-contracting in only one of the
    # two engine programs that evaluate it over the same requests at
    # different widths — it feeds the tail-EMA ratio, part of the
    # engines' bit-exact contract (core/numerics.py, DESIGN.md §6)
    return phys.base_ms + pinned(
        phys.ms_per_token * jnp.asarray(tokens, jnp.float32))


def service_time_ms(
    phys: ProviderPhysics, tokens, inflight, jitter, comfort_scale=None
) -> jnp.ndarray:
    """Realized service time for a request admitted with `inflight`
    concurrent jobs already outstanding; `jitter` is a per-request
    multiplicative noise term (~U[0.95, 1.05]) from the workload PRNG.
    `comfort_scale` applies the brownout window active at admission —
    service time is fixed at admission, so a window inflates exactly the
    requests admitted inside it."""
    return (
        unloaded_latency_ms(phys, tokens)
        * load_multiplier(phys, inflight, comfort_scale)
        * jitter
    )


# ---------------------------------------------------------------------------
# Time-varying provider dynamics (DESIGN.md §5)
# ---------------------------------------------------------------------------


class ProviderDynamics(NamedTuple):
    """Per-tick provider schedules, threaded through the engine scan.

    All-or-nothing per mechanism: `comfort_scale` is None when no
    brownout is configured; `tb_refill`/`tb_capacity`/`retry_after_ms`
    are None together when no rate limiter is configured.  Build these
    inside the jit boundary (from a static scenario spec) so the None
    checks stay Python-static.
    """

    comfort_scale: Optional[jnp.ndarray]  # (T,) f32 brownout knee multiplier
    tb_refill: Optional[jnp.ndarray]      # (T, K) f32 grants refilled per tick
    tb_capacity: Optional[jnp.ndarray]    # (K,) f32 bucket burst size
    retry_after_ms: Optional[jnp.ndarray] # () f32 client-visible Retry-After


def no_dynamics() -> ProviderDynamics:
    """The stationary provider: every mechanism off."""
    return ProviderDynamics(None, None, None, None)


def brownout_schedule(
    n_ticks: int,
    dt_ms: float,
    windows: tuple[tuple[float, float, float], ...],
    span_ms: float,
) -> jnp.ndarray:
    """(T,) comfort multiplier: 1 everywhere except inside each window.

    Windows are `(start_frac, end_frac, scale)` as fractions of
    `span_ms` (the scenario's arrival span, not the raw sim horizon, so
    windows land on the traffic).  Overlapping windows compound by
    taking the minimum scale.
    """
    t_ms = (jnp.arange(n_ticks, dtype=jnp.float32) + 1.0) * dt_ms
    scale = jnp.ones((n_ticks,), jnp.float32)
    for start_frac, end_frac, s in windows:
        inside = (t_ms >= start_frac * span_ms) & (t_ms < end_frac * span_ms)
        scale = jnp.where(inside, jnp.minimum(scale, jnp.float32(s)), scale)
    return scale


def token_bucket_schedule(
    n_ticks: int,
    dt_ms: float,
    rate_rps: tuple[float, ...],
    burst: float,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-class refill schedule: `(T, K)` grants/tick and `(K,)` burst
    capacity for a limiter of `rate_rps[k]` sustained grants per second.
    Constant over time; `token_bucket_windows` layers piecewise rate
    changes on top.  The `(T, K)` shape is the engine contract either
    way — the scan consumes refill rows as xs without caring which
    builder produced them."""
    rate = jnp.asarray(rate_rps, jnp.float32)  # (K,)
    refill = jnp.broadcast_to(
        rate * (dt_ms / 1000.0), (n_ticks, rate.shape[0])
    )
    capacity = jnp.full((rate.shape[0],), jnp.float32(burst))
    return refill, capacity


# ---------------------------------------------------------------------------
# Fleet: a (P,) provider axis (DESIGN.md §10)
# ---------------------------------------------------------------------------


class FleetPhysics(NamedTuple):
    """`ProviderPhysics` stacked along a (P,) endpoint axis.

    Every leaf is (P,)-shaped; `service_time_ms` works unchanged on a
    per-grant gather of these leaves (a `ProviderPhysics` whose leaves
    are (B,)-shaped), because the physics formulas are elementwise.
    """

    base_ms: jnp.ndarray              # (P,) f32
    ms_per_token: jnp.ndarray         # (P,) f32
    comfort_concurrency: jnp.ndarray  # (P,) f32
    slowdown_slope: jnp.ndarray       # (P,) f32
    slowdown_quad: jnp.ndarray        # (P,) f32


class FleetDynamics(NamedTuple):
    """Per-tick, per-endpoint schedules for the fleet engine scan.

    The fleet generalization of `ProviderDynamics`: each schedule gains
    a (P,) endpoint axis, plus `avail` — endpoint availability, the
    failover mechanism.  An endpoint whose `avail[t, p] < 0.5` refuses
    new work *and* kills its in-flight requests: the engine requeues
    them (status back to PENDING, `defer_until = now + retry_after_ms`,
    a throttle-count bump) and the client re-dispatches elsewhere.
    None fields follow the single-provider convention (absence is pytree
    structure); `retry_after_ms` is always present — both the limiter
    bounce and the failover requeue use it.
    """

    avail: Optional[jnp.ndarray]          # (T, P) f32 0/1 endpoint up
    comfort_scale: Optional[jnp.ndarray]  # (T, P) f32 brownout multiplier
    tb_refill: Optional[jnp.ndarray]      # (T, P, K) f32 grants per tick
    tb_capacity: Optional[jnp.ndarray]    # (P, K) f32 bucket burst size
    retry_after_ms: jnp.ndarray           # () f32 client-visible Retry-After


class Fleet(NamedTuple):
    """Static-shape bundle `run_sim(..., fleet=...)` consumes."""

    phys: FleetPhysics
    dyn: FleetDynamics


def uniform_fleet_physics(phys: ProviderPhysics, p: int,
                          speed_mult=None,
                          comfort_mult=None) -> FleetPhysics:
    """Broadcast one endpoint's physics across a fleet of P.

    `speed_mult[p]` scales the per-token cost (values < 1 are *faster*
    endpoints); `comfort_mult[p]` scales the comfort knee — together
    they express skewed fleets (regions, model tiers) without a second
    physics model.
    """
    ones = jnp.ones((p,), jnp.float32)
    speed = ones if speed_mult is None else jnp.asarray(speed_mult, jnp.float32)
    comfort = ones if comfort_mult is None \
        else jnp.asarray(comfort_mult, jnp.float32)
    return FleetPhysics(
        base_ms=jnp.broadcast_to(phys.base_ms, (p,)),
        ms_per_token=phys.ms_per_token * speed,
        comfort_concurrency=phys.comfort_concurrency * comfort,
        slowdown_slope=jnp.broadcast_to(phys.slowdown_slope, (p,)),
        slowdown_quad=jnp.broadcast_to(phys.slowdown_quad, (p,)),
    )


def availability_schedule(
    n_ticks: int,
    dt_ms: float,
    fail_windows: tuple[tuple[int, float, float], ...],
    span_ms: float,
    p: int,
) -> jnp.ndarray:
    """(T, P) availability: 1 everywhere except inside each endpoint's
    fail window.  Windows are `(endpoint, start_frac, end_frac)` over
    the arrival span (like brownouts, so failures land on the traffic).
    """
    t_ms = (jnp.arange(n_ticks, dtype=jnp.float32) + 1.0) * dt_ms
    avail = jnp.ones((n_ticks, p), jnp.float32)
    for ep, start_frac, end_frac in fail_windows:
        inside = (t_ms >= start_frac * span_ms) & (t_ms < end_frac * span_ms)
        avail = avail.at[:, ep].set(
            jnp.where(inside, 0.0, avail[:, ep]))
    return avail


def fleet_brownout_schedule(
    n_ticks: int,
    dt_ms: float,
    windows: tuple[tuple[int, float, float, float], ...],
    span_ms: float,
    p: int,
) -> jnp.ndarray:
    """(T, P) comfort multiplier: the per-endpoint `brownout_schedule`.
    Windows are `(endpoint, start_frac, end_frac, scale)`; overlapping
    windows on one endpoint compound by minimum, other endpoints stay
    at 1."""
    t_ms = (jnp.arange(n_ticks, dtype=jnp.float32) + 1.0) * dt_ms
    scale = jnp.ones((n_ticks, p), jnp.float32)
    for ep, start_frac, end_frac, s in windows:
        inside = (t_ms >= start_frac * span_ms) & (t_ms < end_frac * span_ms)
        scale = scale.at[:, ep].set(
            jnp.where(inside, jnp.minimum(scale[:, ep], jnp.float32(s)),
                      scale[:, ep]))
    return scale


def token_bucket_windows(
    n_ticks: int,
    dt_ms: float,
    rate_rps: tuple[float, ...],
    burst: float,
    windows: tuple[tuple[float, float, float], ...],
    span_ms: float,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Time-varying refill: the constant per-class schedule scaled by
    piecewise windows — real providers tighten rate limits mid-incident
    and restore them later, which is exactly the regime where
    client-side retry policy starts to matter.

    Windows are `(start_frac, end_frac, rate_mult)` as fractions of
    `span_ms` (the scenario's arrival span, like brownouts, so windows
    land on the traffic).  Overlapping windows compound by taking the
    minimum multiplier — a crunch inside a crunch keeps the tighter
    limit.  `rate_mult` may be 0 (a full refill freeze: only the burst
    capacity remains until the window lifts).  Burst capacity is not
    rescaled: the paper's 429 contract is about sustained rate, and a
    capacity cut mid-run could strand already-held tokens above the cap.
    """
    refill, capacity = token_bucket_schedule(n_ticks, dt_ms, rate_rps, burst)
    t_ms = (jnp.arange(n_ticks, dtype=jnp.float32) + 1.0) * dt_ms
    scale = jnp.ones((n_ticks,), jnp.float32)
    for start_frac, end_frac, m in windows:
        if m < 0:
            raise ValueError(f"rate_mult must be >= 0, got {m}")
        inside = (t_ms >= start_frac * span_ms) & (t_ms < end_frac * span_ms)
        scale = jnp.where(inside, jnp.minimum(scale, jnp.float32(m)), scale)
    return refill * scale[:, None], capacity
