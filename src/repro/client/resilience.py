"""Client-side resilience: the watchdog that survives a lying provider.

`ClientSession` trusts the transport by default: an accepted submit is
assumed to eventually produce exactly one completion.  Against a
provider that breaks that contract (sim/faults.py — silent drops, stuck
requests, duplicate deliveries, lying Retry-After), trust means a hung
session: an INFLIGHT slot only retires when its completion lands, so
one dropped completion pins its window slot and hangs `drain` forever.

The recovery design (wired into `ClientSession.poll` when the session
is built with a `ResilienceConfig`):

  * **Client-side deadline.**  Every accepted attempt gets a watchdog
    deadline derived from client-observable priors only: the unloaded
    latency expectation at the p90 token prior
    (`base_ms + ms_per_token * p90`) times `timeout_mult`, floored at
    `min_deadline_ms`.  No server cooperation is assumed — the deadline
    is the client's own bet on "this should have landed by now".
  * **Bounded-budget resubmission.**  An attempt past its deadline with
    no completion in sight is resubmitted — same request, same session
    rid (the idempotency key), a fresh provider ticket — at most
    `max_resubmits` times.  The old ticket stays mapped: attempts RACE,
    first completion wins, the loser is discarded by the session's
    dup-safe ingestion.  Each accepted resubmit charges the request's
    p50 against its class's ADRR deficit
    (`core.scheduler.charge_resubmit`) so recovery traffic cannot
    starve another class.  A 429 on the resubmit consumes no budget —
    the watchdog backs off by the (sanitized) hint and retries the
    check later.
  * **Give-up.**  With the budget exhausted, the watchdog waits for the
    slot's own timeout threshold to pass and then injects a *synthetic*
    completion stamped `finish = now`: the ordinary retirement chain —
    device and host mirror alike — classifies it `timed_out` and
    retires the slot ABANDONED.  No special retirement path exists;
    give-up is just a completion the classifier is guaranteed to reject
    on the e2e bound, which is what keeps the donated-buffer tick free
    of a second retire mechanism (and `drain` guaranteed to terminate).

The watchdog never touches device state directly: it edits the
host-side completion dict before the scatter, submits through the same
provider boundary as the grant loop, and reports its deficit charge as
one (K,) array folded into the fused tick.  Sessions built without a
`ResilienceConfig` trace and execute the exact pre-resilience program.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, NamedTuple

import numpy as np

from repro.sim.provider import ProviderPhysics

if TYPE_CHECKING:  # pragma: no cover
    from repro.client.request import Request


class ResilienceConfig(NamedTuple):
    """Static watchdog knobs (hashable; `None` on the session = off)."""

    # client-side deadline = unloaded p90 latency x timeout_mult,
    # floored at min_deadline_ms.  The mult must absorb honest queueing
    # + load slowdown; too tight wastes resubmit budget on false
    # positives (harmless — first completion wins — but it is provider
    # load and deficit charge), too loose stretches recovery latency.
    timeout_mult: float = 6.0
    min_deadline_ms: float = 1_000.0
    # resubmission budget per request (attempts beyond the first)
    max_resubmits: int = 2


class _Tracked:
    """Watchdog entry for one in-flight session rid."""

    __slots__ = ("tickets", "deadline_ms", "n_resubmits", "gave_up")

    def __init__(self, ticket: int, deadline_ms: float):
        self.tickets = [ticket]      # every live provider ticket (racing)
        self.deadline_ms = deadline_ms
        self.n_resubmits = 0
        self.gave_up = False


class Watchdog:
    """Per-request deadline tracking + resubmission budget accounting.

    Owns no clock and no provider: `ClientSession.poll` drives it once
    per epoch and performs the actual submits, so the watchdog stays a
    pure bookkeeping structure (deterministic, trivially testable).
    """

    def __init__(self, cfg: ResilienceConfig, phys: ProviderPhysics):
        self.cfg = cfg
        self._base = float(np.asarray(phys.base_ms))
        self._ms_per_token = float(np.asarray(phys.ms_per_token))
        self._by_rid: dict[int, _Tracked] = {}
        self.n_resubmits = 0
        self.n_gave_up = 0

    def deadline_ms(self, req: "Request") -> float:
        """Relative client-side deadline for one attempt of `req`."""
        unloaded = self._base + self._ms_per_token * float(req.resolved_p90())
        return max(unloaded * self.cfg.timeout_mult, self.cfg.min_deadline_ms)

    # --- lifecycle driven by the session ------------------------------
    def note_admit(self, rid: int, req: "Request", ticket: int,
                   now_ms: float) -> None:
        """An initial submit was accepted: start the deadline clock."""
        self._by_rid[rid] = _Tracked(ticket, now_ms + self.deadline_ms(req))

    def note_resubmit(self, rid: int, req: "Request", ticket: int,
                      now_ms: float) -> None:
        """A resubmit was accepted: consume budget, reset the deadline."""
        e = self._by_rid[rid]
        e.tickets.append(ticket)
        e.n_resubmits += 1
        e.deadline_ms = now_ms + self.deadline_ms(req)
        self.n_resubmits += 1

    def note_bounced(self, rid: int, delay_ms: float, now_ms: float) -> None:
        """A resubmit was 429'd: no budget consumed, re-check after the
        (already sanitized) backoff."""
        self._by_rid[rid].deadline_ms = now_ms + max(delay_ms, 1.0)

    def note_terminal(self, rid: int) -> list[int]:
        """The rid retired (completed/abandoned/rejected): stop tracking
        and return every ticket the session must unmap — late arrivals
        on those tickets are discarded at ingestion."""
        e = self._by_rid.pop(rid, None)
        return e.tickets if e is not None else []

    # --- the per-epoch scan -------------------------------------------
    def overdue(self, now_ms: float) -> list[int]:
        """Tracked rids past their deadline, in rid order (deterministic
        resubmission order regardless of dict history)."""
        return sorted(
            rid for rid, e in self._by_rid.items()
            if not e.gave_up and now_ms >= e.deadline_ms)

    def budget_left(self, rid: int) -> bool:
        return self._by_rid[rid].n_resubmits < self.cfg.max_resubmits

    def give_up(self, rid: int) -> None:
        e = self._by_rid[rid]
        if not e.gave_up:
            e.gave_up = True
            self.n_gave_up += 1

    def next_deadline_ms(self) -> float:
        """Earliest pending watchdog deadline (idle-sleep hint)."""
        return min(
            (e.deadline_ms for e in self._by_rid.values() if not e.gave_up),
            default=float("inf"))
