"""The async provider boundary: what the client sees of the black box.

`AsyncProvider` is the transport contract `ClientSession` schedules
against — deliberately tiny, matching the paper's black-box premise:

  * `submit(req, now_ms, ...)` is NON-blocking: it either accepts the
    request (work proceeds out of band; completion arrives via `poll`)
    or bounces it 429-style with a client-visible `retry_after_ms`.
    Nothing about service time is revealed at submission.
  * `poll(now_ms)` drains completions that have landed by `now_ms`.
  * `inflight()` is the provider's actual outstanding count — the
    session's concurrency accounting reflects this real number instead
    of bracketing a blocking call one request at a time.
  * `next_event_ms(now_ms)` is an optional scheduling hint (earliest
    time anything can change) so an idle session can sleep instead of
    spinning; transports that cannot know return None.

Two implementations live here / in `repro.client.blackbox`:

  * `MockProvider` — the simulator's provider physics and nonstationary
    dynamics (sim/provider.py) behind the async API: load-dependent
    service times, brownout comfort windows, and the per-class
    token-bucket rate limiter with 429 bounces.  Its arithmetic
    deliberately mirrors the engine's float-for-float (np.float32,
    same operation order) so a `ClientSession` replaying a generated
    trace in virtual time reproduces the windowed sim engine's decision
    sequence (tests/test_serving_client.py pins this).
  * `AsyncBlackBoxProvider` (repro.client.blackbox) — the real JAX
    serving engine behind the same protocol via a thread pool.
"""
from __future__ import annotations

from typing import (
    TYPE_CHECKING,
    Callable,
    NamedTuple,
    Optional,
    Protocol,
    runtime_checkable,
)

import numpy as np

from repro.sim.faults import FaultSchedule, fault_draw
from repro.sim.provider import ProviderPhysics, default_physics

if TYPE_CHECKING:  # pragma: no cover
    from repro.client.request import Request


class SubmitResult(NamedTuple):
    """Outcome of a non-blocking submit."""

    accepted: bool
    retry_after_ms: float = 0.0   # 429 Retry-After hint when not accepted
    ticket: int = -1              # provider-scoped handle when accepted


class Completion(NamedTuple):
    """One landed request, reported by `poll`."""

    ticket: int
    finish_ms: float              # session-clock completion time
    output: Optional[np.ndarray] = None


@runtime_checkable
class AsyncProvider(Protocol):
    """Transport contract the session schedules against (see module
    docstring).  `inflight_hint` is the client's own concurrency view at
    decision time; transports may ignore it."""

    def submit(self, req: "Request", now_ms: float,
               inflight_hint: int | None = None) -> SubmitResult: ...

    def poll(self, now_ms: float) -> list[Completion]: ...

    def inflight(self) -> int: ...

    def next_event_ms(self, now_ms: float) -> Optional[float]: ...


# --- Retry-After policies (the 429 backoff hook) ---------------------------

RetryPolicy = Callable[[float, int], float]


def sanitize_retry_after_ms(retry_after_ms: float) -> float:
    """Clamp a hostile Retry-After hint before any retry policy sees it.

    A real provider can return anything: negative, NaN, or infinite
    hints all occur in the wild (clock skew, serialization bugs, plain
    lies — `FaultSchedule.retry_lie_mult` models them).  Unclamped, a
    negative hint produces a defer expiry in the past (the request
    thrashes every epoch) and a NaN poisons every downstream comparison
    — the fleet router's argmin, the session's idle-sleep hint.  Policy:
    non-finite or negative collapses to 0.0 ("retry whenever you like"),
    which the session's own backoff then shapes; honest hints pass
    through unchanged.
    """
    r = float(retry_after_ms)
    if not np.isfinite(r) or r < 0.0:
        return 0.0
    return r


def honor_retry_after(retry_after_ms: float, n_throttles: int) -> float:
    """Default: wait exactly what the provider asked."""
    return retry_after_ms


def expo_retry(mult: float = 1.0, growth: float = 2.0,
               cap_ms: float = 60_000.0, jitter: float = 0.2,
               seed: int = 0) -> RetryPolicy:
    """Retry-After-seeded exponential backoff with decorrelation jitter.

    The provider's hint is the base, repeated bounces of the same
    request grow it geometrically, and each computed delay is smeared
    uniformly over ±`jitter` (default ±20%).  The jitter matters under
    shared rate limits: a 429 burst hands every bounced request the same
    Retry-After, and un-jittered exponential backoff retries them in
    lockstep forever — each synchronized wave re-exhausts the bucket and
    re-bounces the same cohort.  Seeded so replays stay deterministic;
    pass `jitter=0.0` for the exact geometric schedule.
    """
    if not 0.0 <= jitter < 1.0:
        raise ValueError(f"jitter must be in [0, 1), got {jitter}")
    rng = np.random.default_rng(seed)

    def policy(retry_after_ms: float, n_throttles: int) -> float:
        base = min(retry_after_ms * mult * growth ** max(n_throttles - 1, 0),
                   cap_ms)
        if jitter:
            base *= 1.0 + jitter * (2.0 * rng.random() - 1.0)
        return base
    return policy


def _f32(x) -> np.float32:
    return np.float32(x)


def _fma32(a: np.float32, b: np.float32, c: np.float32) -> np.float32:
    """Single-rounded a*b + c in float32 — the fused multiply-add
    XLA:CPU emits for the engine's trailing `service * jitter + now`.
    Emulated exactly via float64 (Python floats ARE IEEE binary64): the
    f32 product a*b is exact in f64 (48 significand bits), and rounding
    the f64 sum to f32 matches the hardware FMA except on
    double-rounding boundary cases ~2^-29 wide — none of which the
    pinned parity traces cross."""
    return np.float32(float(a) * float(b) + float(c))


class MockProvider:
    """Sim-dynamics provider behind the async boundary.

    Service physics, brownout schedule, and the token-bucket limiter are
    exactly `sim/provider.py`'s, evaluated in strict per-op float32 so
    results are bit-identical to the engine's vectorized evaluation
    (both are IEEE f32 with the same operation order; the engine pins
    the contractible chains — `unloaded_latency_ms`, the EMA — behind
    `core.numerics.pinned`, so XLA cannot re-associate them either).

    Tick alignment: schedules are `(T,)`/`(T, K)` per-tick rows like the
    engine's scan xs.  A poll/submit at `now_ms` first applies every
    refill row r with (r + 1) * dt_ms <= now_ms (the engine applies row
    t before dispatching at now = (t+1) dt), and the brownout row for
    the current tick scales the comfort knee of admissions inside it.

    Token-bucket semantics match `_apply_batch`: grants within one
    decision epoch (one distinct `now_ms`) are ranked per class against
    the bucket level at epoch start, accepted grants consume one token,
    bounces consume nothing and carry `retry_after_ms`.

    `faults` breaks the contract on purpose (sim/faults.py): per-ticket
    deterministic draws decide which accepted submits get stuck
    (service x stuck_mult), which landed completions are silently
    dropped or redelivered `dup_extra` extra times with divergent
    payload stamps, and 429 hints are scaled by `retry_lie_mult`.
    `faults=None` (the default) executes the exact honest path —
    byte-identical to the pre-fault provider, which is what keeps the
    sim<->live parity pins valid.  `fault_salt` decorrelates fault
    streams across a fleet's child endpoints.
    """

    def __init__(
        self,
        phys: ProviderPhysics | None = None,
        *,
        dt_ms: float = 25.0,
        comfort_scale: Optional[np.ndarray] = None,   # (T,) brownout rows
        tb_refill: Optional[np.ndarray] = None,       # (T, K) grants/tick
        tb_capacity: Optional[np.ndarray] = None,     # (K,) burst size
        retry_after_ms: float = 1500.0,
        faults: FaultSchedule | None = None,
        fault_salt: int = 0,
    ):
        phys = phys if phys is not None else default_physics()
        self.phys = phys
        self._base = _f32(np.asarray(phys.base_ms))
        self._ms_per_token = _f32(np.asarray(phys.ms_per_token))
        self._comfort = _f32(np.asarray(phys.comfort_concurrency))
        self._slope = _f32(np.asarray(phys.slowdown_slope))
        self._quad = _f32(np.asarray(phys.slowdown_quad))
        self.dt_ms = float(dt_ms)
        self._comfort_rows = (
            None if comfort_scale is None
            else np.asarray(comfort_scale, np.float32))
        self._refill_rows = (
            None if tb_refill is None else np.asarray(tb_refill, np.float32))
        if (self._refill_rows is None) != (tb_capacity is None):
            raise ValueError("tb_refill and tb_capacity go together")
        self._capacity = (
            None if tb_capacity is None
            else np.asarray(tb_capacity, np.float32))
        self.retry_after_ms = float(retry_after_ms)
        # bucket starts full: burst capacity available at t=0 (engine
        # seeds tb_tokens the same way in run_sim)
        self._tb = None if self._capacity is None else self._capacity.copy()
        self._rows_applied = 0
        self._epoch_now = -np.inf   # decision epoch = one distinct now_ms
        self._epoch_tokens0 = (
            None if self._tb is None else self._tb.copy())
        self._epoch_rank = (
            None if self._tb is None
            else np.zeros(self._capacity.shape[0], np.int64))
        self._outstanding: dict[int, tuple[np.float32, "Request"]] = {}
        self._next_ticket = 0
        self.n_throttled = 0
        self.n_accepted = 0
        self._faults = (faults if faults is not None and faults.injects
                        else None)
        self._fault_salt = int(fault_salt)
        # dup redeliveries waiting their delay: (deliver_at_ms, Completion)
        self._pending_dups: list[tuple[float, Completion]] = []
        self.n_dropped = 0     # completions computed but never delivered
        self.n_stuck = 0       # submits whose service time was inflated
        self.n_duped = 0       # completions scheduled for redelivery
        # loaded-latency memo: the slowdown chain is pure in
        # (tokens, inflight, brownout row), and real pools cycle through
        # a handful of such triples per epoch — caching the f32 result
        # keeps the per-submit host cost flat (values are the cached
        # outputs of the exact same op chain, so replays stay
        # bit-identical)
        self._svc_cache: dict[tuple, np.float32] = {}

    @classmethod
    def from_scenario(cls, scenario, n_requests: int, n_ticks: int,
                      dt_ms: float, k: int,
                      phys: ProviderPhysics | None = None) -> "MockProvider":
        """Build the provider side of a registry `Scenario` — the same
        schedules `run_sim` threads through its scan, so nonstationary
        regimes (brownouts, rate_crunch) replay against the live path."""
        from repro.sim.scenarios import build_dynamics
        dyn = build_dynamics(scenario, n_ticks, dt_ms, n_requests, k)
        faults = scenario.faults
        if dyn is None:
            return cls(phys, dt_ms=dt_ms, faults=faults)
        retry = (float(np.asarray(dyn.retry_after_ms))
                 if dyn.retry_after_ms is not None else 1500.0)
        return cls(
            phys,
            dt_ms=dt_ms,
            comfort_scale=(None if dyn.comfort_scale is None
                           else np.asarray(dyn.comfort_scale)),
            tb_refill=(None if dyn.tb_refill is None
                       else np.asarray(dyn.tb_refill)),
            tb_capacity=(None if dyn.tb_capacity is None
                         else np.asarray(dyn.tb_capacity)),
            retry_after_ms=retry,
            faults=faults,
        )

    # --- time ---------------------------------------------------------
    def _advance(self, now_ms: float) -> None:
        """Apply refill rows due by `now_ms`; open a new decision epoch
        when the clock moved."""
        if self._refill_rows is not None:
            target = int(np.floor(now_ms / self.dt_ms + 1e-6))
            target = min(target, self._refill_rows.shape[0])
            while self._rows_applied < target:
                self._tb = np.minimum(
                    self._tb + self._refill_rows[self._rows_applied],
                    self._capacity)
                self._rows_applied += 1
        if now_ms != self._epoch_now:
            self._epoch_now = now_ms
            if self._tb is not None:
                self._epoch_tokens0 = self._tb.copy()
                self._epoch_rank[:] = 0

    def _tick_index(self, now_ms: float, n_rows: int) -> int:
        t = int(np.floor(now_ms / self.dt_ms + 1e-6)) - 1
        return min(max(t, 0), n_rows - 1)

    # --- physics ------------------------------------------------------
    def _finish_ms(self, tokens: float, inflight: int, jitter: float,
                   now_ms: float) -> np.float32:
        """`now + sim/provider.service_time_ms(...)` with the engine's
        realized rounding: strict per-op float32 through the slowdown
        chain, then the trailing `* jitter + now` as one fused
        multiply-add (see `_fma32` — XLA:CPU contracts exactly that pair
        inside the engine's apply fusion)."""
        row = -1
        if self._comfort_rows is not None:
            row = self._tick_index(now_ms, self._comfort_rows.shape[0])
        key = (tokens, inflight, row)
        loaded = self._svc_cache.get(key)
        if loaded is None:
            comfort = self._comfort
            if row >= 0:
                comfort = comfort * self._comfort_rows[row]
            unloaded = self._base + self._ms_per_token * _f32(tokens)
            excess = np.maximum(_f32(inflight) - comfort, _f32(0.0)) \
                / np.maximum(comfort, _f32(1.0))
            mult = _f32(1.0) + self._slope * excess \
                + self._quad * (excess * excess)
            loaded = unloaded * mult
            if len(self._svc_cache) > 4096:
                self._svc_cache.clear()
            self._svc_cache[key] = loaded
        # inline _fma32(loaded, _f32(jitter), _f32(now_ms)): jitter and
        # now_ms round to f32 first (float(np.float32(x)) is exact), the
        # f64 multiply-add is single-rounded to f32 at the end
        return np.float32(
            float(loaded) * float(np.float32(jitter))
            + float(np.float32(now_ms)))

    # --- AsyncProvider ------------------------------------------------
    def submit(self, req: "Request", now_ms: float,
               inflight_hint: int | None = None) -> SubmitResult:
        self._advance(now_ms)
        if self._tb is not None:
            k = self._capacity.shape[0]
            c = min(max(req.resolved_cls(), 0), k - 1)
            self._epoch_rank[c] += 1
            allowed = (np.float32(self._epoch_rank[c])
                       <= self._epoch_tokens0[c] + np.float32(1e-6))
            if not allowed:
                self.n_throttled += 1
                retry = self.retry_after_ms
                if self._faults is not None \
                        and self._faults.retry_lie_mult != 1.0:
                    # lying Retry-After: the hint no longer reflects the
                    # real refill (may go negative/non-finite — the
                    # client must sanitize, not trust)
                    retry = retry * float(self._faults.retry_lie_mult)
                return SubmitResult(False, retry)
            self._tb[c] = self._tb[c] - np.float32(1.0)
        # service physics at the client's optimistic concurrency view
        # when provided: the engine prices grant g at the inflight count
        # the *decision* saw (every prior ADMIT in the epoch, including
        # ones a rate limit later bounced), which is what a real async
        # client racing its own limit observes.  Fall back to the true
        # outstanding count for hint-less transports.
        inflight = (inflight_hint if inflight_hint is not None
                    else len(self._outstanding))
        finish = self._finish_ms(req.max_new, inflight, req.jitter, now_ms)
        ticket = self._next_ticket
        self._next_ticket += 1
        if self._faults is not None \
                and fault_draw(self._faults, self._fault_salt, ticket).stuck:
            # stuck request: the realized service time (finish - now)
            # inflates by stuck_mult, pushing the completion past any
            # sane timeout horizon; a resubmit draws a fresh ticket and
            # therefore a fresh (independent) verdict
            now32 = float(np.float32(now_ms))
            finish = np.float32(
                now32 + (float(finish) - now32) * self._faults.stuck_mult)
            self.n_stuck += 1
        self._outstanding[ticket] = (finish, req)
        self.n_accepted += 1
        return SubmitResult(True, 0.0, ticket=ticket)

    def poll(self, now_ms: float) -> list[Completion]:
        self._advance(now_ms)
        # deliver in (finish_ms, ticket) order.  Dict insertion order is
        # ascending *ticket* order, which coincides with finish order
        # only while service times are monotone along the submit stream
        # — stuck/dup faults and heterogeneous service break that, so
        # delivery order is pinned explicitly (the decision-parity tests
        # hold either way: the session ingests by sorted rid)
        done = sorted(
            (float(f), t) for t, (f, _) in self._outstanding.items()
            if f <= now_ms)
        out = []
        for finish, t in done:
            self._outstanding.pop(t)
            if self._faults is not None:
                d = fault_draw(self._faults, self._fault_salt, t)
                if d.drop:
                    # silent drop: computed, never delivered — the
                    # client-visible symptom is an RPC that never
                    # resolves
                    self.n_dropped += 1
                    continue
                if d.dup:
                    fs = self._faults
                    for i in range(1, fs.dup_extra + 1):
                        self._pending_dups.append((
                            finish + i * fs.dup_delay_ms,
                            # divergent payload: redelivered copies
                            # disagree about when the work finished
                            Completion(t, finish + i * fs.dup_jitter_ms,
                                       None)))
                    self.n_duped += 1
            out.append(Completion(t, float(finish), None))
        if self._pending_dups:
            due = [(at, c) for at, c in self._pending_dups if at <= now_ms]
            if due:
                self._pending_dups = [
                    x for x in self._pending_dups if x[0] > now_ms]
                due.sort(key=lambda x: (x[0], x[1].ticket))
                out.extend(c for _, c in due)
        return out

    def inflight(self) -> int:
        return len(self._outstanding)

    def next_event_ms(self, now_ms: float) -> Optional[float]:
        cands = [float(f) for f, _ in self._outstanding.values()]
        cands.extend(at for at, _ in self._pending_dups)
        if self._refill_rows is not None \
                and self._rows_applied < self._refill_rows.shape[0]:
            # next refill row lands at (rows_applied + 1) * dt
            cands.append((self._rows_applied + 1) * self.dt_ms)
        return min(cands) if cands else None
