"""The real JAX engine behind the `AsyncProvider` protocol.

`AsyncBlackBoxProvider` adapts any object with the blocking
`submit(prompt, max_new) -> output` surface (`repro.serving.
BlackBoxProvider` wrapping the real model, or any stand-in) into the
session's non-blocking boundary: submissions run on a small thread
pool, `poll` harvests finished futures, and `inflight()` is the true
outstanding count — which is what lets `ClientSession` keep several
requests in flight against the engine instead of bracketing one
blocking call at a time.

An optional `max_inflight` turns the adapter into a 429-emitting
boundary: a submit that would exceed it bounces with `retry_after_ms`,
exercising the same Retry-After path the mock's token bucket does —
useful for driving the session's backoff hook against real hardware.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.client.provider import Completion, SubmitResult

if TYPE_CHECKING:  # pragma: no cover
    from repro.client.request import Request


class AsyncBlackBoxProvider:
    """Thread-pool async facade over a blocking `submit(prompt, max_new)`
    provider.  Completion `finish_ms` is stamped with the session clock
    at the poll that observes the finished future (poll-cadence
    granularity — the client cannot see inside the black box)."""

    def __init__(self, provider, *, max_workers: int = 4,
                 max_inflight: Optional[int] = None,
                 retry_after_ms: float = 500.0):
        self._provider = provider
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._lock = threading.Lock()
        self._futures: dict[int, Future] = {}
        self._next_ticket = 0
        self.max_inflight = max_inflight
        self.retry_after_ms = float(retry_after_ms)
        self.n_throttled = 0
        self.n_accepted = 0

    def submit(self, req: "Request", now_ms: float,
               inflight_hint: int | None = None) -> SubmitResult:
        with self._lock:
            if self.max_inflight is not None \
                    and len(self._futures) >= self.max_inflight:
                self.n_throttled += 1
                return SubmitResult(False, self.retry_after_ms)
            ticket = self._next_ticket
            self._next_ticket += 1
            prompt = req.prompt if req.prompt is not None \
                else np.zeros((8,), np.int32)
            fut = self._pool.submit(
                self._provider.submit, prompt, int(req.max_new))
            self._futures[ticket] = fut
            self.n_accepted += 1
        return SubmitResult(True, 0.0, ticket=ticket)

    def poll(self, now_ms: float) -> list[Completion]:
        out = []
        with self._lock:
            done = sorted(t for t, f in self._futures.items() if f.done())
            for t in done:
                fut = self._futures.pop(t)
                out.append(Completion(t, float(now_ms), fut.result()))
        return out

    def inflight(self) -> int:
        with self._lock:
            return len(self._futures)

    def next_event_ms(self, now_ms: float) -> Optional[float]:
        return None  # an opaque transport cannot predict completions

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
