"""FleetProvider: P async endpoints behind one `AsyncProvider` face.

The live-path sibling of the engine's fleet mode (DESIGN.md §10): a
session schedules against ONE provider boundary, and this adapter
multiplexes it over P child `AsyncProvider`s using the same routing
cost model as `core.routing.route_requests` —

    cost[p] = (base_ms[p] + ms_per_token[p] * p50) * (1 + out[p]/comfort[p])
              + 429_pressure[p]            (+ UNAVAIL if p is down)

evaluated per submit with the client-observable signals only: the
adapter's own per-endpoint outstanding counts and the Retry-After
bounces it has seen.  The 429-pressure term is the live analogue of the
engine's bucket-dryness fraction — a client cannot see the provider's
buckets, only its bounces, so an endpoint that recently 429'd carries
its Retry-After as a routing penalty until that backoff expires.

Failure semantics deliberately differ from the engine (documented
asymmetry): the engine models abrupt endpoint death — in-flight work is
killed and requeued by `_complete_and_timeout`.  The live adapter
drains gracefully: a down endpoint refuses new submits (UNAVAIL cost;
if the whole fleet is down the submit bounces 429-style with
`retry_after_ms`) but its already-accepted work completes via `poll`.
Both behaviors are real — cloud endpoints do both — and the harsher
one lives in the engine, where the failover-recovery bar is measured.

With P == 1 the adapter is a transparent pass-through: the routing
argmin has one candidate and `inflight_hint` is forwarded to the child
untouched, so a single-endpoint fleet replays the exact session-vs-
engine parity traces (tests/test_serving_client.py's contract).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Sequence

import numpy as np

from repro.client.provider import (
    AsyncProvider,
    Completion,
    SubmitResult,
    sanitize_retry_after_ms,
)
from repro.core.routing import UNAVAIL_MS
from repro.sim.provider import FleetPhysics, ProviderPhysics

if TYPE_CHECKING:  # pragma: no cover
    from repro.client.request import Request


class FleetProvider:
    """Route every submit to the cheapest of P child endpoints.

    `providers` are the child transports (any `AsyncProvider`);
    `fphys` carries the (P,)-leaf speed/comfort estimates the routing
    cost reads (the client's *model* of the endpoints, not necessarily
    their truth).  `avail` is an optional (T, P) availability schedule
    sampled at `dt_ms` ticks — the test/replay hook for failover; live
    deployments would instead mark endpoints down from health checks.
    """

    def __init__(
        self,
        providers: Sequence[AsyncProvider],
        fphys: FleetPhysics,
        *,
        dt_ms: float = 25.0,
        avail: Optional[np.ndarray] = None,   # (T, P) rows, like engine xs
        retry_after_ms: float = 1500.0,
    ):
        if len(providers) == 0:
            raise ValueError("FleetProvider needs at least one endpoint")
        p = len(providers)
        if np.asarray(fphys.base_ms).shape != (p,):
            raise ValueError(
                f"fphys is {np.asarray(fphys.base_ms).shape[0]}-endpoint "
                f"but {p} providers were given")
        self.providers = list(providers)
        self.p = p
        self._base = np.asarray(fphys.base_ms, np.float32)
        self._ms_per_token = np.asarray(fphys.ms_per_token, np.float32)
        self._comfort = np.asarray(fphys.comfort_concurrency, np.float32)
        self.dt_ms = float(dt_ms)
        self._avail_rows = None if avail is None else np.asarray(
            avail, np.float32)
        self.retry_after_ms = float(retry_after_ms)
        # fleet ticket -> (endpoint, child ticket); fleet tickets are
        # monotone so completions report in a stable, mergeable order
        self._tickets: dict[int, tuple[int, int]] = {}
        self._by_child: list[dict[int, int]] = [dict() for _ in range(p)]
        self._next_ticket = 0
        # client-observed 429 pressure: endpoint p is penalized by its
        # last Retry-After until that backoff expires
        self._dry_until = np.zeros((p,), np.float64)
        self._dry_penalty = np.zeros((p,), np.float32)
        self.n_routed = np.zeros((p,), np.int64)
        self.n_refused = 0

    @classmethod
    def from_fleet_scenario(cls, scenario, n_requests: int, n_ticks: int,
                            dt_ms: float, k: int,
                            phys: ProviderPhysics | None = None
                            ) -> "FleetProvider":
        """Build the live fleet for a registry fleet scenario: one
        `MockProvider` per endpoint carrying that endpoint's physics
        skew, brownout rows, and bucket schedule — the same arrays
        `scenarios.build_fleet` hands the engine — plus the (T, P)
        availability schedule on the adapter."""
        from repro.client.provider import MockProvider
        from repro.sim.provider import default_physics
        from repro.sim.scenarios import build_fleet

        phys = phys if phys is not None else default_physics()
        fleet = build_fleet(scenario, phys, n_ticks, dt_ms, n_requests, k)
        if fleet is None:
            raise ValueError(
                f"scenario {scenario.name!r} carries no fleet spec")
        fphys, dyn = fleet.phys, fleet.dyn
        children = []
        for ep in range(np.asarray(fphys.base_ms).shape[0]):
            children.append(MockProvider(
                ProviderPhysics(*(np.asarray(a)[ep] for a in fphys)),
                dt_ms=dt_ms,
                comfort_scale=(None if dyn.comfort_scale is None
                               else np.asarray(dyn.comfort_scale)[:, ep]),
                tb_refill=(None if dyn.tb_refill is None
                           else np.asarray(dyn.tb_refill)[:, ep]),
                tb_capacity=(None if dyn.tb_capacity is None
                             else np.asarray(dyn.tb_capacity)[ep]),
                retry_after_ms=float(np.asarray(dyn.retry_after_ms)),
                # each endpoint misbehaves independently: same schedule,
                # decorrelated draw stream
                faults=scenario.faults,
                fault_salt=ep,
            ))
        return cls(
            children, fphys, dt_ms=dt_ms,
            avail=None if dyn.avail is None else np.asarray(dyn.avail),
            retry_after_ms=float(np.asarray(dyn.retry_after_ms)),
        )

    # --- routing ------------------------------------------------------
    def _avail_row(self, now_ms: float) -> Optional[np.ndarray]:
        if self._avail_rows is None:
            return None
        t = int(np.floor(now_ms / self.dt_ms + 1e-6)) - 1
        t = min(max(t, 0), self._avail_rows.shape[0] - 1)
        return self._avail_rows[t]

    def route(self, p50: float, now_ms: float) -> tuple[int, float]:
        """(endpoint, cost_seconds) for a request of predicted size
        `p50` — the same formula the engine's routing layer scores,
        with the adapter's observed signals.  Ties go to the lowest
        endpoint index (np.argmin), matching `jnp.argmin`."""
        out = np.asarray(
            [float(c.inflight()) for c in self.providers], np.float32)
        load = out / np.maximum(self._comfort, np.float32(1.0))
        unloaded = self._base + self._ms_per_token * np.float32(p50)
        cost = unloaded * (np.float32(1.0) + load)
        dry = now_ms < self._dry_until
        cost = cost + np.where(dry, self._dry_penalty, np.float32(0.0))
        row = self._avail_row(now_ms)
        if row is not None:
            cost = np.where(row < 0.5, np.float32(UNAVAIL_MS), cost)
        ep = int(np.argmin(cost))
        return ep, float(cost[ep]) * 1e-3

    # --- AsyncProvider ------------------------------------------------
    def submit(self, req: "Request", now_ms: float,
               inflight_hint: int | None = None) -> SubmitResult:
        ep, cost_s = self.route(req.p50, now_ms)
        if cost_s * 1e3 >= UNAVAIL_MS:
            # whole fleet down: bounce like a 429 so the session's
            # normal retry machinery handles the outage
            self.n_refused += 1
            return SubmitResult(False, self.retry_after_ms)
        # P == 1: forward the session's optimistic concurrency view so a
        # single-endpoint fleet prices service exactly like the bare
        # child (the session-vs-engine parity contract).  P > 1: the
        # child's own outstanding count is the endpoint's true load.
        hint = inflight_hint if self.p == 1 else None
        res = self.providers[ep].submit(req, now_ms, inflight_hint=hint)
        if not res.accepted:
            # observed 429: penalize this endpoint for its Retry-After.
            # Sanitized first — a hostile hint (negative/NaN, see
            # FaultSchedule.retry_lie_mult) would otherwise poison the
            # routing argmin (NaN cost) or *reward* the dry endpoint
            # (negative penalty); the raw hint still propagates to the
            # session, whose retry hook clamps at its own boundary
            hint_ms = sanitize_retry_after_ms(res.retry_after_ms)
            self._dry_until[ep] = now_ms + hint_ms
            self._dry_penalty[ep] = np.float32(hint_ms)
            return res
        ticket = self._next_ticket
        self._next_ticket += 1
        self._tickets[ticket] = (ep, res.ticket)
        self._by_child[ep][res.ticket] = ticket
        self.n_routed[ep] += 1
        return SubmitResult(True, 0.0, ticket=ticket)

    def poll(self, now_ms: float) -> list[Completion]:
        out = []
        for ep, child in enumerate(self.providers):
            for c in child.poll(now_ms):
                ticket = self._by_child[ep].pop(c.ticket)
                del self._tickets[ticket]
                out.append(Completion(ticket, c.finish_ms, c.output))
        # fleet-ticket order: deterministic merge independent of which
        # child reported first
        out.sort(key=lambda c: c.ticket)
        return out

    def inflight(self) -> int:
        return sum(c.inflight() for c in self.providers)

    def inflight_by_endpoint(self) -> np.ndarray:
        """(P,) outstanding counts — the routing layer's load signal,
        exposed for tests and dashboards."""
        return np.asarray([c.inflight() for c in self.providers], np.int64)

    def next_event_ms(self, now_ms: float) -> Optional[float]:
        cands = []
        for c in self.providers:
            e = c.next_event_ms(now_ms)
            if e is not None:
                cands.append(float(e))
        return min(cands) if cands else None
