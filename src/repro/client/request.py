"""Client-facing request record for the streaming session API.

One `Request` is one unit of work a user hands to `ClientSession.submit`.
It carries exactly what the paper's client-side stack is allowed to see
at the black-box boundary: the payload, the coarse priors (p50/p90), the
bucket/class tags the policy routes on, and the lifecycle fields the
session fills in as the request moves through admit/defer/429/complete.

Historically this type lived in `repro.serving.blackbox` with a
hardcoded `p90 = p50 * 1.8` applied inside the client — wrong whenever
the caller's information level isn't the coarse predictor (the neutral
no-info prior is 700/300 ≈ 2.33, not 1.8), and silently divergent from
the simulator's information-ladder semantics.  `p90` is now a real
field; when the caller doesn't have a tail prior, `default_p90` derives
one from the workload generator's *actual* per-bucket token
distribution (log-uniform within the bucket, so p90/p50 =
(hi/lo)^0.4 — see `repro.sim.workload.P90_OVER_P50`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.sim.workload import P90_OVER_P50_NP


def default_p90(p50: float, bucket: int) -> float:
    """Tail prior implied by the bucket's realized token distribution.

    The workload generator draws tokens log-uniformly within each
    bucket's [lo, hi] range, for which quantile ratios are exact:
    p90/p50 = (hi/lo)^0.4.  Using the generator's own ratio keeps the
    live client's information-ladder semantics aligned with the
    simulator instead of the old hardcoded 1.8.
    """
    return float(p50) * float(P90_OVER_P50_NP[int(bucket)])


@dataclasses.dataclass
class Request:
    """One client request.  Caller-provided fields first; the session
    owns the lifecycle fields below the fold."""

    rid: int                    # caller-scoped id (session reassigns its own)
    prompt: Optional[np.ndarray]  # (S_p,) int32 payload; None for mock runs
    max_new: float              # realized/requested output tokens (true cost)
    p50: float                  # coarse prior available at submission
    bucket: int                 # token bucket in [0, 4)
    p90: Optional[float] = None  # tail prior; None = default_p90(p50, bucket)
    cls: Optional[int] = None   # service class; None = paper 2-lane bucket
                                # split (K-class policies expect the caller
                                # to tag tenant/lane ids)
    arrival_s: float = 0.0      # arrival time (session clock, seconds);
                                # wall-clock sessions default it to submit time
    jitter: float = 1.0         # provider-side noise multiplier (the mock
                                # provider applies it; replays pass the
                                # workload generator's jitter stream)

    # --- lifecycle (session-owned) ------------------------------------
    submit_s: float = 0.0       # time handed to the provider
    finish_s: float = 0.0       # provider completion time
    status: str = "pending"     # pending|inflight|completed|rejected|abandoned
    n_defers: int = 0
    n_throttles: int = 0        # 429-style bounces this request saw
    n_resubmits: int = 0        # watchdog resubmissions (resilience layer)
    output: Optional[np.ndarray] = None

    def resolved_p90(self) -> float:
        return self.p90 if self.p90 is not None else default_p90(
            self.p50, self.bucket)

    def resolved_cls(self) -> int:
        """Service class with the paper's 2-lane default (interactive =
        short bucket, heavy = everything else) — the single definition
        the session's window staging and the providers' token-bucket
        class routing both use (mirrors `sim.workload.bucket_to_class`)."""
        return int(self.cls) if self.cls is not None else int(self.bucket != 0)
