"""Transport-agnostic streaming client API (DESIGN.md §7).

`ClientSession` runs the paper's three-layer scheduler as an open-ended
submit/poll/drain session over the `AsyncProvider` boundary;
`MockProvider` replays the simulator's provider dynamics against it,
`AsyncBlackBoxProvider` adapts the real JAX engine, and `FleetProvider`
multiplexes a session over P endpoints with endpoint-aware routing
(DESIGN.md §10).
"""
from repro.client.blackbox import AsyncBlackBoxProvider  # noqa: F401
from repro.client.fleet import FleetProvider  # noqa: F401
from repro.client.provider import (  # noqa: F401
    AsyncProvider,
    Completion,
    MockProvider,
    SubmitResult,
    sanitize_retry_after_ms,
)
from repro.client.request import Request, default_p90  # noqa: F401
from repro.client.resilience import ResilienceConfig, Watchdog  # noqa: F401
from repro.client.session import (  # noqa: F401
    ClientSession,
    PollResult,
    SessionConfig,
    SessionStats,
    expo_retry,
    honor_retry_after,
)
