"""`ClientSession` — the transport-agnostic streaming client API.

The paper's stack is a *client-side* scheduler at a black-box API
boundary, so the client surface is the product: requests arrive over
time (`submit`), the session makes batched admit/defer/reject decisions
(`poll`), and work flows through an `AsyncProvider` that may 429 it.
Unlike the old `ScheduledClient.run(requests)` — a closed upfront list,
dense O(N) state per poll, one blocking request in flight — the session
is open-ended and windowed:

  * **State is a compacted (W,) slot pool**, the live-client mirror of
    the sim engine's `WindowCarry` (DESIGN.md §6): every live request
    (admitted to the window, not yet terminal) holds one slot, occupied
    slots form a request-id-sorted prefix, and each poll's cost is
    O(W + B) regardless of how many requests the session has ever seen.
    Submissions beyond the window queue FIFO and admit as slots free.
  * **Decisions come from the same `schedule_batch`** the simulator
    runs, on the same `(K, W)` view; retirement (completion/timeout
    classification, the tail-latency EMA) is literally the engine's
    `_complete_and_timeout` on the (W,) state.  The policy logic and
    the decision-feeding float chains are written once, which is what
    makes sim↔live parity a theorem rather than a hope: driven in
    virtual time over `MockProvider`, the session reproduces the
    windowed sim engine's decision sequence bit-for-bit
    (tests/test_serving_client.py pins this on the `balanced` scenario).
  * **The provider boundary is async**: submits are non-blocking, many
    requests ride in flight at once, and the session's concurrency
    accounting is the real INFLIGHT recount (== the provider's actual
    outstanding count), not a bracket around a blocking call.  A 429
    bounce parks the request until `now + retry_after` through the
    session's `retry_policy` hook — the place Retry-After-aware backoff
    strategies plug in (the `rate_crunch` regime is where they
    separate).
  * **Two clocks.**  `clock="virtual"` advances `dt_ms` per poll (or an
    explicit `now_ms`) — deterministic replays, tests, benchmarks.
    `clock="wall"` reads the monotonic clock scaled by `time_scale`,
    and `drain()` sleeps until the next actionable instant (next queued
    arrival, earliest defer/Retry-After expiry, the provider's next
    event hint) instead of spinning at a fixed cadence.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.client.provider import AsyncProvider
from repro.client.request import Request
from repro.core import overload as olc
from repro.core.policy import ALLOC_ADRR, PolicyConfig, n_classes
from repro.core.scheduler import IDLE, schedule_batch
from repro.core.types import (
    ABANDONED,
    COMPLETED,
    INFLIGHT,
    PENDING,
    REJECTED,
    RequestBatch,
    SimState,
    empty_window_batch,
    empty_window_request_state,
    init_sim_state,
)
from repro.sim.engine import _complete_and_timeout
from repro.sim.provider import ProviderPhysics, default_physics
from repro.sim.workload import DEADLINE_BUDGET_MS

_DEADLINE_NP = np.asarray(DEADLINE_BUDGET_MS)


# ---------------------------------------------------------------------------
# Configuration and result records
# ---------------------------------------------------------------------------


class SessionConfig(NamedTuple):
    window: int = 256          # slot-pool capacity W (per-poll cost is O(W))
    max_grants: int = 4        # batch dispatch width B per poll
    dt_ms: float = 25.0        # virtual tick / decision-epoch granularity
    backend: str = "jnp"       # ordering backend ("jnp" | "pallas")
    time_scale: float = 1.0    # wall mode: session ms per wall ms
    max_idle_sleep_ms: float = 250.0  # wall mode: cap on one idle sleep
                                      # (session clock ms)


class PollResult(NamedTuple):
    """One decision epoch's outcome (all rids are session-scoped)."""

    now_ms: float
    actions: np.ndarray        # (B,) int32 decision per grant row
    req_rids: np.ndarray       # (B,) session rid per grant row (-1 = idle)
    severity: np.float32       # overload severity this epoch's ladder used
    completed: list[int]
    abandoned: list[int]
    rejected: list[int]
    admitted: list[int]
    deferred: list[int]
    throttled: list[int]       # 429-bounced this epoch
    n_live: int                # occupied window slots after admission
    progressed: bool           # anything moved (else the caller may sleep)


@dataclasses.dataclass
class SessionStats:
    n_polls: int = 0
    n_admitted: int = 0
    n_completed: int = 0
    n_rejected: int = 0
    n_abandoned: int = 0
    n_deferred: int = 0
    n_throttled: int = 0
    n_idle_sleeps: int = 0
    peak_inflight: int = 0


# --- Retry-After policies (the 429 backoff hook) ---------------------------

RetryPolicy = Callable[[float, int], float]


def honor_retry_after(retry_after_ms: float, n_throttles: int) -> float:
    """Default: wait exactly what the provider asked."""
    return retry_after_ms


def expo_retry(mult: float = 1.0, growth: float = 2.0,
               cap_ms: float = 60_000.0) -> RetryPolicy:
    """Retry-After-seeded exponential backoff: the provider's hint is the
    base, repeated bounces of the same request grow it geometrically."""
    def policy(retry_after_ms: float, n_throttles: int) -> float:
        return min(retry_after_ms * mult * growth ** max(n_throttles - 1, 0),
                   cap_ms)
    return policy


# ---------------------------------------------------------------------------
# Jitted steps (module-level so compilations are shared across sessions)
# ---------------------------------------------------------------------------


@jax.jit
def _ingest_and_retire(policy: PolicyConfig, phys: ProviderPhysics,
                       batch: RequestBatch, state: SimState,
                       comp_slot, comp_fin, now):
    """Scatter provider completions into finish_ms, then run the
    engine's retirement pass (completion vs timeout classification,
    stale-abandonment, tail EMA, inflight recount) on the (W,) state.
    Returns (state, alive) — alive marks slots still PENDING/INFLIGHT."""
    finish = state.req.finish_ms.at[comp_slot].set(comp_fin, mode="drop")
    state = state._replace(
        now_ms=now, req=state.req._replace(finish_ms=finish))
    state = _complete_and_timeout(policy, phys, batch, state)
    alive = (state.req.status == PENDING) | (state.req.status == INFLIGHT)
    return state, alive


@jax.jit
def _compact_and_admit(batch: RequestBatch, req, alive, staged: RequestBatch,
                       n_stage):
    """Stable-compact live slots to the prefix (preserving request-id
    order — the ordering layer's tie-break invariant) and append up to
    `n_stage` newly admitted requests behind them.  Staged request
    state is fresh (PENDING, finish=inf); vacated slots are neutralized
    exactly like the engine's empty-slot view (invalid, terminal,
    never landing)."""
    w = alive.shape[0]
    iota = jnp.arange(w, dtype=jnp.int32)
    idx, = jnp.nonzero(alive, size=w, fill_value=0)
    n_live = alive.sum().astype(jnp.int32)
    live_here = iota < n_live
    stage_here = (iota >= n_live) & (iota < n_live + n_stage)
    spos = jnp.clip(iota - n_live, 0, w - 1)

    def mix(old, st, fill=None):
        v = jnp.where(stage_here, st[spos], old[idx])
        if fill is not None:
            v = jnp.where(live_here | stage_here, v, fill)
        return v

    new_batch = RequestBatch(
        arrival_ms=mix(batch.arrival_ms, staged.arrival_ms),
        bucket=mix(batch.bucket, staged.bucket),
        cls=mix(batch.cls, staged.cls),
        true_tokens=mix(batch.true_tokens, staged.true_tokens),
        p50=mix(batch.p50, staged.p50),
        p90=mix(batch.p90, staged.p90),
        deadline_budget_ms=mix(batch.deadline_budget_ms,
                               staged.deadline_budget_ms),
        valid=mix(batch.valid, staged.valid, fill=False),
    )
    fresh_i = jnp.zeros((w,), jnp.int32)
    fresh_f = jnp.zeros((w,), jnp.float32)
    inf_f = jnp.full((w,), jnp.inf, jnp.float32)
    new_req = req._replace(
        status=mix(req.status, fresh_i, fill=jnp.int32(REJECTED)),
        submit_ms=mix(req.submit_ms, inf_f),
        finish_ms=mix(req.finish_ms, inf_f, fill=jnp.inf),
        defer_until=mix(req.defer_until, fresh_f),
        n_defers=mix(req.n_defers, fresh_i),
        n_throttles=mix(req.n_throttles, fresh_i),
    )
    return new_batch, new_req, n_live + n_stage


_dispatch = jax.jit(schedule_batch, static_argnames=("max_grants", "backend"))


@jax.jit
def _apply_decisions(policy: PolicyConfig, batch: RequestBatch,
                     state: SimState, d, accepted, delay_ms):
    """Post-dispatch state transition on the (W,) pool — the live-path
    sibling of the engine's `_apply_batch`, with two deliberate
    differences: admits get finish_ms = inf (the transport decides when
    work lands; completion arrives via the provider poll), and the
    throttle verdict comes from the provider's actual submit responses
    (`accepted`) with the session's retry policy supplying `delay_ms`,
    instead of an engine-owned token bucket.  Deficit conservation on a
    bounce matches the engine: the allocation charge is refunded
    (ADRR-gated) because the 429 blocked the release."""
    w = batch.n
    req = state.req
    admit = (d.actions == olc.ADMIT) & accepted
    throttled = (d.actions == olc.ADMIT) & ~accepted
    defer = d.actions == olc.DEFER
    reject = d.actions == olc.REJECT
    idx = d.req_idx
    drop = jnp.int32(w)
    adm_i = jnp.where(admit, idx, drop)
    def_i = jnp.where(defer, idx, drop)
    rej_i = jnp.where(reject, idx, drop)
    thr_i = jnp.where(throttled, idx, drop)

    backoff = olc.defer_backoff(policy, d.severity, req.n_defers[idx])

    status = req.status.at[adm_i].set(INFLIGHT, mode="drop")
    status = status.at[rej_i].set(REJECTED, mode="drop")
    submit = req.submit_ms.at[adm_i].set(state.now_ms, mode="drop")
    defer_until = req.defer_until.at[def_i].set(
        state.now_ms + backoff, mode="drop")
    defer_until = defer_until.at[thr_i].set(
        state.now_ms + delay_ms, mode="drop")
    n_defers = req.n_defers.at[def_i].add(1, mode="drop")
    n_throttles = req.n_throttles.at[thr_i].add(1, mode="drop")

    deficit = d.deficit
    k = deficit.shape[0]
    gcls = jnp.clip(batch.cls[idx], 0, k - 1)
    refund = (
        jax.nn.one_hot(gcls, k)
        * batch.p50[idx][:, None]
        * throttled[:, None]
    ).sum(axis=0) * (policy.alloc_mode == ALLOC_ADRR)
    # gate on an actual bounce so the no-throttle path returns d.deficit
    # bit-unchanged (x + 0.0 is not an f32 identity at -0.0)
    deficit = jnp.where(
        throttled.any() & jnp.isfinite(deficit + refund).all(),
        deficit + refund, deficit)

    inflight = state.provider.inflight + admit.sum().astype(jnp.int32)
    inflight_tokens = state.provider.inflight_tokens + jnp.where(
        admit, batch.p50[idx], 0.0).sum()
    return state._replace(
        req=req._replace(
            status=status,
            submit_ms=submit,
            defer_until=defer_until,
            n_defers=n_defers,
            n_throttles=n_throttles,
        ),
        sched=state.sched._replace(deficit=deficit, rr_turn=d.rr_turn),
        provider=state.provider._replace(
            inflight=inflight,
            inflight_tokens=inflight_tokens,
            n_throttled=state.provider.n_throttled
            + throttled.sum().astype(jnp.int32),
        ),
    )


@jax.jit
def _next_defer_ms(state: SimState):
    """Earliest defer/Retry-After expiry among pending slots (inf if
    none) — one of the idle-sleep wakeup candidates."""
    pend = state.req.status == PENDING
    parked = pend & (state.req.defer_until > state.now_ms)
    return jnp.where(parked, state.req.defer_until, jnp.inf).min()


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------


_TERMINAL = {"completed", "rejected", "abandoned"}


class ClientSession:
    """Streaming three-layer client over an `AsyncProvider`.

    Lifecycle: `submit()` any number of requests over time (admission
    into the window is FIFO by submission order; keep arrivals
    nondecreasing when replaying a trace), `poll()` one decision epoch,
    `drain()` until everything submitted is terminal.  See the module
    docstring for the architecture.

    `phys` is the *client's* latency model — the unloaded-latency
    expectation the tail EMA normalizes observed completions against
    (client-observable signals only, per the paper; the benchmarks
    calibrate it against the real engine).
    """

    def __init__(
        self,
        provider: AsyncProvider,
        policy: PolicyConfig,
        cfg: SessionConfig = SessionConfig(),
        *,
        clock: str = "wall",
        phys: ProviderPhysics | None = None,
        retry_policy: RetryPolicy | None = None,
    ):
        if clock not in ("wall", "virtual"):
            raise ValueError(f"clock must be 'wall' or 'virtual', got {clock!r}")
        self.provider = provider
        self.policy = policy
        self.cfg = cfg
        self.clock = clock
        self.phys = phys if phys is not None else default_physics()
        self.retry_policy = retry_policy or honor_retry_after
        self.stats = SessionStats()

        w = cfg.window
        self._k = n_classes(policy)
        self._win_batch = empty_window_batch(w)
        self._state = init_sim_state(w, self._k)._replace(
            req=empty_window_request_state(w))
        # host mirrors (kept in lockstep with the device pool)
        self._reqs: list[Request] = []
        self._arrival_ms: list[float] = []
        self._queue: deque[int] = deque()
        self._slot_rid = np.full(w, -1, np.int64)
        self._slot_live = np.zeros(w, bool)
        self._n_live = 0
        self._tickets: dict[int, int] = {}
        self._unfinished = 0
        self._t = 0
        self._t0: Optional[float] = None
        self._warmup()

    def _warmup(self) -> None:
        """Compile the session's jitted steps against the (W, B, K)
        shapes before the clock starts: XLA compilation takes seconds,
        and a wall-clock session that compiles inside its first poll
        would burn that as session time — at time_scale >> 1 enough to
        blow every deadline before the first decision lands."""
        w = self.cfg.window
        comp_slot = np.full(w, w, np.int32)
        comp_fin = np.full(w, np.inf, np.float32)
        state, alive = _ingest_and_retire(
            self.policy, self.phys, self._win_batch, self._state,
            comp_slot, comp_fin, jnp.float32(0.0))
        _, staged = self._stage_admissions(-1.0, 0)
        batch, req, _ = _compact_and_admit(
            self._win_batch, state.req, alive, staged, jnp.int32(0))
        d = _dispatch(self.policy, batch, state._replace(req=req),
                      max_grants=self.cfg.max_grants,
                      backend=self.cfg.backend)
        bm = int(d.actions.shape[0])
        out = _apply_decisions(
            self.policy, batch, state._replace(req=req), d,
            np.ones(bm, bool), np.zeros(bm, np.float32))
        _next_defer_ms(out)
        jax.block_until_ready(out.req.status)

    # --- clock --------------------------------------------------------
    def _wall_now_ms(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return (time.monotonic() - self._t0) * 1e3 * self.cfg.time_scale

    def now_ms(self) -> float:
        if self.clock == "virtual":
            return float(np.float32(self._t) * np.float32(self.cfg.dt_ms))
        return self._wall_now_ms()

    # --- lifecycle ----------------------------------------------------
    def submit(self, req: Request) -> int:
        """Register a request; returns its session rid.  `arrival_s` is
        honored as given (0.0 = arrived at session start); wall-clock
        callers typically leave it 0 or stamp it with `now_ms()/1e3`."""
        rid = len(self._reqs)
        self._reqs.append(req)
        self._arrival_ms.append(float(np.float32(req.arrival_s * 1000.0)))
        self._queue.append(rid)
        self._unfinished += 1
        return rid

    @property
    def unfinished(self) -> int:
        return self._unfinished

    def requests(self) -> list[Request]:
        return list(self._reqs)

    def _stage_admissions(self, now_ms: float, free: int):
        """Pop arrived requests off the FIFO queue into a (W,)-padded
        staging batch (the window-admission rule the engine's
        `_compact_and_admit` applies to its arrival stream)."""
        w = self.cfg.window
        rids = []
        while self._queue and len(rids) < free \
                and self._arrival_ms[self._queue[0]] <= now_ms:
            rids.append(self._queue.popleft())
        arr = np.zeros(w, np.float32)
        bucket = np.zeros(w, np.int32)
        cls = np.zeros(w, np.int32)
        tok = np.ones(w, np.float32)
        p50 = np.ones(w, np.float32)
        p90 = np.ones(w, np.float32)
        ddl = np.full(w, 1e9, np.float32)
        valid = np.zeros(w, bool)
        for i, rid in enumerate(rids):
            r = self._reqs[rid]
            arr[i] = self._arrival_ms[rid]
            bucket[i] = int(r.bucket)
            cls[i] = r.resolved_cls()
            tok[i] = float(r.max_new)
            p50[i] = float(r.p50)
            p90[i] = float(r.resolved_p90())
            ddl[i] = _DEADLINE_NP[int(r.bucket)]
            valid[i] = True
        staged = RequestBatch(
            arrival_ms=arr, bucket=bucket, cls=cls, true_tokens=tok,
            p50=p50, p90=p90, deadline_budget_ms=ddl, valid=valid)
        return rids, staged

    def poll(self, now_ms: Optional[float] = None) -> PollResult:
        """One decision epoch: ingest completions, retire, compact +
        admit, dispatch `schedule_batch` over the (K, W) view, submit
        grants to the provider, apply.  O(W + B) regardless of session
        history length."""
        self._t += 1
        if now_ms is None:
            now_ms = self.now_ms() if self.clock == "wall" else float(
                np.float32(np.float32(self._t) * np.float32(self.cfg.dt_ms)))
        w, b = self.cfg.window, self.cfg.max_grants
        self.stats.n_polls += 1

        # 1. provider completions -> slot scatter
        comps = self.provider.poll(now_ms)
        comp_slot = np.full(w, w, np.int32)
        comp_fin = np.full(w, np.inf, np.float32)
        comp_by_rid: dict[int, object] = {}
        if comps:
            for c in comps:
                comp_by_rid[self._tickets.pop(c.ticket)] = c
            rids = np.fromiter(sorted(comp_by_rid), np.int64)
            slots = np.searchsorted(self._slot_rid[:self._n_live], rids)
            comp_slot[:len(rids)] = slots
            comp_fin[:len(rids)] = [
                np.float32(comp_by_rid[r].finish_ms) for r in rids]

        # 2. retire (engine's completion/timeout/EMA pass)
        state, alive_dev = _ingest_and_retire(
            self.policy, self.phys, self._win_batch, self._state,
            comp_slot, comp_fin, jnp.float32(now_ms))
        status_np = np.asarray(state.req.status)
        alive = np.asarray(alive_dev)

        completed, abandoned = [], []
        newly_term = self._slot_live & ~alive
        for slot in np.nonzero(newly_term)[0]:
            rid = int(self._slot_rid[slot])
            r = self._reqs[rid]
            if status_np[slot] == COMPLETED:
                c = comp_by_rid.get(rid)
                r.status = "completed"
                r.finish_s = float(np.asarray(state.req.finish_ms[slot])) / 1e3 \
                    if c is None else float(c.finish_ms) / 1e3
                if c is not None:
                    r.output = c.output
                completed.append(rid)
                self.stats.n_completed += 1
            else:
                assert status_np[slot] == ABANDONED
                # stale pending, or landed past the timeout multiple
                r.status = "abandoned"
                abandoned.append(rid)
                self.stats.n_abandoned += 1
            self._unfinished -= 1

        # 3. stage arrivals + 4. compact/admit
        n_alive = int(alive.sum())
        staged_rids, staged = self._stage_admissions(now_ms, w - n_alive)
        self._win_batch, new_req, _ = _compact_and_admit(
            self._win_batch, state.req, alive_dev, staged,
            jnp.int32(len(staged_rids)))
        state = state._replace(req=new_req)
        self._slot_rid = np.concatenate([
            self._slot_rid[alive],
            np.asarray(staged_rids, np.int64),
            np.full(w - n_alive - len(staged_rids), -1, np.int64)])
        self._n_live = n_alive + len(staged_rids)
        for rid in staged_rids:
            self._reqs[rid].status = "pending"

        # 5. dispatch — one batched decision over the (K, W) view
        d = _dispatch(self.policy, self._win_batch, state,
                      max_grants=b, backend=self.cfg.backend)
        actions = np.asarray(d.actions)
        idxs = np.asarray(d.req_idx)
        infl_at = np.asarray(d.inflight_at)
        severity = np.float32(np.asarray(d.severity))

        # 6. submit grants (decision order); collect 429 verdicts
        bm = actions.shape[0]
        accepted = np.ones(bm, bool)
        delay_ms = np.zeros(bm, np.float32)
        req_rids = np.full(bm, -1, np.int64)
        admitted, deferred, rejected, throttled = [], [], [], []
        for g in range(bm):
            a = actions[g]
            if a == IDLE:
                continue
            rid = int(self._slot_rid[idxs[g]])
            req_rids[g] = rid
            r = self._reqs[rid]
            if a == olc.ADMIT:
                res = self.provider.submit(
                    r, now_ms, inflight_hint=int(infl_at[g]))
                if res.accepted:
                    self._tickets[res.ticket] = rid
                    r.status = "inflight"
                    r.submit_s = now_ms / 1e3
                    admitted.append(rid)
                    self.stats.n_admitted += 1
                else:
                    accepted[g] = False
                    r.n_throttles += 1
                    delay_ms[g] = np.float32(self.retry_policy(
                        res.retry_after_ms, r.n_throttles))
                    throttled.append(rid)
                    self.stats.n_throttled += 1
            elif a == olc.DEFER:
                r.n_defers += 1
                deferred.append(rid)
                self.stats.n_deferred += 1
            else:  # REJECT
                r.status = "rejected"
                rejected.append(rid)
                self.stats.n_rejected += 1
                self._unfinished -= 1

        # 7. apply the transition on the (W,) pool
        self._state = _apply_decisions(
            self.policy, self._win_batch, state, d, accepted, delay_ms)
        self._slot_live = np.asarray(
            (self._state.req.status == PENDING)
            | (self._state.req.status == INFLIGHT))
        self.stats.peak_inflight = max(
            self.stats.peak_inflight, self.provider.inflight())

        progressed = bool(
            completed or abandoned or rejected or admitted or deferred
            or throttled or staged_rids)
        return PollResult(
            now_ms=now_ms, actions=actions, req_rids=req_rids,
            severity=severity, completed=completed, abandoned=abandoned,
            rejected=rejected, admitted=admitted, deferred=deferred,
            throttled=throttled, n_live=self._n_live, progressed=progressed)

    # --- drain --------------------------------------------------------
    def _idle_sleep(self, now_ms: float) -> None:
        """Sleep until the next actionable instant instead of spinning:
        the next queued arrival, the earliest defer/Retry-After expiry,
        or the provider's next-event hint — capped so an unhintable
        transport still gets re-polled."""
        cands = []
        if self._queue:
            cands.append(self._arrival_ms[self._queue[0]])
        nd = float(np.asarray(_next_defer_ms(self._state)))
        if np.isfinite(nd):
            cands.append(nd)
        pe = self.provider.next_event_ms(now_ms)
        if pe is not None:
            cands.append(pe)
        # a candidate already due (e.g. a queued arrival stuck behind a
        # full window) is not a wakeup signal — keeping it would clamp
        # the sleep to zero and busy-spin until the blocker clears
        cands = [c for c in cands if c > now_ms]
        target = min(cands) if cands else now_ms + self.cfg.max_idle_sleep_ms
        target = min(target, now_ms + self.cfg.max_idle_sleep_ms)
        sleep_s = (target - now_ms) / 1e3 / self.cfg.time_scale
        if sleep_s > 0:
            self.stats.n_idle_sleeps += 1
            time.sleep(sleep_s)

    def drain(self, max_polls: Optional[int] = None) -> list[Request]:
        """Poll until every submitted request is terminal.  Wall-clock
        sessions sleep through idle epochs; virtual sessions advance one
        tick per poll.  Returns the session's requests."""
        n = 0
        while self._unfinished:
            r = self.poll()
            n += 1
            if self._unfinished and max_polls is not None and n >= max_polls:
                raise RuntimeError(
                    f"drain: {self._unfinished} request(s) still live "
                    f"after {n} polls")
            if self.clock == "wall" and not r.progressed:
                self._idle_sleep(r.now_ms)
        return list(self._reqs)
