"""`ClientSession` — the transport-agnostic streaming client API.

The paper's stack is a *client-side* scheduler at a black-box API
boundary, so the client surface is the product: requests arrive over
time (`submit`), the session makes batched admit/defer/reject decisions
(`poll`), and work flows through an `AsyncProvider` that may 429 it.
Unlike the old `ScheduledClient.run(requests)` — a closed upfront list,
dense O(N) state per poll, one blocking request in flight — the session
is open-ended and windowed:

  * **State is a compacted (W,) slot pool**, the live-client mirror of
    the sim engine's `WindowCarry` (DESIGN.md §6): every live request
    (admitted to the window, not yet terminal) holds one slot, occupied
    slots form a request-id-sorted prefix, and each poll's cost is
    O(W + B) regardless of how many requests the session has ever seen.
    Submissions beyond the window queue FIFO and admit as slots free.
  * **One device step per poll** (DESIGN.md §8): the whole decision
    epoch — apply the previous epoch's verdicts, ingest completions,
    retire, compact + admit, dispatch — is a single donated-buffer
    `jax.jit` (`_fused_tick`).  The slot pool never leaves the device:
    the host pushes the newly-staged arrivals plus a narrow completion
    scatter, and pulls one packed `(4B+2,)` decision summary.  Terminal
    classification (completed vs abandoned) runs on host-side float32
    mirrors that replay the device's own comparison chains bit-exactly,
    so the per-poll `(W,)` status pulls of the unfused design are gone.
  * **Decisions come from the same `schedule_batch`** the simulator
    runs, on the same `(K, W)` view; retirement (completion/timeout
    classification, the tail-latency EMA) is literally the engine's
    `_complete_and_timeout` on the (W,) state.  The policy logic and
    the decision-feeding float chains are written once, which is what
    makes sim↔live parity a theorem rather than a hope: driven in
    virtual time over `MockProvider`, the session reproduces the
    windowed sim engine's decision sequence bit-for-bit
    (tests/test_serving_client.py pins this on the `balanced` regime).
  * **The provider boundary is async**: submits are non-blocking, many
    requests ride in flight at once, and the session's concurrency
    accounting is the real INFLIGHT recount (== the provider's actual
    outstanding count), not a bracket around a blocking call.  A 429
    bounce parks the request until `now + retry_after` through the
    session's `retry_policy` hook — the place Retry-After-aware backoff
    strategies plug in (the `rate_crunch` regime is where they
    separate).  The boundary is one provider wide by contract:
    fleet-scale sessions hand the session a
    `repro.client.fleet.FleetProvider`, which multiplexes P child
    endpoints behind this same interface with endpoint-aware routing
    (DESIGN.md §10) — the session itself never learns P exists.
  * **Two clocks.**  `clock="virtual"` advances `dt_ms` per poll (or an
    explicit `now_ms`) — deterministic replays, tests, benchmarks.
    `clock="wall"` reads the monotonic clock scaled by `time_scale`,
    and `drain()` sleeps until the next actionable instant (next queued
    arrival, earliest defer/Retry-After expiry, the provider's next
    event hint) instead of spinning at a fixed cadence.

Decision timing under the fused step: `schedule_batch` runs at the end
of epoch t's device call, the host submits the grants and collects the
provider's 429 verdicts, and the state transition (`_apply_decisions`)
is the *first* stage of epoch t+1's call — the same floats in the same
order as applying at the end of t, since nothing between reads the
written fields.  Reading `session._state` flushes that pending
transition on demand, so introspection still sees post-apply state.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.client.provider import (
    AsyncProvider,
    Completion,
    expo_retry,  # noqa: F401  (re-exported; historic home of the hook)
    honor_retry_after,
    sanitize_retry_after_ms,
)
from repro.client.request import Request
from repro.client.resilience import ResilienceConfig, Watchdog
from repro.core import overload as olc
from repro.core.policy import ALLOC_ADRR, PolicyConfig, n_classes
from repro.core.scheduler import IDLE, charge_resubmit, schedule_batch
from repro.core.types import (
    INFLIGHT,
    PENDING,
    REJECTED,
    RequestBatch,
    SimState,
    empty_window_batch,
    empty_window_request_state,
    init_sim_state,
)
from repro.sim.engine import _complete_and_timeout
from repro.sim.provider import ProviderPhysics, default_physics
from repro.sim.workload import DEADLINE_BUDGET_MS

_DEADLINE_NP = np.asarray(DEADLINE_BUDGET_MS)
_DEADLINE_PY = [float(x) for x in _DEADLINE_NP]


# ---------------------------------------------------------------------------
# Configuration and result records
# ---------------------------------------------------------------------------


class SessionConfig(NamedTuple):
    window: int = 256          # slot-pool capacity W (per-poll cost is O(W))
    max_grants: int = 4        # batch dispatch width B per poll
    dt_ms: float = 25.0        # virtual tick / decision-epoch granularity
    backend: str = "jnp"       # ordering backend ("jnp" | "pallas")
    time_scale: float = 1.0    # wall mode: session ms per wall ms
    max_idle_sleep_ms: float = 250.0  # wall mode: cap on one idle sleep
                                      # (session clock ms)


class PollResult(NamedTuple):
    """One decision epoch's outcome (all rids are session-scoped)."""

    now_ms: float
    actions: np.ndarray        # (B,) int32 decision per grant row
    req_rids: np.ndarray       # (B,) session rid per grant row (-1 = idle)
    severity: np.float32       # overload severity this epoch's ladder used
    completed: list[int]
    abandoned: list[int]
    rejected: list[int]
    admitted: list[int]
    deferred: list[int]
    throttled: list[int]       # 429-bounced this epoch
    n_live: int                # occupied window slots after admission
    progressed: bool           # anything moved (else the caller may sleep)


@dataclasses.dataclass
class SessionStats:
    n_polls: int = 0
    n_admitted: int = 0
    n_completed: int = 0
    n_rejected: int = 0
    n_abandoned: int = 0
    n_deferred: int = 0
    n_throttled: int = 0
    n_idle_sleeps: int = 0
    peak_inflight: int = 0
    # resilience / dup-safety accounting (zero on honest transports)
    n_resubmitted: int = 0      # watchdog resubmissions accepted
    n_gave_up: int = 0          # budget exhausted -> synthetic abandon
    n_dup_discarded: int = 0    # dead-ticket / same-epoch dup arrivals
    n_late_discarded: int = 0   # completions for already-retired rids


RetryPolicy = Callable[[float, int], float]


# ---------------------------------------------------------------------------
# The fused device tick (module-level so compilations are shared)
# ---------------------------------------------------------------------------


# row layout of the packed (7, W) staging transfer: int fields ride
# exactly in f32 (buckets/classes are tiny) so the host pushes ONE
# array per poll instead of eight
_ST_ARRIVAL, _ST_BUCKET, _ST_CLS, _ST_TOKENS = 0, 1, 2, 3
_ST_P50, _ST_P90, _ST_DEADLINE = 4, 5, 6


def _compact_and_admit(batch: RequestBatch, req, alive, staged, n_stage):
    """Stable-compact live slots to the prefix (preserving request-id
    order — the ordering layer's tie-break invariant) and append up to
    `n_stage` newly admitted requests behind them (rows of the packed
    (7, W) staging transfer).  Staged request state is fresh (PENDING,
    finish=inf); vacated slots are neutralized exactly like the
    engine's empty-slot view (invalid, terminal, never landing)."""
    w = alive.shape[0]
    iota = jnp.arange(w, dtype=jnp.int32)
    idx, = jnp.nonzero(alive, size=w, fill_value=0)
    n_live = alive.sum().astype(jnp.int32)
    live_here = iota < n_live
    stage_here = (iota >= n_live) & (iota < n_live + n_stage)
    spos = jnp.clip(iota - n_live, 0, w - 1)

    def mix(old, st, fill=None):
        v = jnp.where(stage_here, st[spos], old[idx])
        if fill is not None:
            v = jnp.where(live_here | stage_here, v, fill)
        return v

    new_batch = RequestBatch(
        arrival_ms=mix(batch.arrival_ms, staged[_ST_ARRIVAL]),
        bucket=mix(batch.bucket, staged[_ST_BUCKET].astype(jnp.int32)),
        cls=mix(batch.cls, staged[_ST_CLS].astype(jnp.int32)),
        true_tokens=mix(batch.true_tokens, staged[_ST_TOKENS]),
        p50=mix(batch.p50, staged[_ST_P50]),
        p90=mix(batch.p90, staged[_ST_P90]),
        deadline_budget_ms=mix(batch.deadline_budget_ms,
                               staged[_ST_DEADLINE]),
        # every staged row is an admission, so validity needs no
        # transferred column
        valid=jnp.where(stage_here, True,
                        jnp.where(live_here, batch.valid[idx], False)),
    )
    fresh_i = jnp.zeros((w,), jnp.int32)
    fresh_f = jnp.zeros((w,), jnp.float32)
    inf_f = jnp.full((w,), jnp.inf, jnp.float32)
    new_req = req._replace(
        status=mix(req.status, fresh_i, fill=jnp.int32(REJECTED)),
        submit_ms=mix(req.submit_ms, inf_f),
        finish_ms=mix(req.finish_ms, inf_f, fill=jnp.inf),
        defer_until=mix(req.defer_until, fresh_f),
        n_defers=mix(req.n_defers, fresh_i),
        n_throttles=mix(req.n_throttles, fresh_i),
    )
    return new_batch, new_req, n_live + n_stage


def _apply_body(policy: PolicyConfig, batch: RequestBatch,
                state: SimState, d, accepted, delay_ms):
    """Post-dispatch state transition on the (W,) pool — the live-path
    sibling of the engine's `_apply_batch`, with two deliberate
    differences: admits get finish_ms = inf (the transport decides when
    work lands; completion arrives via the provider poll), and the
    throttle verdict comes from the provider's actual submit responses
    (`accepted`) with the session's retry policy supplying `delay_ms`,
    instead of an engine-owned token bucket.  Deficit conservation on a
    bounce matches the engine: the allocation charge is refunded
    (ADRR-gated) because the 429 blocked the release."""
    w = batch.n
    req = state.req
    admit = (d.actions == olc.ADMIT) & accepted
    throttled = (d.actions == olc.ADMIT) & ~accepted
    defer = d.actions == olc.DEFER
    reject = d.actions == olc.REJECT
    idx = d.req_idx
    drop = jnp.int32(w)
    adm_i = jnp.where(admit, idx, drop)
    def_i = jnp.where(defer, idx, drop)
    rej_i = jnp.where(reject, idx, drop)
    thr_i = jnp.where(throttled, idx, drop)

    backoff = olc.defer_backoff(policy, d.severity, req.n_defers[idx])

    status = req.status.at[adm_i].set(INFLIGHT, mode="drop")
    status = status.at[rej_i].set(REJECTED, mode="drop")
    submit = req.submit_ms.at[adm_i].set(state.now_ms, mode="drop")
    defer_until = req.defer_until.at[def_i].set(
        state.now_ms + backoff, mode="drop")
    defer_until = defer_until.at[thr_i].set(
        state.now_ms + delay_ms, mode="drop")
    n_defers = req.n_defers.at[def_i].add(1, mode="drop")
    n_throttles = req.n_throttles.at[thr_i].add(1, mode="drop")

    deficit = d.deficit
    k = deficit.shape[0]
    gcls = jnp.clip(batch.cls[idx], 0, k - 1)
    refund = (
        jax.nn.one_hot(gcls, k)
        * batch.p50[idx][:, None]
        * throttled[:, None]
    ).sum(axis=0) * (policy.alloc_mode == ALLOC_ADRR)
    # gate on an actual bounce so the no-throttle path returns d.deficit
    # bit-unchanged (x + 0.0 is not an f32 identity at -0.0)
    deficit = jnp.where(
        throttled.any() & jnp.isfinite(deficit + refund).all(),
        deficit + refund, deficit)

    inflight = state.provider.inflight + admit.sum().astype(jnp.int32)
    inflight_tokens = state.provider.inflight_tokens + jnp.where(
        admit, batch.p50[idx], 0.0).sum()
    return state._replace(
        req=req._replace(
            status=status,
            submit_ms=submit,
            defer_until=defer_until,
            n_defers=n_defers,
            n_throttles=n_throttles,
        ),
        sched=state.sched._replace(deficit=deficit, rr_turn=d.rr_turn),
        provider=state.provider._replace(
            inflight=inflight,
            inflight_tokens=inflight_tokens,
            n_throttled=state.provider.n_throttled
            + throttled.sum().astype(jnp.int32),
        ),
    )


# standalone jit of the transition, used only when `session._state` is
# introspected before the next poll has folded the pending apply in.
# RPL002 audit: donates position 2 (the RequestState bundle); the sole
# caller (`_state`) rebinds `self._dev_state` from the result in the
# same statement, so no stale binding survives the call.
_apply_decisions = jax.jit(_apply_body, donate_argnums=(2,))


def _fused_tick(policy: PolicyConfig, phys: ProviderPhysics,
                batch: RequestBatch, state: SimState, prev,
                comp, staged, n_stage, now, resub=None,
                *, max_grants: int, backend: str):
    """One decision epoch as a single donated-buffer device step:

      apply(prev) -> charge resubmits -> ingest completions -> retire
                  -> compact + admit -> dispatch -> packed summary

    `prev` is the previous epoch's `(BatchDecision, accept_delay)` —
    or None on the first epoch / after an explicit `_state` flush, a
    distinct pytree structure that traces the no-leading-apply variant;
    `accept_delay` is the (2B,) packed [accepted; delay_ms] verdict of
    the host's submit loop.  `batch` and `state` are donated: the (W,)
    slot pool lives on the device across polls and the host never
    rematerializes it.  Per poll the host pushes exactly three packed
    arrays — `comp` (2, W) [slot; finish], `staged` (7, W), and the
    verdicts — and pulls one summary vector
    `[actions, req_idx, inflight_at, backoff, severity, next_defer]`
    (int fields ride exactly in f32 throughout).

    `resub` is the (K,) per-class deficit charge for this epoch's
    watchdog resubmissions — or None on sessions without a resilience
    layer, where its absence is pytree structure: the None trace is the
    byte-identical pre-resilience program.  Charged before dispatch so
    recovery traffic depresses its class's share this very epoch.
    """
    if prev is not None:
        d0, ad0 = prev
        b0 = d0.actions.shape[0]
        state = _apply_body(policy, batch, state, d0,
                            ad0[:b0] != 0.0, ad0[b0:])
    if resub is not None:
        state = state._replace(sched=state.sched._replace(
            deficit=charge_resubmit(policy, state.sched.deficit, resub)))
    comp_slot = comp[0].astype(jnp.int32)
    finish = state.req.finish_ms.at[comp_slot].set(comp[1], mode="drop")
    state = state._replace(
        now_ms=now, req=state.req._replace(finish_ms=finish))
    state = _complete_and_timeout(policy, phys, batch, state)
    alive = (state.req.status == PENDING) | (state.req.status == INFLIGHT)
    batch, req, _ = _compact_and_admit(batch, state.req, alive, staged,
                                       n_stage)
    state = state._replace(req=req)
    d = schedule_batch(policy, batch, state,
                       max_grants=max_grants, backend=backend)
    # idle-sleep hint: earliest defer/Retry-After expiry already on the
    # books (this epoch's defers are added host-side from `backoff`)
    pend = req.status == PENDING
    next_defer = jnp.where(pend & (req.defer_until > now),
                           req.defer_until, jnp.inf).min()
    backoff = olc.defer_backoff(policy, d.severity, req.n_defers[d.req_idx])
    summary = jnp.concatenate([
        d.actions.astype(jnp.float32),
        d.req_idx.astype(jnp.float32),
        d.inflight_at.astype(jnp.float32),
        backoff,
        d.severity[None],
        next_defer[None],
    ])
    return batch, state, d, summary


def _freeze(tree) -> tuple:
    """Hashable value-key for a pytree of arrays (shape, dtype, bytes
    per leaf) — equality means numerically identical."""
    return tuple(
        (np.asarray(leaf).shape, str(np.asarray(leaf).dtype),
         np.asarray(leaf).tobytes())
        for leaf in jax.tree_util.tree_leaves(tree))


_TICK_CACHE: dict = {}


def _tick_for(policy: PolicyConfig, phys: ProviderPhysics,
              max_grants: int, backend: str):
    """Jitted fused tick with `policy` and `phys` baked in as trace
    constants.  A session's policy never changes mid-flight, and baking
    it buys the hot path twice: the per-poll dispatch flattens ~30
    argument leaves instead of ~60, and XLA folds the constant knobs
    through the program (the alloc-mode switch collapses to the one
    live branch, threshold ladders become immediates).  Cached by VALUE
    so every session with a numerically identical (policy, phys, B,
    backend) shares one compilation."""
    key = (_freeze(policy), _freeze(phys), max_grants, backend)
    fn = _TICK_CACHE.get(key)
    if fn is None:
        if len(_TICK_CACHE) > 64:
            _TICK_CACHE.clear()
        # RPL002 audit: donates positions 0-1 (the (W,) window pool and
        # device-state bundle). Callers reach this through `self._tick`,
        # declared in [tool.reprolint.donating-callables] so the
        # dataflow rule sees the donation through the bound method; both
        # call sites rebind the donated attributes in the same statement
        # (tests/test_serving_client.py::test_stale_post_donation_read_raises
        # is the runtime twin).
        fn = jax.jit(
            functools.partial(_fused_tick, policy, phys,
                              max_grants=max_grants, backend=backend),
            donate_argnums=(0, 1))
        _TICK_CACHE[key] = fn
    return fn


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------


class ClientSession:
    """Streaming three-layer client over an `AsyncProvider`.

    Lifecycle: `submit()` any number of requests over time (admission
    into the window is FIFO by submission order; keep arrivals
    nondecreasing when replaying a trace), `poll()` one decision epoch,
    `drain()` until everything submitted is terminal.  See the module
    docstring for the architecture.

    `phys` is the *client's* latency model — the unloaded-latency
    expectation the tail EMA normalizes observed completions against
    (client-observable signals only, per the paper; the benchmarks
    calibrate it against the real engine).

    `resilience` arms the watchdog (repro.client.resilience): per-
    request client-side deadlines, bounded-budget resubmission of stuck
    requests, and synthetic-abandon give-up — the machinery that keeps
    the session live against a provider that drops or wedges work.
    None (the default) is the trusting session: byte-identical device
    program, zero extra host work.  Duplicate-safe ingestion is NOT
    gated on this — at-least-once delivery is survived unconditionally.
    """

    def __init__(
        self,
        provider: AsyncProvider,
        policy: PolicyConfig,
        cfg: SessionConfig = SessionConfig(),
        *,
        clock: str = "wall",
        phys: ProviderPhysics | None = None,
        retry_policy: RetryPolicy | None = None,
        resilience: ResilienceConfig | None = None,
    ):
        if clock not in ("wall", "virtual"):
            raise ValueError(f"clock must be 'wall' or 'virtual', got {clock!r}")
        self.provider = provider
        self.policy = policy
        self.cfg = cfg
        self.clock = clock
        self.phys = phys if phys is not None else default_physics()
        self.retry_policy = retry_policy or honor_retry_after
        self.stats = SessionStats()
        self._prof: Optional[dict] = None

        w = cfg.window
        self._k = n_classes(policy)
        self._win_batch = empty_window_batch(w)
        self._dev_state = init_sim_state(w, self._k)._replace(
            req=empty_window_request_state(w))
        self._pending = None  # (BatchDecision, accepted, delay) to fold in
        self._idle_cache: Optional[PollResult] = None
        # host mirrors (kept in lockstep with the device pool; float32
        # fields replay the device's own comparison chains bit-exactly)
        self._reqs: list[Request] = []
        self._arrival_ms: list[float] = []
        # columnar staging features, filled at submit() — queue pops
        # are a contiguous rid range, so staging is 7 list-slice
        # assigns into the packed transfer buffer, not a per-row loop
        self._cols: tuple[list, ...] = tuple([] for _ in range(7))
        self._queue: deque[int] = deque()
        self._slot_rid = np.full(w, -1, np.int64)
        self._slot_status = np.full(w, REJECTED, np.int32)
        self._slot_arrival = np.zeros(w, np.float32)
        self._slot_thresh = np.full(w, np.inf, np.float32)
        self._slot_finish = np.full(w, np.inf, np.float32)
        self._n_live = 0
        self._tickets: dict[int, int] = {}
        self._unfinished = 0
        self._t = 0
        self._t0: Optional[float] = None
        self._defer_hint = float("inf")
        self._timeout_mult = np.asarray(policy.timeout_mult, np.float32)
        # reused per-poll transfer buffers (jit copies them at call
        # time, so in-place refills between calls are safe)
        self._comp = np.empty((2, w), np.float32)
        self._comp[0] = w          # scatter sentinel: dropped by the set
        self._comp[1] = np.inf
        self._staged_px = np.zeros((7, w), np.float32)
        self._staged_px[_ST_TOKENS:_ST_P90 + 1] = 1.0
        self._staged_px[_ST_DEADLINE] = 1e9
        self._watchdog = (Watchdog(resilience, self.phys)
                          if resilience is not None else None)
        # (K,) per-class deficit charge for this epoch's resubmissions;
        # reused transfer buffer like _comp (jit copies at call time)
        self._resub_charge = np.zeros(self._k, np.float32)
        self._tick = _tick_for(policy, self.phys, cfg.max_grants,
                               cfg.backend)
        self._warmup()

    @property
    def _state(self) -> SimState:
        """Post-apply device state.  The fused tick leaves the previous
        epoch's transition pending (it is folded into the next poll);
        introspection flushes it first so callers always observe the
        state as if the epoch had been applied eagerly."""
        if self._pending is not None:
            d, ad = self._pending
            b = self._bm
            self._dev_state = _apply_decisions(
                self.policy, self._win_batch, self._dev_state, d,
                ad[:b] != 0.0, ad[b:].copy())
            self._pending = None
        return self._dev_state

    def _warmup(self) -> None:
        """Compile the session's device step against the (W, B, K)
        shapes before the clock starts: XLA compilation takes seconds,
        and a wall-clock session that compiles inside its first poll
        would burn that as session time — at time_scale >> 1 enough to
        blow every deadline before the first decision lands.  Both trace
        variants (with and without the leading apply) and the flush path
        are warmed; the throwaway buffers are re-initialized after."""
        w, k = self.cfg.window, self._k
        zero = np.int32(0)
        t0 = np.float32(0.0)
        # resilient sessions always pass the (K,) resubmit charge, so
        # those are the variants to warm; trusting sessions omit the
        # argument entirely (distinct trace, byte-identical to the
        # pre-resilience program)
        extra = (self._resub_charge,) if self._watchdog is not None else ()
        batch1, state1, d1, _ = self._tick(
            self._win_batch, self._dev_state, None,
            self._comp, self._staged_px, zero, t0, *extra)
        bm = int(d1.actions.shape[0])
        self._bm = bm
        self._accdelay = np.zeros(2 * bm, np.float32)
        self._accdelay[:bm] = 1.0
        batch2, state2, d2, _ = self._tick(
            batch1, state1, (d1, self._accdelay),
            self._comp, self._staged_px, zero, t0, *extra)
        out = _apply_decisions(self.policy, batch2, state2, d2,
                               self._accdelay[:bm] != 0.0,
                               self._accdelay[bm:].copy())
        jax.block_until_ready(out.req.status)
        self._win_batch = empty_window_batch(w)
        self._dev_state = init_sim_state(w, k)._replace(
            req=empty_window_request_state(w))

    # --- clock --------------------------------------------------------
    def _wall_now_ms(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return (time.monotonic() - self._t0) * 1e3 * self.cfg.time_scale

    def now_ms(self) -> float:
        if self.clock == "virtual":
            return float(np.float32(self._t) * np.float32(self.cfg.dt_ms))
        return self._wall_now_ms()

    # --- lifecycle ----------------------------------------------------
    def submit(self, req: Request) -> int:
        """Register a request; returns its session rid.  `arrival_s` is
        honored as given (0.0 = arrived at session start); wall-clock
        callers typically leave it 0 or stamp it with `now_ms()/1e3`."""
        rid = len(self._reqs)
        self._reqs.append(req)
        arrival = float(np.float32(req.arrival_s * 1000.0))
        self._arrival_ms.append(arrival)
        bkt = int(req.bucket)
        c = self._cols
        c[_ST_ARRIVAL].append(arrival)
        c[_ST_BUCKET].append(bkt)
        c[_ST_CLS].append(req.resolved_cls())
        c[_ST_TOKENS].append(float(req.max_new))
        c[_ST_P50].append(float(req.p50))
        c[_ST_P90].append(float(req.resolved_p90()))
        c[_ST_DEADLINE].append(_DEADLINE_PY[bkt])
        self._queue.append(rid)
        self._unfinished += 1
        self._idle_cache = None
        return rid

    @property
    def unfinished(self) -> int:
        return self._unfinished

    def enable_profiling(self) -> dict:
        """Turn on per-poll wall-time accounting and return the live
        accumulator dict.  Buckets (seconds, cumulative over profiled
        polls): `stage` — host-side work (completion ingest, retirement
        classification, arrival staging, mirror compaction), `dispatch`
        — the async fused-tick call (argument flatten + enqueue; the
        device executes concurrently with the mirror work), `pull` —
        the blocking device->host summary fetch, i.e. time actually
        waiting on the device, `grants` — the provider submit loop and
        verdict bookkeeping.  `polls` counts profiled epochs (the
        post-drain idle fast path is excluded — it does no device
        work)."""
        self._prof = {"stage": 0.0, "dispatch": 0.0, "pull": 0.0,
                      "grants": 0.0, "polls": 0}
        return self._prof

    def requests(self) -> list[Request]:
        return list(self._reqs)

    def _stage_admissions(self, now_ms: float, free: int) -> list[int]:
        """Pop arrived requests off the FIFO queue into the prefix of
        the persistent staging buffers (the window-admission rule the
        engine's `_compact_and_admit` applies to its arrival stream).
        Rows past the returned count are ignored by the device (masked
        by `n_stage`), so no reset is needed between polls."""
        rids = []
        while self._queue and len(rids) < free \
                and self._arrival_ms[self._queue[0]] <= now_ms:
            rids.append(self._queue.popleft())
        if not rids:
            return rids
        # rids popped FIFO off the monotone submit stream are a
        # contiguous range, so the staging features are column slices:
        # seven bulk assigns, no per-row work
        r0, n = rids[0], len(rids)
        px = self._staged_px
        for row, col in enumerate(self._cols):
            px[row, :n] = col[r0:r0 + n]
        return rids

    def _run_watchdog(self, now_ms: float, now32: np.float32, nl: int,
                      comp_by_rid: dict) -> None:
        """The resilience pass (repro.client.resilience): resubmit
        overdue in-flight requests within budget, give up — via a
        synthetic completion the retirement chain classifies
        timed_out -> ABANDONED — once the budget is gone and the slot's
        own timeout threshold has passed.  Mutates `comp_by_rid` (the
        pre-scatter completion view) and the ticket map only; device
        state is touched exclusively through the ordinary ingest path."""
        wd = self._watchdog
        for rid in wd.overdue(now_ms):
            if rid in comp_by_rid:
                continue  # landed this very epoch; retirement untracks it
            slot = int(np.searchsorted(self._slot_rid[:nl], rid))
            if slot >= nl or self._slot_rid[slot] != rid \
                    or self._slot_status[slot] != INFLIGHT:
                # defensive: no longer an in-flight slot (retirement
                # should have untracked it already)
                for t in wd.note_terminal(rid):
                    self._tickets.pop(t, None)
                continue
            r = self._reqs[rid]
            if wd.budget_left(rid):
                res = self.provider.submit(r, now_ms)
                if res.accepted:
                    # the attempts race: the old ticket stays mapped,
                    # first completion wins, the loser is discarded by
                    # dup-safe ingestion
                    self._tickets[res.ticket] = rid
                    wd.note_resubmit(rid, r, res.ticket, now_ms)
                    r.n_resubmits += 1
                    cls = min(max(r.resolved_cls(), 0), self._k - 1)
                    self._resub_charge[cls] += np.float32(r.p50)
                    self.stats.n_resubmitted += 1
                else:
                    # 429 on the recovery path: no budget consumed,
                    # re-check after the (sanitized) backoff
                    r.n_throttles += 1
                    delay = self.retry_policy(
                        sanitize_retry_after_ms(res.retry_after_ms),
                        r.n_throttles)
                    wd.note_bounced(rid, float(delay), now_ms)
                    self.stats.n_throttled += 1
                continue
            # budget exhausted: once the slot's e2e threshold has
            # passed (the same f32 comparison the classifier runs), a
            # synthetic completion stamped `now` is guaranteed to
            # classify timed_out -> ABANDONED on device and mirror
            # alike — give-up needs no second retirement mechanism
            if np.float32(now32 - self._slot_arrival[slot]) \
                    > self._slot_thresh[slot]:
                wd.give_up(rid)
                self.stats.n_gave_up += 1
                comp_by_rid[rid] = Completion(-1, float(now32), None)

    def poll(self, now_ms: Optional[float] = None) -> PollResult:
        """One decision epoch: one fused device step (apply previous
        verdicts, ingest completions, retire, compact + admit, dispatch)
        plus the host-side provider boundary (submit grants, collect 429
        verdicts).  O(W + B) regardless of session history length."""
        self._t += 1
        if now_ms is None:
            now_ms = self.now_ms() if self.clock == "wall" else float(
                np.float32(np.float32(self._t) * np.float32(self.cfg.dt_ms)))
        w, b = self.cfg.window, self._bm
        self.stats.n_polls += 1

        # post-drain fast path: an empty pool with nothing queued and
        # nothing in flight is a fixpoint (deficits reset on the first
        # idle epoch, the EMA holds, severity is constant), so the epoch
        # is replayed from the cached result with zero device work
        if (self._idle_cache is not None and not self._queue
                and not self._tickets and not self._unfinished):
            return self._idle_cache._replace(now_ms=now_ms)

        prof = self._prof
        if prof is not None:
            _tp0 = time.perf_counter()
        now32 = np.float32(now_ms)
        nl = self._n_live

        # 1. provider completions -> comp scatter prefix + finish mirror.
        # Ingestion is duplicate-safe: the FIRST arrival for a rid wins,
        # and everything else — a redelivered ticket, a raced attempt
        # whose sibling already landed, a completion for a rid the
        # session already retired — is discarded HERE, before the
        # scatter, so the donated-buffer tick never sees a double-retire
        comps = self.provider.poll(now_ms)
        comp_by_rid: dict[int, Completion] = {}
        ncomp = 0
        for c in comps:
            rid = self._tickets.pop(c.ticket, None)
            if rid is None or rid in comp_by_rid:
                # dead ticket (dup redelivery / resolved race) or a
                # second arrival for the same rid within this epoch
                self.stats.n_dup_discarded += 1
                continue
            comp_by_rid[rid] = c
        if self._watchdog is not None:
            self._run_watchdog(now_ms, now32, nl, comp_by_rid)
        if comp_by_rid:
            rid_list = sorted(comp_by_rid)
            rids = np.asarray(rid_list, np.int64)
            slots = np.searchsorted(self._slot_rid[:nl], rids)
            if nl:
                live = ((slots < nl)
                        & (self._slot_rid[np.minimum(slots, nl - 1)] == rids))
            else:
                live = np.zeros(len(rids), bool)
            if not live.all():
                # late arrival: the rid no longer holds a window slot
                # (retired in an earlier epoch, e.g. after give-up)
                for i in np.nonzero(~live)[0]:
                    del comp_by_rid[rid_list[i]]
                    self.stats.n_late_discarded += 1
                rids, slots = rids[live], slots[live]
                rid_list = [r for r in rid_list if r in comp_by_rid]
            # asarray(..., f32) rounds each f64 element exactly like a
            # per-element np.float32() cast
            ncomp = len(rids)
            if ncomp:
                fins = np.asarray(
                    [comp_by_rid[r].finish_ms for r in rid_list], np.float32)
                self._comp[0, :ncomp] = slots
                self._comp[1, :ncomp] = fins
                self._slot_finish[slots] = fins

        # 2. retirement classification on the f32 mirrors — the same
        # comparison chains `_complete_and_timeout` runs on the device
        # (sub/mul/compare round identically in f32; no FMA can form
        # across a comparison), so the verdicts match bit-for-bit
        st = self._slot_status[:nl]
        arr = self._slot_arrival[:nl]
        fin = self._slot_finish[:nl]
        th = self._slot_thresh[:nl]
        landed = (st == INFLIGHT) & (fin <= now32)
        timed_out = landed & ((fin - arr) > th)
        stale = (st == PENDING) & (arr <= now32) & ((now32 - arr) > th)
        dead = landed | stale
        completed: list[int] = []
        abandoned: list[int] = []
        for slot in np.nonzero(dead)[0]:
            rid = int(self._slot_rid[slot])
            r = self._reqs[rid]
            if landed[slot] and not timed_out[slot]:
                c = comp_by_rid.get(rid)
                r.status = "completed"
                r.finish_s = float(fin[slot]) / 1e3 \
                    if c is None else float(c.finish_ms) / 1e3
                if c is not None:
                    r.output = c.output
                completed.append(rid)
                self.stats.n_completed += 1
            else:
                # stale pending, or landed past the timeout multiple
                r.status = "abandoned"
                abandoned.append(rid)
                self.stats.n_abandoned += 1
            self._unfinished -= 1
            if self._watchdog is not None:
                # unmap every racing ticket this rid still holds: their
                # late completions are discarded at ingestion
                for t in self._watchdog.note_terminal(rid):
                    self._tickets.pop(t, None)
        alive = ((st == PENDING) | (st == INFLIGHT)) & ~dead
        n_alive = int(alive.sum())

        # 3. stage arrivals + 4. the fused device step
        staged_rids = self._stage_admissions(now_ms, w - n_alive)
        n_stage = len(staged_rids)
        if prof is not None:
            _tp1 = time.perf_counter()
        extra = (self._resub_charge,) if self._watchdog is not None else ()
        self._win_batch, self._dev_state, d, summary = self._tick(
            self._win_batch, self._dev_state, self._pending,
            self._comp, self._staged_px, np.int32(n_stage), now32, *extra)
        if prof is not None:
            _tp2 = time.perf_counter()
        # the dispatch is async: the mirror bookkeeping below depends
        # only on host state, so it runs while the device executes —
        # the blocking summary pull comes after
        if ncomp:
            self._comp[0, :ncomp] = w
            self._comp[1, :ncomp] = np.inf
        if extra and self._resub_charge.any():
            self._resub_charge[:] = 0.0

        # 5. mirror compaction (lockstep with the device scatter)
        nt = n_alive + n_stage
        self._slot_rid[:n_alive] = self._slot_rid[:nl][alive]
        self._slot_status[:n_alive] = st[alive]
        self._slot_arrival[:n_alive] = arr[alive]
        self._slot_thresh[:n_alive] = th[alive]
        self._slot_finish[:n_alive] = fin[alive]
        if n_stage:
            sl = slice(n_alive, nt)
            self._slot_rid[sl] = staged_rids
            self._slot_status[sl] = PENDING
            px = self._staged_px
            self._slot_arrival[sl] = px[_ST_ARRIVAL, :n_stage]
            self._slot_thresh[sl] = (
                self._timeout_mult[px[_ST_BUCKET, :n_stage].astype(np.int64)]
                * px[_ST_DEADLINE, :n_stage])
            self._slot_finish[sl] = np.inf
            for rid in staged_rids:
                self._reqs[rid].status = "pending"
        self._slot_rid[nt:self._n_live] = -1
        self._slot_status[nt:self._n_live] = REJECTED
        self._n_live = nt

        # 6. submit grants (decision order); collect 429 verdicts
        if prof is not None:
            _tp3 = time.perf_counter()
        summary = np.asarray(summary)  # the one device->host pull
        if prof is not None:
            _tp4 = time.perf_counter()
        actions = summary[0:b].astype(np.int32)
        idxs = summary[b:2 * b].astype(np.int32)
        infl_at = summary[2 * b:3 * b].astype(np.int32)
        backoff = summary[3 * b:4 * b]
        severity = np.float32(summary[4 * b])
        dev_next_defer = float(summary[4 * b + 1])
        ad = self._accdelay
        ad[:b] = 1.0
        ad[b:] = 0.0
        req_rids = np.full(b, -1, np.int64)
        admitted, deferred, rejected, throttled = [], [], [], []
        for g in range(b):
            a = actions[g]
            if a == IDLE:
                continue
            slot = idxs[g]
            rid = int(self._slot_rid[slot])
            req_rids[g] = rid
            r = self._reqs[rid]
            if a == olc.ADMIT:
                res = self.provider.submit(
                    r, now_ms, inflight_hint=int(infl_at[g]))
                if res.accepted:
                    self._tickets[res.ticket] = rid
                    r.status = "inflight"
                    r.submit_s = now_ms / 1e3
                    self._slot_status[slot] = INFLIGHT
                    admitted.append(rid)
                    self.stats.n_admitted += 1
                    if self._watchdog is not None:
                        self._watchdog.note_admit(rid, r, res.ticket, now_ms)
                else:
                    ad[g] = 0.0
                    r.n_throttles += 1
                    # f32-array store rounds the f64 delay identically
                    # to an explicit np.float32 cast.  The hint is
                    # sanitized first: a hostile (negative/NaN)
                    # Retry-After must not mint a defer expiry in the
                    # past or poison the idle-sleep hint
                    ad[b + g] = self.retry_policy(
                        sanitize_retry_after_ms(res.retry_after_ms),
                        r.n_throttles)
                    throttled.append(rid)
                    self.stats.n_throttled += 1
            elif a == olc.DEFER:
                r.n_defers += 1
                deferred.append(rid)
                self.stats.n_deferred += 1
            else:  # REJECT
                r.status = "rejected"
                self._slot_status[slot] = REJECTED
                rejected.append(rid)
                self.stats.n_rejected += 1
                self._unfinished -= 1

        # 7. the device transition folds into the next poll's step
        self._pending = (d, ad)
        self.stats.peak_inflight = max(
            self.stats.peak_inflight, self.provider.inflight())
        hint = dev_next_defer
        if deferred:
            hint = min(hint, float(
                (now32 + backoff[actions == olc.DEFER]).min()))
        if throttled:
            bounced = ad[:b] == 0.0
            hint = min(hint, float((now32 + ad[b:][bounced]).min()))
        self._defer_hint = hint

        if prof is not None:
            _tp5 = time.perf_counter()
            prof["stage"] += (_tp1 - _tp0) + (_tp3 - _tp2)
            prof["dispatch"] += _tp2 - _tp1
            prof["pull"] += _tp4 - _tp3
            prof["grants"] += _tp5 - _tp4
            prof["polls"] += 1
        progressed = bool(
            completed or abandoned or rejected or admitted or deferred
            or throttled or staged_rids)
        result = PollResult(
            now_ms=now_ms, actions=actions, req_rids=req_rids,
            severity=severity, completed=completed, abandoned=abandoned,
            rejected=rejected, admitted=admitted, deferred=deferred,
            throttled=throttled, n_live=self._n_live, progressed=progressed)
        if (not progressed and not self._unfinished and not self._queue
                and not self._tickets and nt == 0 and ncomp == 0):
            self._idle_cache = result
        return result

    # --- drain --------------------------------------------------------
    def _idle_sleep(self, now_ms: float) -> None:
        """Sleep until the next actionable instant instead of spinning:
        the next queued arrival, the earliest defer/Retry-After expiry,
        or the provider's next-event hint — capped so an unhintable
        transport still gets re-polled."""
        cands = []
        if self._queue:
            cands.append(self._arrival_ms[self._queue[0]])
        if np.isfinite(self._defer_hint):
            cands.append(self._defer_hint)
        if self._watchdog is not None:
            nd = self._watchdog.next_deadline_ms()
            if np.isfinite(nd):
                cands.append(nd)
        pe = self.provider.next_event_ms(now_ms)
        if pe is not None:
            cands.append(pe)
        # a candidate already due (e.g. a queued arrival stuck behind a
        # full window) is not a wakeup signal — keeping it would clamp
        # the sleep to zero and busy-spin until the blocker clears
        cands = [c for c in cands if c > now_ms]
        target = min(cands) if cands else now_ms + self.cfg.max_idle_sleep_ms
        target = min(target, now_ms + self.cfg.max_idle_sleep_ms)
        sleep_s = (target - now_ms) / 1e3 / self.cfg.time_scale
        if sleep_s > 0:
            self.stats.n_idle_sleeps += 1
            time.sleep(sleep_s)

    def _live_slot_report(self, limit: int = 16) -> str:
        """Human-readable snapshot of the occupied window slots for
        liveness diagnostics: (rid, status, age_ms) triples."""
        names = {PENDING: "pending", INFLIGHT: "inflight"}
        nl = self._n_live
        now = np.float32(self.now_ms())
        rows = []
        for slot in range(nl):
            st = int(self._slot_status[slot])
            if st not in names:
                continue
            rows.append(
                f"(rid={int(self._slot_rid[slot])} {names[st]} "
                f"age={float(now - self._slot_arrival[slot]):.0f}ms)")
        extra = f" ... +{len(rows) - limit} more" if len(rows) > limit else ""
        return " ".join(rows[:limit]) + extra

    def drain(self, max_polls: Optional[int] = None,
              max_idle_ms: Optional[float] = None) -> list[Request]:
        """Poll until every submitted request is terminal.  Wall-clock
        sessions sleep through idle epochs; virtual sessions advance one
        tick per poll.  Ends with one settling epoch that compacts the
        last retirements out of the pool and primes the idle fast path
        (subsequent polls on the drained session are host-only no-ops).
        Returns the session's requests.

        `max_idle_ms` is the liveness guard: if no poll makes progress
        for that much session time — the signature of a completion that
        will never arrive (e.g. silently dropped by the provider) — the
        drain raises a diagnostic RuntimeError naming the live slots,
        the provider's inflight count, and the last-progress timestamp,
        instead of sleeping forever.  None (the default) preserves the
        wait-forever contract for trusted transports."""
        n = 0
        last_progress: Optional[float] = None
        while self._unfinished:
            r = self.poll()
            n += 1
            if last_progress is None or r.progressed:
                last_progress = r.now_ms
            if self._unfinished and max_polls is not None and n >= max_polls:
                raise RuntimeError(
                    f"drain: {self._unfinished} request(s) still live "
                    f"after {n} polls")
            if (max_idle_ms is not None and self._unfinished
                    and r.now_ms - last_progress > max_idle_ms):
                raise RuntimeError(
                    f"drain: no progress for "
                    f"{r.now_ms - last_progress:.0f} ms (cap "
                    f"{max_idle_ms:.0f} ms): {self._unfinished} "
                    f"unfinished, {self.provider.inflight()} "
                    f"provider-inflight, last progress at "
                    f"t={last_progress:.0f} ms (now t={r.now_ms:.0f} ms); "
                    f"live slots: {self._live_slot_report()}")
            if self.clock == "wall" and not r.progressed:
                self._idle_sleep(r.now_ms)
        if not self._queue and not self._tickets \
                and self._idle_cache is None:
            self.poll()  # settle: retire bookkeeping, prime the fast path
        return list(self._reqs)
