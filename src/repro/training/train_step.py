"""Train step: value_and_grad over lm_loss with remat-inside-scan, AdamW,
optional gradient-accumulation microbatching (the memory/perf knob the
roofline hillclimb sweeps)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models import Model
from repro.models.common import dtype_of
from repro.models.model import lm_loss
from repro.training import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def init_train_state(model: Model, tc: TrainConfig) -> TrainState:
    return TrainState(params=model.params, opt=adamw.init(model.params))


def _loss_fn(params, cfg: ModelConfig, batch, tc: TrainConfig):
    return lm_loss(
        params, cfg, batch["tokens"], batch["labels"],
        batch.get("prefix_embeds"), impl="xla", remat=tc.remat)


def _grads(params, cfg, batch, tc):
    """Whole-batch or microbatched (scan) gradients."""
    if tc.microbatches <= 1:
        return jax.value_and_grad(_loss_fn)(params, cfg, batch, tc)

    n = tc.microbatches
    split = lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:])
    mb = jax.tree.map(split, batch)

    def step(carry, micro):
        loss_acc, grad_acc = carry
        loss, g = jax.value_and_grad(_loss_fn)(params, cfg, micro, tc)
        return (loss_acc + loss / n,
                jax.tree.map(lambda a, b: a + b / n, grad_acc, g)), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(step, (jnp.float32(0.0), zeros), mb)
    return loss, grads


def train_step(state: TrainState, batch, cfg: ModelConfig, tc: TrainConfig):
    loss, grads = _grads(state.params, cfg, batch, tc)
    new_params, new_opt, om = adamw.apply(
        state.opt, grads, tc, dtype_of(cfg.dtype))
    metrics = {"loss": loss, **om}
    return TrainState(params=new_params, opt=new_opt), metrics
