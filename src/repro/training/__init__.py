from repro.training import adamw, train_step  # noqa: F401
from repro.training.train_step import TrainState, init_train_state  # noqa: F401
