"""Hand-rolled AdamW with fp32 master weights + moments (optax is not
available offline). Optimizer state is a pytree mirroring the params so
the sharding rules shard it identically (ZeRO-style when params are
FSDP-sharded)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray     # () int32
    master: Any           # fp32 master copy of params
    m: Any                # fp32 first moment
    v: Any                # fp32 second moment


def init(params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_schedule(tc: TrainConfig, step) -> jnp.ndarray:
    """Linear warmup then cosine decay to 10%."""
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(tc.warmup_steps, 1)
    frac = jnp.clip(
        (s - tc.warmup_steps) / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
        0.0, 1.0)
    cos = 0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return tc.lr * jnp.where(s < tc.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply(state: AdamWState, grads, tc: TrainConfig, param_dtype):
    """One AdamW update. grads may be bf16; math is fp32.
    Returns (new_params_in_model_dtype, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(tc, step)
    b1, b2, eps, wd = tc.b1, tc.b2, tc.eps, tc.weight_decay
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + eps) + wd * master)
        return new_master, m, v

    out = jax.tree.map(upd, grads, state.master, state.m, state.v)
    new_master = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    new_state = AdamWState(step=step, master=new_master, m=new_m, v=new_v)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
