"""Serving engine: batched prefill + jit'd decode loop over the model zoo.

`generate` is the reference generation loop (greedy / temperature) used by
the examples and the latency-calibration benchmark; `serve_step` /
`prefill_step` are the AOT-loweable entry points the multi-pod dry-run
compiles (decode shapes lower serve_step per the assignment).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ServeConfig
from repro.models import decode_step, prefill


class GenState(NamedTuple):
    tokens: jnp.ndarray      # (B, S_max) generated ids
    pos: jnp.ndarray         # () int32 absolute position
    caches: Any
    done: jnp.ndarray        # (B,) bool
    key: jax.Array


def prefill_step(params, cfg: ModelConfig, tokens, max_seq: int,
                 prefix_embeds=None, impl: str = "xla"):
    """AOT entry point for prefill shapes: logits + caches."""
    return prefill(params, cfg, tokens, max_seq, prefix_embeds, impl)


def serve_step(params, cfg: ModelConfig, token, pos, caches,
               impl: str = "xla"):
    """AOT entry point for decode shapes: ONE new token against a cache."""
    return decode_step(params, cfg, token, pos, caches, impl)


def _sample(key, logits, temperature: float):
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg", "sc", "n_new", "impl"))
def _generate_jit(params, cfg: ModelConfig, sc: ServeConfig, prompt,
                  prompt_len, key, n_new: int, impl: str = "xla",
                  prefix_embeds=None):
    B, S_p = prompt.shape
    logits, caches = prefill(params, cfg, prompt, sc.max_seq, prefix_embeds,
                             impl)
    pos0 = S_p + (cfg.prefix_len if prefix_embeds is not None else 0)
    k0, key = jax.random.split(key)
    first = _sample(k0, logits[:, -1], sc.temperature).astype(jnp.int32)

    tokens0 = jnp.zeros((B, n_new), jnp.int32).at[:, 0].set(first)
    state = GenState(
        tokens=tokens0,
        pos=jnp.asarray(pos0, jnp.int32),
        caches=caches,
        done=first == sc.eos_id,
        key=key,
    )

    def step(state: GenState, i):
        tok = jax.lax.dynamic_slice_in_dim(state.tokens, i, 1, axis=1)
        logits, caches = decode_step(params, cfg, tok, state.pos, state.caches, impl)
        k, key = jax.random.split(state.key)
        nxt = _sample(k, logits[:, -1], sc.temperature).astype(jnp.int32)
        nxt = jnp.where(state.done, sc.eos_id, nxt)
        tokens = jax.lax.dynamic_update_slice_in_dim(
            state.tokens, nxt[:, None], i + 1, axis=1)
        done = state.done | (nxt == sc.eos_id)
        return GenState(tokens, state.pos + 1, caches, done, key), None

    state, _ = jax.lax.scan(step, state, jnp.arange(n_new - 1))
    return state.tokens, state.done


def generate(params, cfg: ModelConfig, sc: ServeConfig, prompt,
             n_new: int, seed: int = 0, impl: str = "xla",
             prefix_embeds=None):
    """prompt: (B, S_p) int32 -> (B, n_new) generated ids."""
    key = jax.random.PRNGKey(seed)
    toks, done = _generate_jit(
        params, cfg, sc, prompt, prompt.shape[1], key, n_new, impl,
        prefix_embeds)
    return toks
