from repro.serving.engine import generate, prefill_step, serve_step  # noqa: F401
from repro.serving.blackbox import BlackBoxProvider, Request, ScheduledClient  # noqa: F401
