from repro.serving.engine import generate, prefill_step, serve_step  # noqa: F401
from repro.serving.blackbox import BlackBoxProvider, ScheduledClient  # noqa: F401
# the client surface proper lives in repro.client; Request is re-exported
# here for compatibility with the pre-§7 import path
from repro.client import (  # noqa: F401
    AsyncBlackBoxProvider,
    ClientSession,
    MockProvider,
    Request,
    SessionConfig,
)
