"""The black-box boundary, made concrete.

`BlackBoxProvider` wraps the real JAX serving engine behind exactly the
API surface the paper assumes the client sees: submit(request) ->
completion with latency; no internals exposed.  `ScheduledClient` runs
the paper's three-layer stack (repro.core) in front of it — the same
batched `schedule_batch` decision function the simulator uses, driven by
wall clock instead of ticks: each poll runs ONE vectorized pass and
drains up to `max_grants` sends, instead of re-tracing the full policy
per request.  This is the end-to-end deployment path
(examples/serve_blackbox.py) proving the scheduler is not simulator-bound.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig
from repro.core import overload as olc
from repro.core.policy import PolicyConfig, n_classes
from repro.core.scheduler import IDLE, schedule_batch
from repro.core.types import (
    COMPLETED,
    INFLIGHT,
    REJECTED,
    RequestBatch,
    init_sim_state,
)
from repro.serving.engine import generate
from repro.sim.workload import DEADLINE_BUDGET_MS, bucket_to_class


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S_p,) int32
    max_new: int                # realized output tokens (the "true" cost)
    p50: float                  # coarse prior available at submission
    bucket: int
    cls: Optional[int] = None   # service class; None = paper 2-lane
                                # bucket split (K-class policies expect
                                # the caller to tag tenant/lane ids)
    arrival_s: float = 0.0
    submit_s: float = 0.0
    finish_s: float = 0.0
    status: str = "pending"
    output: Optional[np.ndarray] = None


class BlackBoxProvider:
    """A real JAX model behind an opaque submit() API."""

    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig):
        self.params, self.cfg, self.sc = params, cfg, sc

    def submit(self, prompt: np.ndarray, max_new: int) -> np.ndarray:
        out = generate(self.params, self.cfg, self.sc,
                       jnp.asarray(prompt)[None], max_new)
        return np.asarray(out[0])


class ScheduledClient:
    """Three-layer client (allocation/ordering/overload) in front of a
    BlackBoxProvider, reusing the exact same `schedule_batch` the
    simulator exercises — the policy logic is written once (DESIGN.md
    §2).  Each wall-clock poll makes one batched decision and drains up
    to `max_grants` releases."""

    def __init__(self, provider: BlackBoxProvider, policy: PolicyConfig,
                 max_grants: int = 4):
        self.provider = provider
        self.policy = policy
        self.requests: list[Request] = []
        # max_grants is baked into the jitted partial (it must be static);
        # build a new client to change the drain width
        self._batch = jax.jit(
            functools.partial(schedule_batch, max_grants=max_grants))

    def run(self, requests: list[Request], time_scale: float = 1.0) -> list[Request]:
        """Executes the full request list; arrival times honored in scaled
        wall clock. Synchronous single-threaded submission (the engine is
        compute-bound on CPU); the scheduler still controls ORDER and
        admit/defer/reject, which is what the paper's layers own."""
        n = len(requests)
        buckets = jnp.asarray([r.bucket for r in requests], jnp.int32)
        default_cls = np.asarray(bucket_to_class(buckets))  # one device pull
        cls = jnp.asarray(
            [r.cls if r.cls is not None else default_cls[i]
             for i, r in enumerate(requests)], jnp.int32)
        batch = RequestBatch(
            arrival_ms=jnp.asarray([r.arrival_s * 1e3 for r in requests], jnp.float32),
            bucket=buckets,
            cls=cls,
            true_tokens=jnp.asarray([r.max_new for r in requests], jnp.float32),
            p50=jnp.asarray([r.p50 for r in requests], jnp.float32),
            p90=jnp.asarray([r.p50 * 1.8 for r in requests], jnp.float32),
            deadline_budget_ms=DEADLINE_BUDGET_MS[buckets],
            valid=jnp.ones((n,), bool),
        )
        state = init_sim_state(n, n_classes(self.policy))
        t0 = time.monotonic()

        done = 0
        while done < n:
            now_ms = (time.monotonic() - t0) * 1e3 * time_scale
            state = state._replace(now_ms=jnp.float32(now_ms))
            d = self._batch(self.policy, batch, state)
            state = state._replace(sched=state.sched._replace(
                deficit=d.deficit, rr_turn=d.rr_turn))
            actions = np.asarray(d.actions)
            req_idx = np.asarray(d.req_idx)
            if (actions == IDLE).all():
                # nothing eligible yet: advance to next arrival
                pend = [r for r in requests if r.status == "pending"]
                if not pend:
                    break
                time.sleep(0.005)
                continue
            # drain every grant of the batch in decision order
            for a, i in zip(actions.tolist(), req_idx.tolist()):
                if a == IDLE:
                    continue
                req = requests[i]
                if a == olc.REJECT:
                    req.status = "rejected"
                    state = _set_status(state, i, REJECTED)
                    done += 1
                elif a == olc.DEFER:
                    back = olc.defer_backoff(
                        self.policy, d.severity, state.req.n_defers[i])
                    # backoff starts at apply time, not decision time:
                    # synchronous admits earlier in this batch consumed
                    # real wall clock, and the pacing window must not
                    # silently expire under them
                    cur_ms = (time.monotonic() - t0) * 1e3 * time_scale
                    state = state._replace(req=state.req._replace(
                        defer_until=state.req.defer_until.at[i].set(
                            cur_ms + float(back)),
                        n_defers=state.req.n_defers.at[i].add(1)))
                else:  # admit -> call the black box (synchronous)
                    req.submit_s = time.monotonic() - t0
                    state = _set_status(state, i, INFLIGHT)
                    state = state._replace(provider=state.provider._replace(
                        inflight=state.provider.inflight + 1))
                    req.output = self.provider.submit(req.prompt, req.max_new)
                    req.finish_s = time.monotonic() - t0
                    req.status = "completed"
                    state = _set_status(state, i, COMPLETED)
                    state = state._replace(provider=state.provider._replace(
                        inflight=state.provider.inflight - 1))
                    done += 1
        return requests


def _set_status(state, i, code):
    return state._replace(req=state.req._replace(
        status=state.req.status.at[i].set(code)))
