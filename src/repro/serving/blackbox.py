"""The black-box boundary, made concrete.

`BlackBoxProvider` wraps the real JAX serving engine behind exactly the
API surface the paper assumes the client sees: submit(request) ->
completion with latency; no internals exposed.

The scheduling client itself moved to `repro.client` (DESIGN.md §7):
`ClientSession` is the transport-agnostic streaming API — open-ended
submit/poll/drain over an `AsyncProvider`, windowed O(W) state, several
requests in flight, 429/Retry-After handling — and
`repro.client.blackbox.AsyncBlackBoxProvider` adapts this provider
behind that protocol.

`ScheduledClient` remains as a thin compatibility shim over
`ClientSession` for the old closed-list `run(requests)` call shape.  It
is DEPRECATED: new code should drive a `ClientSession` directly
(examples/serve_blackbox.py shows the ported flow).
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp
import numpy as np

from repro.client import (
    AsyncBlackBoxProvider,
    ClientSession,
    Request,
    SessionConfig,
)
from repro.config import ModelConfig, ServeConfig
from repro.core.policy import PolicyConfig
from repro.serving.engine import generate


class BlackBoxProvider:
    """A real JAX model behind an opaque submit() API."""

    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig):
        self.params, self.cfg, self.sc = params, cfg, sc

    def submit(self, prompt: np.ndarray, max_new: int) -> np.ndarray:
        out = generate(self.params, self.cfg, self.sc,
                       jnp.asarray(prompt)[None], max_new)
        return np.asarray(out[0])


class ScheduledClient:
    """DEPRECATED closed-list shim over `ClientSession`.

    Runs the same three-layer stack (one batched `schedule_batch`
    decision per poll, up to `max_grants` releases) but through the new
    streaming session: the provider is adapted to the async boundary,
    so multiple requests ride in flight and idle waits sleep to the
    next actionable instant instead of spinning.  Use `ClientSession`
    directly for open-ended submission, Retry-After policies, and
    windowed state sizing.
    """

    def __init__(self, provider, policy: PolicyConfig,
                 max_grants: int = 4, max_workers: int = 4):
        warnings.warn(
            "ScheduledClient is deprecated: drive repro.client."
            "ClientSession over an AsyncProvider instead "
            "(see examples/serve_blackbox.py and DESIGN.md §7)",
            DeprecationWarning, stacklevel=2)
        self.provider = provider
        self.policy = policy
        self.max_grants = max_grants
        self.max_workers = max_workers

    def run(self, requests: list[Request],
            time_scale: float = 1.0) -> list[Request]:
        """Executes the full request list; arrival times honored in
        scaled wall clock.  The window is sized to the list so the shim
        never queues behind its own slot pool (the closed-list
        contract); requests are mutated in place like the old client."""
        async_provider = AsyncBlackBoxProvider(
            self.provider, max_workers=self.max_workers)
        session = ClientSession(
            async_provider,
            self.policy,
            SessionConfig(
                window=max(32, len(requests)),
                max_grants=self.max_grants,
                time_scale=time_scale,
            ),
            clock="wall",
        )
        for r in requests:
            session.submit(r)
        try:
            session.drain()
        finally:
            async_provider.shutdown()
        return requests
