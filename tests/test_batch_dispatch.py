"""Tests for the batched top-B dispatch pass (`schedule_batch`) and the
Pallas-fused ordering backend.

Covers the PR's acceptance points:
  (a) B=1 bit-exactness: the batched pass reduces exactly to
      `schedule_slot`, decision-by-decision over a driven state stream,
      and the rewritten engine at k_slots=1 reproduces the sequential
      slot-loop engine state bit-for-bit;
  (b) multi-grant semantics: grants are distinct eligible requests,
      per-class caps and the global max_inflight bind cumulatively
      across the batch, and DRR deficit conservation holds — admits
      charge exactly head_cost, defer/reject round-trip to zero net
      change, multi-grant charges sum over grants;
  (c) the Pallas `sched_score` ordering backend matches the jnp path
      (CPU interpret mode), including FIFO-class emulation and queue
      padding to a block multiple.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drr, ordering, overload as olc
from repro.core.policy import base_policy, kclass_policy, strategy
from repro.core.scheduler import (
    IDLE,
    effective_class,
    schedule_batch,
    schedule_slot,
)
from repro.core.types import (
    INFLIGHT,
    PENDING,
    RequestBatch,
    SimState,
    init_sim_state,
)
from repro.sim import SimConfig, WorkloadConfig, default_physics, generate, run_sim
from repro.sim.provider import service_time_ms


def mk_batch(n=24, seed=0, k=2):
    rng = np.random.default_rng(seed)
    arrival = np.sort(rng.uniform(0, 400.0, n)).astype(np.float32)
    bucket = rng.integers(0, 4, n)
    p50 = (np.float32([60, 150, 600, 2000])[bucket]
           * rng.uniform(0.7, 1.3, n).astype(np.float32))
    if k == 2:
        cls = (bucket != 0).astype(np.int32)
    else:
        cls = rng.integers(0, k, n).astype(np.int32)
    return RequestBatch(
        arrival_ms=jnp.asarray(arrival),
        bucket=jnp.asarray(bucket, jnp.int32),
        cls=jnp.asarray(cls),
        true_tokens=jnp.asarray(p50),
        p50=jnp.asarray(p50),
        p90=jnp.asarray(p50 * 1.8),
        deadline_budget_ms=jnp.full((n,), 5000.0, jnp.float32),
        valid=jnp.ones((n,), bool),
    )


_slot = jax.jit(schedule_slot)
_batch = jax.jit(schedule_batch, static_argnames=("max_grants", "backend"))


# ---------------------------------------------------------------------------
# (a) B=1 bit-exactness with the single-slot path
# ---------------------------------------------------------------------------

class TestB1BitExact:
    @pytest.mark.parametrize("name", [
        "final_adrr_olc", "adaptive_drr", "fair_queuing", "short_priority",
        "quota_tiered", "direct_naive",
    ])
    def test_decision_stream_matches_schedule_slot(self, name):
        """Drive 40 engine-style steps; every SlotDecision field must be
        bit-identical to row 0 of the max_grants=1 BatchDecision."""
        cfg = strategy(name)
        batch = mk_batch()
        state = init_sim_state(batch.n)._replace(
            now_ms=jnp.float32(50.0),
            sched=init_sim_state(batch.n).sched._replace(
                ema_latency_ratio=jnp.float32(2.5)),
        )
        live = 0
        for step in range(40):
            d = _slot(cfg, batch, state)
            b = _batch(cfg, batch, state, max_grants=1)
            assert b.actions.shape == (1,)
            assert int(d.action) == int(b.actions[0]), f"step {step}"
            if int(d.action) != IDLE:
                live += 1
                assert int(d.req_idx) == int(b.req_idx[0]), f"step {step}"
            assert np.array_equal(np.asarray(d.deficit), np.asarray(b.deficit))
            assert int(d.rr_turn) == int(b.rr_turn)
            assert float(d.severity) == float(b.severity)
            assert int(b.inflight_at[0]) == int(state.provider.inflight)

            state = state._replace(
                sched=state.sched._replace(deficit=d.deficit, rr_turn=d.rr_turn))
            if int(d.action) == olc.ADMIT:
                i = int(d.req_idx)
                state = state._replace(
                    req=state.req._replace(
                        status=state.req.status.at[i].set(INFLIGHT)),
                    provider=state.provider._replace(
                        inflight=state.provider.inflight + 1))
            elif int(d.action) == olc.DEFER:
                i = int(d.req_idx)
                state = state._replace(req=state.req._replace(
                    defer_until=state.req.defer_until.at[i].set(
                        state.now_ms + 100.0),
                    n_defers=state.req.n_defers.at[i].add(1)))
            if step % 8 == 7:
                state = state._replace(
                    req=state.req._replace(status=jnp.where(
                        state.req.status == INFLIGHT, 2, state.req.status)),
                    provider=state.provider._replace(inflight=jnp.int32(0)))
            state = state._replace(now_ms=state.now_ms + jnp.float32(25.0))
        if name != "direct_naive":
            assert live > 5

    @pytest.mark.slow
    def test_engine_k_slots_1_matches_sequential_reference(self):
        """Full-horizon engine equivalence: the batched tick at
        k_slots=1 equals the former sequential `_dispatch_one` loop,
        replayed here verbatim over `schedule_slot`."""
        from repro.sim.engine import _complete_and_timeout

        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=48, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(3), wl)
        phys = default_physics()
        sim_cfg = SimConfig(n_ticks=1200, k_slots=1)

        def dispatch_one(state: SimState) -> SimState:
            # verbatim port of the seed engine's per-slot transition
            d = schedule_slot(policy, batch, state)
            i = d.req_idx
            req = state.req
            onehot = jnp.arange(batch.n) == i
            admit = d.action == olc.ADMIT
            defer = d.action == olc.DEFER
            reject = d.action == olc.REJECT
            service = service_time_ms(
                phys, batch.true_tokens[i], state.provider.inflight, jitter[i])
            finish = state.now_ms + service
            backoff = olc.defer_backoff(policy, d.severity, req.n_defers[i])
            status = jnp.where(
                onehot & admit, INFLIGHT,
                jnp.where(onehot & reject, 3, req.status))
            submit = jnp.where(onehot & admit, state.now_ms, req.submit_ms)
            finish_ms = jnp.where(onehot & admit, finish, req.finish_ms)
            defer_until = jnp.where(
                onehot & defer, state.now_ms + backoff, req.defer_until)
            n_defers = req.n_defers + (onehot & defer).astype(jnp.int32)
            inflight = state.provider.inflight + admit.astype(jnp.int32)
            inflight_tokens = state.provider.inflight_tokens + jnp.where(
                admit, batch.p50[i], 0.0)
            noop = d.action == IDLE
            new_req = jax.tree.map(
                lambda new, old: jnp.where(noop, old, new),
                req._replace(status=status, submit_ms=submit,
                             finish_ms=finish_ms, defer_until=defer_until,
                             n_defers=n_defers),
                req)
            return state._replace(
                req=new_req,
                sched=state.sched._replace(deficit=d.deficit, rr_turn=d.rr_turn),
                provider=state.provider._replace(
                    inflight=jnp.where(noop, state.provider.inflight, inflight),
                    inflight_tokens=jnp.where(
                        noop, state.provider.inflight_tokens, inflight_tokens)))

        @jax.jit
        def reference_sim():
            state0 = init_sim_state(batch.n, 2)

            def tick(state, t_idx):
                now = (t_idx + 1).astype(jnp.float32) * sim_cfg.dt_ms
                state = state._replace(now_ms=now)
                state = _complete_and_timeout(policy, phys, batch, state)
                return dispatch_one(state), None

            final, _ = jax.lax.scan(tick, state0, jnp.arange(sim_cfg.n_ticks))
            final = final._replace(now_ms=final.now_ms + 1e9)
            return _complete_and_timeout(policy, phys, batch, final)

        ref = reference_sim()
        got = run_sim(policy, batch, jitter, phys, sim_cfg)
        for field in ("status", "submit_ms", "finish_ms", "defer_until",
                      "n_defers"):
            assert np.array_equal(
                np.asarray(getattr(got.req, field)),
                np.asarray(getattr(ref.req, field))), field
        assert np.array_equal(np.asarray(got.sched.deficit),
                              np.asarray(ref.sched.deficit))
        assert int(got.sched.rr_turn) == int(ref.sched.rr_turn)
        # the run actually scheduled work
        assert int((np.asarray(got.req.status) == 2).sum()) > 10


# ---------------------------------------------------------------------------
# (b) multi-grant semantics
# ---------------------------------------------------------------------------

class TestMultiGrant:
    def _ready_state(self, batch, k=2, deficit=None):
        st = init_sim_state(batch.n, k)._replace(now_ms=jnp.float32(1e6))
        if deficit is not None:
            st = st._replace(sched=st.sched._replace(
                deficit=jnp.asarray(deficit, jnp.float32)))
        return st

    def test_grants_distinct_eligible_and_bounded(self):
        cfg = kclass_policy(4)
        batch = mk_batch(64, seed=5, k=4)
        state = self._ready_state(batch, 4)
        d = _batch(cfg, batch, state, max_grants=8)
        acts = np.asarray(d.actions)
        idxs = np.asarray(d.req_idx)
        live = idxs[acts != IDLE]
        assert acts.shape == (8,)
        assert len(set(live.tolist())) == len(live)  # no double grants
        assert np.asarray(batch.valid)[live].all()
        assert (np.asarray(batch.arrival_ms)[live] <= 1e6).all()

    def test_global_max_inflight_binds_cumulatively(self):
        cfg = kclass_policy(2, max_inflight=jnp.float32(3.0))
        batch = mk_batch(64, seed=6)
        state = self._ready_state(batch)
        d = _batch(cfg, batch, state, max_grants=16)
        admits = int((np.asarray(d.actions) == olc.ADMIT).sum())
        assert admits == 3  # plenty eligible; cap must stop the batch

    def test_class_cap_binds_cumulatively(self):
        cfg = kclass_policy(
            2, caps=[2.0, 2.0], olc_enabled=jnp.float32(0.0))
        batch = mk_batch(64, seed=7)
        state = self._ready_state(batch)
        d = _batch(cfg, batch, state, max_grants=16)
        acts, idxs = np.asarray(d.actions), np.asarray(d.req_idx)
        cls = np.asarray(effective_class(cfg, batch))
        admitted_cls = cls[idxs[acts == olc.ADMIT]]
        for c in range(2):
            assert (admitted_cls == c).sum() <= 2

    def test_deficit_multi_grant_charges_sum(self):
        """With zero quantum and overload off, the net deficit change of
        a batch is exactly the (sequentially accumulated) sum of the
        admitted head costs."""
        k, n = 2, 64
        cfg = kclass_policy(
            k,
            drr_quantum=jnp.float32(0.0),
            olc_enabled=jnp.float32(0.0),
            deficit_cap=jnp.float32(8000.0),
        )
        batch = mk_batch(n, seed=8)
        init = [8000.0, 8000.0]
        state = self._ready_state(batch, k, deficit=init)
        B = 6
        d = _batch(cfg, batch, state, max_grants=B)
        acts, idxs = np.asarray(d.actions), np.asarray(d.req_idx)
        assert (acts == olc.ADMIT).sum() >= 2  # both lanes afford work
        cls = np.asarray(effective_class(cfg, batch))
        expect = np.float32(init).copy()
        for a, i in zip(acts, idxs):
            if a == olc.ADMIT:
                expect[cls[i]] -= np.float32(batch.p50[i])
        np.testing.assert_allclose(
            np.asarray(d.deficit), expect, rtol=0, atol=1e-3)

    @pytest.mark.parametrize("reject", [False, True])
    def test_deficit_defer_reject_round_trips_to_zero(self, reject):
        """A blocked release must leave the deficit vector untouched
        (charge + refund cancel exactly) — across all B grants."""
        k, n = 2, 48
        thr, rej = (10.0, 0.01) if reject else (0.01, 10.0)
        cfg = kclass_policy(
            k,
            drr_quantum=jnp.float32(0.0),
            deficit_cap=jnp.float32(8000.0),
            defer_thr=jnp.asarray([jnp.inf, thr, thr, thr], jnp.float32),
            reject_thr=jnp.asarray([jnp.inf, rej, rej, rej], jnp.float32),
        )
        rng = np.random.default_rng(9)
        bucket = rng.integers(1, 4, n)  # no shorts: every grant blocks
        batch = mk_batch(n, seed=9)._replace(
            bucket=jnp.asarray(bucket, jnp.int32),
            cls=jnp.asarray(rng.integers(0, k, n), jnp.int32))
        init = [8000.0, 8000.0]
        state = self._ready_state(batch, k, deficit=init)
        state = state._replace(sched=state.sched._replace(
            ema_latency_ratio=jnp.float32(3.0)))
        d = _batch(cfg, batch, state, max_grants=6)
        want = olc.REJECT if reject else olc.DEFER
        acts = np.asarray(d.actions)
        assert (acts == want).sum() >= 2
        np.testing.assert_allclose(
            np.asarray(d.deficit), np.float32(init), rtol=0, atol=0)

    def test_blocked_candidate_leaves_feasible_set_for_batch(self):
        """A deferred candidate must not be re-granted later in the same
        batch (it left the feasible set exactly as its backoff would
        remove it)."""
        k, n = 2, 48
        cfg = kclass_policy(
            k,
            defer_thr=jnp.asarray([jnp.inf, 0.01, 0.01, 0.01], jnp.float32),
            reject_thr=jnp.asarray([jnp.inf] * 4, jnp.float32),
        )
        rng = np.random.default_rng(10)
        batch = mk_batch(n, seed=10)._replace(
            bucket=jnp.asarray(rng.integers(1, 4, n), jnp.int32))
        state = self._ready_state(batch, k)
        state = state._replace(sched=state.sched._replace(
            ema_latency_ratio=jnp.float32(3.0)))
        d = _batch(cfg, batch, state, max_grants=8)
        acts, idxs = np.asarray(d.actions), np.asarray(d.req_idx)
        live = idxs[acts != IDLE]
        assert (acts[acts != IDLE] == olc.DEFER).all()
        assert len(set(live.tolist())) == len(live)

    @pytest.mark.slow
    def test_engine_b4_terminates_and_conserves(self):
        """Full sim at k_slots=4 (one batched pass per tick): every
        request reaches a terminal state."""
        wl = WorkloadConfig(n_requests=48, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(4), wl)
        final = run_sim(strategy("final_adrr_olc"), batch, jitter,
                        default_physics(), SimConfig(n_ticks=1500, k_slots=4))
        s = np.asarray(final.req.status)
        assert ((s == 2) | (s == 3) | (s == 4)).all()
        assert int(final.provider.inflight) == 0


# ---------------------------------------------------------------------------
# rr_turn stays in range across long FQ runs (satellite bugfix)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestRrTurnRange:
    @pytest.mark.parametrize("k", [2, 3, 8])
    def test_allocate_pointer_wraps(self, k):
        cfg = kclass_policy(k, alloc_mode=jnp.asarray(3, jnp.int32))
        rng = np.random.default_rng(0)
        turn = jnp.int32(0)
        deficit = jnp.zeros((k,), jnp.float32)
        for step in range(6 * k):
            backlog = jnp.asarray(rng.integers(0, 3, k), jnp.int32)
            c = drr.allocate(
                cfg,
                backlog=backlog,
                head_cost=jnp.full((k,), 100.0, jnp.float32),
                inflight_cls=jnp.zeros((k,), jnp.int32),
                inflight_total=jnp.int32(0),
                severity=jnp.float32(0.0),
                deficit=deficit,
                rr_turn=turn,
            )
            turn = c.rr_turn
            assert 0 <= int(turn) < k, f"step {step}: rr_turn={int(turn)}"

    def test_fq_engine_run_keeps_pointer_in_range(self):
        wl = WorkloadConfig(n_requests=48, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(5), wl)
        final = run_sim(strategy("fair_queuing"), batch, jitter,
                        default_physics(), SimConfig(n_ticks=2000, k_slots=4))
        assert 0 <= int(final.sched.rr_turn) < 2

    def test_fq_rotation_visits_all_classes(self):
        """Long driven FQ run at K=3: the pointer cycles through every
        class instead of sticking past K."""
        k = 3
        cfg = kclass_policy(k, alloc_mode=jnp.asarray(3, jnp.int32),
                            olc_enabled=jnp.float32(0.0))
        batch = mk_batch(60, seed=11, k=k)
        state = init_sim_state(batch.n, k)._replace(now_ms=jnp.float32(1e6))
        seen = set()
        for _ in range(30):
            d = _batch(cfg, batch, state, max_grants=1)
            assert 0 <= int(d.rr_turn) < k
            if int(d.actions[0]) == olc.ADMIT:
                seen.add(int(np.asarray(effective_class(cfg, batch))[
                    int(d.req_idx[0])]))
            state = state._replace(sched=state.sched._replace(
                deficit=d.deficit, rr_turn=d.rr_turn))
            # release provider slots so the rotation keeps granting
            state = state._replace(req=state.req._replace(status=jnp.where(
                state.req.status == INFLIGHT, PENDING, state.req.status)))
            i = int(d.req_idx[0])
            if int(d.actions[0]) == olc.ADMIT:
                state = state._replace(req=state.req._replace(
                    status=state.req.status.at[i].set(2)))
        assert seen == {0, 1, 2}


# ---------------------------------------------------------------------------
# (c) Pallas ordering backend parity (CPU interpret mode)
# ---------------------------------------------------------------------------

class TestPallasOrderingParity:
    def _mask_and_state(self, cfg, batch, seed=0):
        k = cfg.drr_weights.shape[0]
        state = init_sim_state(batch.n, k)._replace(now_ms=jnp.float32(1e5))
        elig = ordering.eligibility(
            batch, state.req.status, state.req.defer_until, state.now_ms)
        eff = effective_class(cfg, batch)
        kn = (eff[None, :] == jnp.arange(k)[:, None]) & elig[None, :]
        return kn, state

    @pytest.mark.parametrize("n", [64, 700])  # 700 exercises block padding
    def test_select_per_class_backends_agree(self, n):
        cfg = base_policy()
        batch = mk_batch(n, seed=1)
        kn, state = self._mask_and_state(cfg, batch)
        i_j, ok_j = ordering.select_per_class(
            batch, kn, state.now_ms, cfg, backend="jnp")
        i_p, ok_p = ordering.select_per_class(
            batch, kn, state.now_ms, cfg, backend="pallas")
        assert np.array_equal(np.asarray(ok_j), np.asarray(ok_p))
        ok = np.asarray(ok_j)
        assert np.array_equal(np.asarray(i_j)[ok], np.asarray(i_p)[ok])

    def test_select_top_b_backends_agree(self):
        cfg = base_policy()
        batch = mk_batch(96, seed=2)
        kn, state = self._mask_and_state(cfg, batch)
        b = 4
        i_j, n_j = ordering.select_top_b(
            batch, kn, state.now_ms, cfg, b, backend="jnp")
        i_p, n_p = ordering.select_top_b(
            batch, kn, state.now_ms, cfg, b, backend="pallas")
        assert np.array_equal(np.asarray(n_j), np.asarray(n_p))
        for c in range(2):
            valid = min(int(n_j[c]), b)
            assert np.array_equal(
                np.asarray(i_j)[c, :valid], np.asarray(i_p)[c, :valid]), c

    def test_schedule_batch_pallas_backend_matches_jnp(self):
        cfg = base_policy()
        batch = mk_batch(64, seed=3)
        state = init_sim_state(batch.n, 2)._replace(
            now_ms=jnp.float32(1e5),
            sched=init_sim_state(batch.n, 2).sched._replace(
                ema_latency_ratio=jnp.float32(2.0)))
        d_j = _batch(cfg, batch, state, max_grants=4, backend="jnp")
        d_p = _batch(cfg, batch, state, max_grants=4, backend="pallas")
        assert np.array_equal(np.asarray(d_j.actions), np.asarray(d_p.actions))
        live = np.asarray(d_j.actions) != IDLE
        assert np.array_equal(
            np.asarray(d_j.req_idx)[live], np.asarray(d_p.req_idx)[live])
        assert np.array_equal(np.asarray(d_j.deficit), np.asarray(d_p.deficit))

    def test_fifo_parity_at_large_now_with_close_arrivals(self):
        """FIFO emulation keys on -arrival_ms, not now - arrival: at
        large now_ms a f32 wait would quantize sub-ms arrival gaps into
        ties and break backend parity."""
        n = 64
        rng = np.random.default_rng(4)
        arrival = np.cumsum(rng.uniform(0.1, 0.9, n)).astype(np.float32)
        order = rng.permutation(n)  # not pre-sorted by arrival
        batch = mk_batch(n, seed=4)._replace(
            arrival_ms=jnp.asarray(arrival[order]))
        cfg = base_policy()
        kn = jnp.stack([batch.bucket == 0, batch.bucket != 0])
        now = jnp.float32(1e7)
        i_j, ok_j = ordering.select_per_class(batch, kn, now, cfg, backend="jnp")
        i_p, ok_p = ordering.select_per_class(
            batch, kn, now, cfg, backend="pallas")
        assert bool(ok_j[0]) and bool(ok_p[0])
        assert int(i_j[0]) == int(i_p[0])  # the FIFO (short) lane

    def test_ops_padding_matches_ref(self):
        """N not a block multiple: the ops wrapper pads with mask=False
        and the fused result still matches the unpadded oracle."""
        from repro.kernels.sched_score.ops import sched_score_argmax
        from repro.kernels.sched_score.ref import sched_score_argmax_ref

        n = 700  # blk=512 -> 324 padding lanes
        ks = jax.random.split(jax.random.PRNGKey(7), 4)
        wait = jax.random.uniform(ks[0], (n,)) * 1e4
        cost = jax.random.uniform(ks[1], (n,)) * 4000 + 16
        urg = jax.random.uniform(ks[2], (n,)) * 2
        mask = jax.random.bernoulli(ks[3], 0.5, (n,))
        w = jnp.asarray([1.0, 0.6, 0.8, 512.0])
        i1, s1 = sched_score_argmax(wait, cost, urg, mask, w, blk=512)
        i2, s2 = sched_score_argmax_ref(wait, cost, urg, mask, w)
        assert int(i1) == int(i2)
        assert float(s1) == pytest.approx(float(s2), rel=1e-5)

    def test_unknown_backend_raises(self):
        cfg = base_policy()
        batch = mk_batch(8)
        kn, state = self._mask_and_state(cfg, batch)
        with pytest.raises(ValueError, match="backend"):
            ordering.select_per_class(
                batch, kn, state.now_ms, cfg, backend="cuda")


# ---------------------------------------------------------------------------
# Refund mode-gating (satellite bugfix): non-ADRR modes never charged,
# so a blocked release must not credit their deficit vector.
# ---------------------------------------------------------------------------

class TestRefundModeGated:
    @pytest.mark.parametrize("mode", [1, 3, 4])  # quota, fq, sp
    def test_blocked_release_leaves_non_adrr_deficit_untouched(self, mode):
        cfg = kclass_policy(
            2,
            alloc_mode=jnp.asarray(mode, jnp.int32),
            defer_thr=jnp.asarray([jnp.inf, 0.01, 0.01, 0.01], jnp.float32),
            reject_thr=jnp.asarray([jnp.inf] * 4, jnp.float32),
        )
        rng = np.random.default_rng(12)
        batch = mk_batch(32, seed=12)._replace(
            bucket=jnp.asarray(rng.integers(1, 4, 32), jnp.int32))
        init = jnp.asarray([123.0, 456.0], jnp.float32)
        state = init_sim_state(batch.n, 2)._replace(
            now_ms=jnp.float32(1e6),
            sched=init_sim_state(batch.n, 2).sched._replace(
                deficit=init, ema_latency_ratio=jnp.float32(3.0)))
        d = _slot(cfg, batch, state)
        assert int(d.action) == olc.DEFER  # the release was blocked
        np.testing.assert_array_equal(np.asarray(d.deficit), np.asarray(init))
