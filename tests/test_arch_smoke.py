"""Per-assigned-architecture smoke tests (assignment requirement):
instantiate the REDUCED same-family variant, run one forward and one
train step on CPU, assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import ARCHS, get, get_smoke
from repro.models import forward_train, init_model
from repro.training import train_step as ts_mod


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = init_model(jax.random.PRNGKey(0), cfg)

    B, S, P = 2, 32, cfg.prefix_len or 0
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    pe = (jnp.zeros((B, P, cfg.d_model), jnp.bfloat16) if P else None)

    # forward
    logits, aux = forward_train(model.params, cfg, toks, pe, remat=False)
    assert logits.shape == (B, S + P, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one train step
    tc = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = ts_mod.init_train_state(model, tc)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if pe is not None:
        batch["prefix_embeds"] = pe
    state, metrics = ts_mod.train_step(state, batch, cfg, tc)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda p1, p2: bool(jnp.any(p1 != p2)),
                     model.params, state.params))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The exact (non-smoke) configs carry the assigned hyperparameters."""
    spec = {
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    cfg = get(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
            cfg.vocab) == spec
    if arch == "mamba2-780m":
        assert cfg.ssm.d_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm.d_state == 16
    if arch == "arctic-480b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 2
        assert cfg.moe.dense_residual
    if arch == "phi3.5-moe-42b-a6.6b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
