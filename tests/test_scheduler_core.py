"""Unit + property tests for the three scheduler layers (repro.core)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import drr, ordering, overload
from repro.core.policy import (
    base_policy,
    strategy,
    with_bucket_policy,
    with_information,
)
from repro.core.scheduler import IDLE, schedule_slot
from repro.core.types import (
    RequestBatch,
    SHORT,
    XLONG,
    init_sim_state,
)


def mk_batch(n=8, arrival=None, bucket=None, p50=None):
    arrival = jnp.asarray(arrival if arrival is not None else np.arange(n) * 10.0, jnp.float32)
    bucket = jnp.asarray(bucket if bucket is not None else np.zeros(n), jnp.int32)
    p50 = jnp.asarray(p50 if p50 is not None else np.full(n, 100.0), jnp.float32)
    cls = jnp.where(bucket == SHORT, 0, 1).astype(jnp.int32)
    return RequestBatch(
        arrival_ms=arrival,
        bucket=bucket,
        cls=cls,
        true_tokens=p50,
        p50=p50,
        p90=p50 * 1.8,
        deadline_budget_ms=jnp.full((n,), 5000.0, jnp.float32),
        valid=jnp.ones((n,), bool),
    )


# ---------------------------------------------------------------------------
# Layer 2: ordering
# ---------------------------------------------------------------------------

class TestOrdering:
    def test_fifo_picks_earliest(self):
        b = mk_batch(4, arrival=[30.0, 10.0, 20.0, 40.0])
        idx, ok = ordering.select_fifo(b, jnp.ones(4, bool))
        assert bool(ok) and int(idx) == 1

    def test_fifo_respects_mask(self):
        b = mk_batch(4, arrival=[30.0, 10.0, 20.0, 40.0])
        idx, ok = ordering.select_fifo(b, jnp.asarray([True, False, False, True]))
        assert bool(ok) and int(idx) == 0

    def test_empty_mask_not_ok(self):
        b = mk_batch(4)
        _, ok = ordering.select_fifo(b, jnp.zeros(4, bool))
        assert not bool(ok)

    def test_score_prefers_older_and_smaller(self):
        cfg = base_policy()
        # two heavy jobs, same arrival: smaller wins
        b = mk_batch(2, arrival=[0.0, 0.0], bucket=[2, 2], p50=[2000.0, 300.0])
        idx, ok = ordering.select_scored(b, jnp.ones(2, bool), jnp.float32(1000.0), cfg)
        assert bool(ok) and int(idx) == 1
        # same size, older wins
        b = mk_batch(2, arrival=[0.0, 900.0], bucket=[2, 2], p50=[300.0, 300.0])
        idx, _ = ordering.select_scored(b, jnp.ones(2, bool), jnp.float32(1000.0), cfg)
        assert int(idx) == 0

    def test_urgency_overrides_size(self):
        cfg = base_policy(ord_w_urg=jnp.float32(50.0))
        b = mk_batch(2, arrival=[0.0, 0.0], bucket=[2, 2], p50=[2000.0, 300.0])
        # request 0 about to blow its deadline
        b = b._replace(deadline_budget_ms=jnp.asarray([1000.0, 99000.0], jnp.float32))
        idx, _ = ordering.select_scored(b, jnp.ones(2, bool), jnp.float32(990.0), cfg)
        assert int(idx) == 0

    def test_eligibility_excludes_future_and_deferred(self):
        b = mk_batch(3, arrival=[0.0, 100.0, 0.0])
        status = jnp.zeros(3, jnp.int32)
        defer_until = jnp.asarray([0.0, 0.0, 500.0], jnp.float32)
        el = ordering.eligibility(b, status, defer_until, jnp.float32(50.0))
        assert el.tolist() == [True, False, False]


# ---------------------------------------------------------------------------
# Layer 3: overload
# ---------------------------------------------------------------------------

class TestOverload:
    def test_severity_zero_when_idle(self):
        cfg = base_policy()
        s = overload.severity_score(
            cfg, inflight_total=0, n_pending=0, ema_latency_ratio=jnp.float32(1.0))
        assert float(s) == pytest.approx(0.0, abs=1e-5)

    def test_short_never_rejected_under_ladder(self):
        cfg = base_policy()
        for sev in [0.0, 0.5, 0.9, 5.0]:
            a = overload.admission_action(
                cfg, severity=jnp.float32(sev), bucket=jnp.int32(SHORT),
                n_defers=jnp.int32(0))
            assert int(a) == overload.ADMIT

    def test_ladder_progression_xlong(self):
        cfg = base_policy()
        acts = [
            int(overload.admission_action(
                cfg, severity=jnp.float32(s), bucket=jnp.int32(XLONG),
                n_defers=jnp.int32(0)))
            for s in [0.2, 0.5, 0.7]
        ]
        assert acts == [overload.ADMIT, overload.DEFER, overload.REJECT]

    def test_long_rejected_later_than_xlong(self):
        cfg = base_policy()
        a_long = int(overload.admission_action(
            cfg, severity=jnp.float32(0.7), bucket=jnp.int32(2), n_defers=jnp.int32(0)))
        assert a_long == overload.DEFER  # long defers where xlong rejects

    def test_disabled_olc_always_admits(self):
        cfg = base_policy(olc_enabled=jnp.float32(0.0))
        a = overload.admission_action(
            cfg, severity=jnp.float32(9.0), bucket=jnp.int32(XLONG), n_defers=jnp.int32(0))
        assert int(a) == overload.ADMIT

    def test_defer_exhaustion_admits(self):
        cfg = base_policy()
        a = overload.admission_action(
            cfg, severity=jnp.float32(0.5), bucket=jnp.int32(XLONG),
            n_defers=jnp.int32(99))
        assert int(a) == overload.ADMIT

    @given(sev=st.floats(0, 3), bucket=st.integers(0, 3), nd=st.integers(0, 5))
    @settings(max_examples=60, deadline=None)
    def test_property_monotone_in_severity(self, sev, bucket, nd):
        """Raising severity never produces a milder action."""
        cfg = base_policy()
        a1 = int(overload.admission_action(
            cfg, severity=jnp.float32(sev), bucket=jnp.int32(bucket), n_defers=jnp.int32(nd)))
        a2 = int(overload.admission_action(
            cfg, severity=jnp.float32(sev + 0.3), bucket=jnp.int32(bucket), n_defers=jnp.int32(nd)))
        order = {overload.ADMIT: 0, overload.DEFER: 1, overload.REJECT: 2}
        # exhausted defers collapse DEFER->ADMIT; treat that as equivalent
        if nd < 2:
            assert order[a2] >= order[a1]

    @given(sev=st.floats(0, 3))
    @settings(max_examples=40, deadline=None)
    def test_property_every_shape_spares_short(self, sev):
        for shape in ["ladder", "uniform_mild", "uniform_harsh", "reverse"]:
            cfg = with_bucket_policy(base_policy(), shape)
            a = int(overload.admission_action(
                cfg, severity=jnp.float32(sev), bucket=jnp.int32(SHORT), n_defers=jnp.int32(0)))
            assert a == overload.ADMIT


# ---------------------------------------------------------------------------
# Layer 1: allocation
# ---------------------------------------------------------------------------

def alloc_args(**kw):
    d = dict(
        backlog=jnp.asarray([1, 1], jnp.int32),
        head_cost=jnp.asarray([50.0, 500.0], jnp.float32),
        inflight_cls=jnp.asarray([0, 0], jnp.int32),
        inflight_total=jnp.int32(0),
        severity=jnp.float32(0.0),
        deficit=jnp.asarray([1000.0, 1000.0], jnp.float32),
        rr_turn=jnp.int32(0),
    )
    d.update(kw)
    return d


class TestAllocation:
    def test_adrr_work_conserving(self):
        """An empty interactive class never blocks heavy dispatch."""
        cfg = strategy("adaptive_drr")
        c = drr.allocate(cfg, **alloc_args(backlog=jnp.asarray([0, 1], jnp.int32)))
        assert bool(c.send_ok) and int(c.cls_id) == 1

    def test_adrr_insufficient_deficit_blocks(self):
        cfg = strategy("adaptive_drr")
        c = drr.allocate(cfg, **alloc_args(
            backlog=jnp.asarray([0, 1], jnp.int32),
            head_cost=jnp.asarray([jnp.inf, 1e9], jnp.float32),
            deficit=jnp.asarray([0.0, 0.0], jnp.float32)))
        assert not bool(c.send_ok)
        # ... but deficit accrued for the backlogged class
        assert float(c.deficit[1]) > 0

    def test_adrr_deficit_charged_on_send(self):
        cfg = strategy("adaptive_drr")
        c = drr.allocate(cfg, **alloc_args(backlog=jnp.asarray([0, 1], jnp.int32)))
        assert bool(c.send_ok)
        assert float(c.deficit[1]) < 1000.0 + float(cfg.drr_quantum) * 2

    def test_adrr_heavy_cap_blocks_heavy_only(self):
        cfg = strategy("adaptive_drr")
        c = drr.allocate(cfg, **alloc_args(
            inflight_cls=jnp.asarray([0, 99], jnp.int32)))
        assert bool(c.send_ok) and int(c.cls_id) == 0

    def test_severity_biases_interactive(self):
        cfg = strategy("adaptive_drr")
        w0 = drr.effective_weights(cfg, jnp.float32(0.0))
        w1 = drr.effective_weights(cfg, jnp.float32(1.0))
        assert float(w1[0] / w1[1]) > float(w0[0] / w0[1])

    def test_quota_strands_heavy_beyond_quota(self):
        cfg = strategy("quota_tiered")
        # heavy inflight at its quota (class_cap[1] = 3) => no send
        c = drr.allocate(cfg, **alloc_args(
            backlog=jnp.asarray([0, 5], jnp.int32),
            inflight_cls=jnp.asarray([0, 3], jnp.int32)))
        assert not bool(c.send_ok)

    def test_fq_alternates(self):
        cfg = strategy("fair_queuing")
        c0 = drr.allocate(cfg, **alloc_args())
        c1 = drr.allocate(cfg, **alloc_args(rr_turn=c0.rr_turn))
        assert int(c0.cls_id) != int(c1.cls_id)

    def test_sp_prefers_short(self):
        cfg = strategy("short_priority")
        c = drr.allocate(cfg, **alloc_args())
        assert int(c.cls_id) == 0

    def test_naive_ignores_class(self):
        cfg = strategy("direct_naive")
        c = drr.allocate(cfg, **alloc_args())
        assert bool(c.ignore_class) and bool(c.send_ok)

    # the slow mark sits *above* @given: the hypothesis fallback shim's
    # wrapper does not propagate pytestmark from the wrapped function
    @pytest.mark.slow
    @given(
        b0=st.integers(0, 3), b1=st.integers(0, 3),
        sev=st.floats(0, 1.5), d0=st.floats(0, 3000), d1=st.floats(0, 3000),
    )
    @settings(max_examples=60, deadline=None)
    def test_property_send_implies_backlog(self, b0, b1, sev, d0, d1):
        """Whatever the mode, a selected class must actually have work."""
        for name in ["adaptive_drr", "fair_queuing", "short_priority", "quota_tiered"]:
            cfg = strategy(name)
            c = drr.allocate(cfg, **alloc_args(
                backlog=jnp.asarray([b0, b1], jnp.int32),
                severity=jnp.float32(sev),
                deficit=jnp.asarray([d0, d1], jnp.float32)))
            if bool(c.send_ok):
                assert [b0, b1][int(c.cls_id)] > 0


# ---------------------------------------------------------------------------
# Fused slot (layers composed)
# ---------------------------------------------------------------------------

class TestScheduleSlot:
    def test_slot_selects_feasible_request(self):
        """Paper: zero violations of the ordering layer's feasibility
        constraints — the released request is always arrived+pending."""
        cfg = strategy("final_adrr_olc")
        b = mk_batch(6, arrival=[0, 0, 50, 5000, 0, 0],
                     bucket=[0, 2, 0, 0, 3, 1])
        st0 = init_sim_state(6)._replace(now_ms=jnp.float32(100.0))
        d = schedule_slot(cfg, b, st0)
        assert int(d.action) != IDLE
        i = int(d.req_idx)
        assert float(b.arrival_ms[i]) <= 100.0

    def test_idle_when_nothing_eligible(self):
        cfg = strategy("final_adrr_olc")
        b = mk_batch(3, arrival=[1000.0, 2000.0, 3000.0])
        st0 = init_sim_state(3)._replace(now_ms=jnp.float32(10.0))
        d = schedule_slot(cfg, b, st0)
        assert int(d.action) == IDLE

    def test_no_info_single_lane(self):
        cfg = with_information(strategy("final_adrr_olc"), "no_info")
        b = mk_batch(4, bucket=[0, 3, 2, 1])
        from repro.core.scheduler import effective_class
        assert effective_class(cfg, b).tolist() == [0, 0, 0, 0]

    def test_jit_and_vmap_compile(self):
        cfg = strategy("final_adrr_olc")
        b = mk_batch(8)
        st0 = init_sim_state(8)._replace(now_ms=jnp.float32(100.0))
        d = jax.jit(schedule_slot)(cfg, b, st0)
        assert d.action.shape == ()
