"""reprolint fixture tests: each rule demonstrates a catch and a clean
pass on a minimal reproducer, plus suppression syntax, manifest loading,
and the CLI contract `make ci` relies on (nonzero exit on a violation,
zero on the real tree).

These tests never import jax — the analysis package is stdlib-only by
design, and that property is itself asserted here.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis.manifest import load_manifest, manifest_for_tests
from repro.analysis.registry import Project
from repro.analysis.walker import SourceFile
import repro.analysis.rules  # noqa: F401  (registers the rules)

REPO_ROOT = Path(__file__).resolve().parents[1]
SRC_DIR = REPO_ROOT / "src"


def _project(tmp_path, files, **manifest_overrides):
    sfs = []
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text), encoding="utf-8")
        if rel.endswith(".py"):
            sfs.append(SourceFile(p, rel))
    return Project(root=tmp_path, files=sfs,
                   manifest=manifest_for_tests(**manifest_overrides))


def _findings(project, rule_id):
    return [f for f in project.run(only={rule_id}) if not f.suppressed]


# ---------------------------------------------------------------------------
# RPL001 — pinned-float discipline
# ---------------------------------------------------------------------------

_RPL001_MANIFEST = dict(
    critical_modules=["core/engine.py"],
    sensitive_names=["sev", "scores", "score", "ema"],
)


class TestRPL001:
    def test_catch_bare_reduction(self, tmp_path):
        p = _project(tmp_path, {"core/engine.py": """\
            import jax.numpy as jnp

            def overload(scores):
                sev = jnp.sum(scores)
                return sev
            """}, **_RPL001_MANIFEST)
        fs = _findings(p, "RPL001")
        assert len(fs) == 1 and fs[0].line == 4

    def test_catch_method_reduction_and_fma(self, tmp_path):
        p = _project(tmp_path, {"core/engine.py": """\
            def update(ema, x, alpha):
                ema = ema.mean()
                score = alpha * x + ema
                return score
            """}, **_RPL001_MANIFEST)
        lines = {f.line for f in _findings(p, "RPL001")}
        assert lines == {2, 3}

    def test_clean_inside_pinned(self, tmp_path):
        p = _project(tmp_path, {"core/engine.py": """\
            import jax.numpy as jnp
            from repro.core.numerics import pinned

            def overload(scores, alpha, x, ema):
                sev = pinned(jnp.sum(scores))
                score = pinned(alpha * x + ema)
                return sev + score
            """}, **_RPL001_MANIFEST)
        assert _findings(p, "RPL001") == []

    def test_clean_outside_critical_module(self, tmp_path):
        p = _project(tmp_path, {"sim/other.py": """\
            import jax.numpy as jnp

            def overload(scores):
                return jnp.sum(scores)
            """}, **_RPL001_MANIFEST)
        assert _findings(p, "RPL001") == []

    def test_insensitive_counting_sum_is_clean(self, tmp_path):
        # bool-mask counting (`elig.sum()`) must not be flagged
        p = _project(tmp_path, {"core/engine.py": """\
            def count(elig):
                n = elig.sum()
                return n
            """}, **_RPL001_MANIFEST)
        assert _findings(p, "RPL001") == []


# ---------------------------------------------------------------------------
# RPL002 — use-after-donate
# ---------------------------------------------------------------------------

class TestRPL002:
    def test_catch_read_after_donation(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import jax

            def step(pool, x):
                fn = jax.jit(work, donate_argnums=(0,))
                out = fn(pool, x)
                bad = pool + 1
                return out, bad
            """})
        fs = _findings(p, "RPL002")
        assert len(fs) == 1 and fs[0].line == 6 and "`pool`" in fs[0].message

    def test_clean_when_rebound_from_result(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import jax

            def step(pool, x):
                fn = jax.jit(work, donate_argnums=(0,))
                pool = fn(pool, x)
                return pool + 1
            """})
        assert _findings(p, "RPL002") == []

    def test_catch_redonation_in_loop_without_rebind(self, tmp_path):
        # second iteration passes an already-deleted buffer back in
        p = _project(tmp_path, {"m.py": """\
            import jax

            def drive(pool, xs):
                fn = jax.jit(work, donate_argnums=(0,))
                for x in xs:
                    out = fn(pool, x)
                return out
            """})
        assert len(_findings(p, "RPL002")) == 1

    def test_clean_loop_with_rebind(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import jax

            def drive(pool, xs):
                fn = jax.jit(work, donate_argnums=(0,))
                for x in xs:
                    pool = fn(pool, x)
                return pool
            """})
        assert _findings(p, "RPL002") == []

    def test_manifest_donating_callable_attribute(self, tmp_path):
        # bound methods the AST can't resolve come from the manifest
        p = _project(tmp_path, {"m.py": """\
            class S:
                def poll(self):
                    d = self._tick(self._win, self._dev)
                    return self._win[0], d
            """}, donating_callables={"self._tick": [0, 1]})
        fs = _findings(p, "RPL002")
        assert len(fs) == 1 and "self._win" in fs[0].message

    def test_tuple_rebind_same_statement_is_clean(self, tmp_path):
        # the fused-tick idiom: donate and rebind in one statement
        p = _project(tmp_path, {"m.py": """\
            class S:
                def poll(self):
                    self._win, self._dev, d = self._tick(self._win, self._dev)
                    return self._win[0], d
            """}, donating_callables={"self._tick": [0, 1]})
        assert _findings(p, "RPL002") == []

    def test_non_literal_donate_argnums_is_skipped(self, tmp_path):
        # launch/dryrun.py style: positions unresolvable -> hand audit
        p = _project(tmp_path, {"m.py": """\
            import jax

            def lower(spec):
                fn = jax.jit(spec.fn, donate_argnums=spec.donate)
                fn(spec.args)
                return spec.args
            """})
        assert _findings(p, "RPL002") == []


# ---------------------------------------------------------------------------
# RPL003 — host-sync-in-jit
# ---------------------------------------------------------------------------

class TestRPL003:
    def test_catch_float_cast_under_jit_decorator(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import jax

            @jax.jit
            def f(x):
                return float(x) + 1.0
            """})
        fs = _findings(p, "RPL003")
        assert len(fs) == 1 and "float()" in fs[0].message

    def test_catch_item_in_scan_body(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import jax

            def run(c0, xs):
                def body(c, x):
                    bad = x.item()
                    return c + bad, c
                return jax.lax.scan(body, c0, xs)
            """})
        assert len(_findings(p, "RPL003")) == 1

    def test_catch_transitive_helper(self, tmp_path):
        # helper called from a traced body is itself traced
        p = _project(tmp_path, {"m.py": """\
            import jax
            import numpy as np

            def helper(x):
                return np.asarray(x)

            @jax.jit
            def f(x):
                return helper(x)
            """})
        fs = _findings(p, "RPL003")
        assert len(fs) == 1 and fs[0].line == 5

    def test_clean_shape_reads_and_host_code(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                n = int(x.shape[0])
                return x * n

            def host(x):
                return float(x), np.asarray(x)
            """})
        assert _findings(p, "RPL003") == []

    def test_partial_jit_decorator_detected(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("n",))
            def f(x, n):
                return bool(x)
            """})
        assert len(_findings(p, "RPL003")) == 1


# ---------------------------------------------------------------------------
# RPL004 — static-arg hashability
# ---------------------------------------------------------------------------

class TestRPL004:
    def test_catch_list_passed_to_static_name(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("cfg",))
            def g(x, cfg):
                return x

            def use(x):
                return g(x, cfg=[1, 2])
            """})
        fs = _findings(p, "RPL004")
        assert len(fs) == 1 and "list" in fs[0].message

    def test_catch_unhashable_positional_via_argnums(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import jax

            def f(x, cfg):
                return x

            g = jax.jit(f, static_argnums=(1,))

            def use(x):
                return g(x, {"a": 1})
            """})
        fs = _findings(p, "RPL004")
        assert len(fs) == 1 and "dict" in fs[0].message

    def test_catch_unhashable_default(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("ws",))
            def g(x, ws=[1.0, 2.0]):
                return x
            """})
        fs = _findings(p, "RPL004")
        assert len(fs) == 1 and "default" in fs[0].message

    def test_clean_tuple_and_scalar(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("cfg", "n"))
            def g(x, cfg=(1, 2), n=4):
                return x

            def use(x):
                return g(x, cfg=(3, 4), n=8)
            """})
        assert _findings(p, "RPL004") == []


# ---------------------------------------------------------------------------
# RPL005 — Pallas kernel contract
# ---------------------------------------------------------------------------

_KERNEL_MANIFEST = dict(kernels_root="kernels",
                        kernel_test_file="tests/test_kernels.py")


class TestRPL005:
    def test_catch_missing_ref_module(self, tmp_path):
        p = _project(tmp_path, {
            "kernels/foo/__init__.py": "",
            "kernels/foo/foo.py": "def kern():\n    pass\n",
            "tests/test_kernels.py": "",
        }, **_KERNEL_MANIFEST)
        fs = _findings(p, "RPL005")
        assert len(fs) == 1 and "no ref.py" in fs[0].message

    def test_catch_ref_without_parity_test(self, tmp_path):
        p = _project(tmp_path, {
            "kernels/foo/__init__.py": "",
            "kernels/foo/ref.py": "def foo_ref(x):\n    return x\n",
            "tests/test_kernels.py": "import math\n\n\ndef test_pi():\n    assert math.pi > 3\n",
        }, **_KERNEL_MANIFEST)
        fs = _findings(p, "RPL005")
        assert len(fs) == 1 and "parity" in fs[0].message

    def test_catch_misaligned_blockspec_minor_axis(self, tmp_path):
        p = _project(tmp_path, {
            "kernels/foo/__init__.py": "",
            "kernels/foo/ref.py": "def foo_ref(x):\n    return x\n",
            "kernels/foo/foo.py": """\
                from jax.experimental import pallas as pl
                from jax.experimental.pallas import tpu as pltpu
                import jax.numpy as jnp

                BLK = 256

                SPEC_BAD = pl.BlockSpec((8, 40), lambda i: (0, i))
                SPEC_OK = pl.BlockSpec((8, BLK), lambda i: (0, i))
                SCRATCH_BAD = pltpu.VMEM((1, 2), jnp.float32)
                SCRATCH_HALF = pltpu.VMEM((1, 128), jnp.bfloat16)
                SCRATCH_OK = pltpu.VMEM((1, 128), jnp.float32)
                """,
            "tests/test_kernels.py":
                "from kernels.foo.ref import foo_ref  # noqa: F401\n",
        }, **_KERNEL_MANIFEST)
        fs = _findings(p, "RPL005")
        msgs = "\n".join(f.message for f in fs)
        assert len(fs) == 3
        assert "minor axis 40" in msgs and "minor axis 2" in msgs
        assert "bfloat16" in msgs

    def test_clean_full_contract(self, tmp_path):
        p = _project(tmp_path, {
            "kernels/foo/__init__.py": "",
            "kernels/foo/ref.py": "def foo_ref(x):\n    return x\n",
            "kernels/foo/foo.py": """\
                from jax.experimental import pallas as pl

                SPEC = pl.BlockSpec((8, 128), lambda i: (0, i))
                VEC = pl.BlockSpec((128,), lambda i: (i,))
                """,
            "tests/test_kernels.py":
                "from kernels.foo.ref import foo_ref  # noqa: F401\n",
        }, **_KERNEL_MANIFEST)
        assert _findings(p, "RPL005") == []

    def test_reexported_ref_counts_as_oracle(self, tmp_path):
        # ssd_scan style: ref.py re-exports an oracle that lives with
        # the model stack
        p = _project(tmp_path, {
            "kernels/foo/__init__.py": "",
            "kernels/foo/ref.py":
                "from models.ssm import foo_ref  # noqa: F401\n",
            "tests/test_kernels.py":
                "from kernels.foo.ref import foo_ref  # noqa: F401\n",
        }, **_KERNEL_MANIFEST)
        assert _findings(p, "RPL005") == []


# ---------------------------------------------------------------------------
# RPL006 — import hygiene
# ---------------------------------------------------------------------------

class TestRPL006:
    def test_catch_unused_and_duplicate(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            import os
            import sys
            import sys

            print(sys.argv)
            """})
        msgs = [f.message for f in _findings(p, "RPL006")]
        assert any("`os` imported but unused" in m for m in msgs)
        assert any("re-imported" in m for m in msgs)

    def test_noqa_silences_on_name_line(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            from os.path import (
                join,
                sep,  # noqa: F401
            )

            print(join("a", "b"))
            """})
        assert _findings(p, "RPL006") == []

    def test_init_without_all_is_reexport_surface(self, tmp_path):
        p = _project(tmp_path, {"pkg/__init__.py": "from os import sep\n"})
        assert _findings(p, "RPL006") == []

    def test_all_counts_as_use(self, tmp_path):
        p = _project(tmp_path, {"m.py": """\
            from os import sep

            __all__ = ["sep"]
            """})
        assert _findings(p, "RPL006") == []


# ---------------------------------------------------------------------------
# framework: suppression, manifest, CLI
# ---------------------------------------------------------------------------

class TestSuppression:
    def test_line_suppression_marks_not_reports(self, tmp_path):
        p = _project(tmp_path, {"core/engine.py": """\
            import jax.numpy as jnp

            def overload(scores):
                sev = jnp.sum(scores)  # reprolint: disable=RPL001
                return sev
            """}, **_RPL001_MANIFEST)
        all_f = p.run(only={"RPL001"})
        assert len(all_f) == 1 and all_f[0].suppressed
        assert _findings(p, "RPL001") == []

    def test_file_level_suppression(self, tmp_path):
        p = _project(tmp_path, {"core/engine.py": """\
            # reprolint: disable-file=RPL001
            import jax.numpy as jnp

            def overload(scores):
                sev = jnp.sum(scores)
                return sev
            """}, **_RPL001_MANIFEST)
        assert _findings(p, "RPL001") == []

    def test_suppression_is_rule_specific(self, tmp_path):
        p = _project(tmp_path, {"core/engine.py": """\
            import jax.numpy as jnp

            def overload(scores):
                sev = jnp.sum(scores)  # reprolint: disable=RPL002
                return sev
            """}, **_RPL001_MANIFEST)
        assert len(_findings(p, "RPL001")) == 1


class TestManifest:
    def test_repo_manifest_loads(self):
        man = load_manifest(REPO_ROOT)
        assert "core/scheduler.py" in man.critical_modules
        assert "sim/engine.py" in man.critical_modules
        assert man.lane == 128
        assert man.kernels_root == "src/repro/kernels"
        assert man.donating_callables.get("self._tick") == (0, 1)

    def test_defaults_without_pyproject(self, tmp_path):
        man = load_manifest(tmp_path)
        assert man.critical_modules == ()
        assert man.pinned_names == ("pinned",)
        assert man.lane == 128

    def test_fallback_parser_matches_real_manifest(self):
        # the no-TOML-library code path must read the repo manifest the
        # same way tomllib/tomli do (it runs on bare CI interpreters)
        from repro.analysis.manifest import _fallback_parse
        data = _fallback_parse(
            (REPO_ROOT / "pyproject.toml").read_text(encoding="utf-8"))
        t = data["tool"]["reprolint"]
        real = load_manifest(REPO_ROOT)
        assert tuple(t["critical-modules"]) == real.critical_modules
        assert tuple(t["sensitive-names"]) == real.sensitive_names
        assert t["lane"] == real.lane
        assert {k: tuple(v) for k, v in t["donating-callables"].items()} \
            == real.donating_callables


def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC_DIR) + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=120)


class TestCLI:
    def test_nonzero_on_violation_tree(self, tmp_path):
        # the deliberate-violation smoke `make ci` relies on: a tree
        # with a use-after-donate must fail the lint gate
        (tmp_path / "pyproject.toml").write_text("[tool.reprolint]\n")
        (tmp_path / "bad.py").write_text(textwrap.dedent("""\
            import jax

            def step(pool, x):
                fn = jax.jit(work, donate_argnums=(0,))
                out = fn(pool, x)
                return out, pool
            """))
        r = _run_cli(["--root", str(tmp_path), "bad.py"], cwd=tmp_path)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "RPL002" in r.stdout

    def test_zero_on_real_tree(self):
        r = _run_cli(["src", "tests", "benchmarks"], cwd=REPO_ROOT)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "0 finding(s)" in r.stdout

    def test_missing_path_is_usage_error(self, tmp_path):
        (tmp_path / "pyproject.toml").write_text("[tool.reprolint]\n")
        r = _run_cli(["--root", str(tmp_path), "nope"], cwd=tmp_path)
        assert r.returncode == 2

    def test_list_rules_names_all_six(self):
        r = _run_cli(["--list-rules"], cwd=REPO_ROOT)
        assert r.returncode == 0
        for rid in ("RPL001", "RPL002", "RPL003", "RPL004", "RPL005",
                    "RPL006"):
            assert rid in r.stdout

    def test_analysis_package_never_imports_jax(self):
        # lint must run on a bare CI interpreter before deps install
        code = ("import sys; import repro.analysis.lint; "
                "import repro.analysis.rules; "
                "sys.exit(1 if any(m.startswith('jax') for m in sys.modules) "
                "else 0)")
        env = dict(os.environ)
        env["PYTHONPATH"] = str(SRC_DIR)
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=60)
        assert r.returncode == 0, r.stderr
