"""core.numerics.pinned edge cases (satellite of the reprolint PR).

The engine-parity suites exercise `pinned` indirectly — these tests pin
its contract directly: bitwise identity eager and under jit, pytree
structure preservation, nested vmap-of-vmap batching of the custom
rule, and the property the whole discipline exists for — a pinned
subgraph rounds identically whether it runs standalone or fused into a
larger jitted program (including a `lax.scan` EMA chain, the shape
`sim/engine.py` relies on).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import pinned


def _bits(x):
    return np.asarray(x, dtype=np.float32).view(np.uint32)


def _vals(key, shape):
    # awkward magnitudes: values where reassociation/FMA actually moves ulps
    a = jax.random.uniform(key, shape, jnp.float32, 1e-4, 1e4)
    return a * jnp.where(jax.random.bernoulli(key, 0.5, shape), 1.0, -1.0)


class TestIdentity:
    def test_identity_bits_eager_and_jit(self):
        x = _vals(jax.random.PRNGKey(0), (257,))
        np.testing.assert_array_equal(_bits(pinned(x)), _bits(x))
        np.testing.assert_array_equal(_bits(jax.jit(pinned)(x)), _bits(x))

    def test_pytree_structure_preserved(self):
        tree = {"a": jnp.float32(1.5),
                "b": (jnp.arange(3, dtype=jnp.float32),
                      jnp.ones((2, 2), jnp.float32))}
        out = pinned(tree)
        assert jax.tree_util.tree_structure(out) == \
            jax.tree_util.tree_structure(tree)
        for o, t in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(_bits(o), _bits(t))

    def test_dtype_and_weak_type_preserved(self):
        xi = jnp.arange(4, dtype=jnp.int32)
        assert pinned(xi).dtype == jnp.int32
        xf = jnp.float32(2.0)
        assert pinned(xf).dtype == jnp.float32


class TestVmapBatching:
    def test_vmap_matches_stacked_loop_bitwise(self):
        xs = _vals(jax.random.PRNGKey(1), (8, 33))

        def f(x):
            return pinned(x * 3.0 + x / 7.0)

        batched = jax.vmap(f)(xs)
        looped = jnp.stack([f(xs[i]) for i in range(xs.shape[0])])
        np.testing.assert_array_equal(_bits(batched), _bits(looped))

    def test_nested_vmap_of_vmap(self):
        # the runner's seed axis on top of the class axis: the custom
        # batching rule must compose with itself
        xs = _vals(jax.random.PRNGKey(2), (4, 5, 17))

        def f(x):
            return pinned(jnp.sum(x * 1.000001))

        nested = jax.vmap(jax.vmap(f))(xs)
        flat = jax.vmap(f)(xs.reshape(20, 17)).reshape(4, 5)
        np.testing.assert_array_equal(_bits(nested), _bits(flat))

    def test_nested_vmap_under_jit(self):
        # the pin's contract is cross-*program* (two different jitted
        # programs round the pinned subgraph identically), asserted here
        # at vmap-of-vmap depth: an extra consumer that would otherwise
        # fuse into the producer must not perturb the pinned value
        xs = _vals(jax.random.PRNGKey(3), (3, 4, 9))

        def f(x):
            return pinned(x * 0.1 + 0.9)

        @jax.jit
        def bare(xs):
            return jax.vmap(jax.vmap(f))(xs)

        @jax.jit
        def embedded(xs):
            y = jax.vmap(jax.vmap(f))(xs)
            return y, jnp.tanh(y * 3.0).sum()

        a = bare(xs)
        b, _ = embedded(xs)
        np.testing.assert_array_equal(_bits(a), _bits(b))

    def test_vmap_over_pytree(self):
        xs = {"u": _vals(jax.random.PRNGKey(4), (6, 5)),
              "v": _vals(jax.random.PRNGKey(5), (6, 5))}

        def f(t):
            return pinned({"s": t["u"] + t["v"], "d": t["u"] - t["v"]})

        out = jax.vmap(f)(xs)
        assert out["s"].shape == (6, 5) and out["d"].shape == (6, 5)
        np.testing.assert_array_equal(
            _bits(out["s"]), _bits(xs["u"] + xs["v"]))


class TestPinSurvivesFusion:
    """The property the discipline exists for: arithmetic between two
    pins rounds identically no matter what program surrounds it."""

    def test_pinned_subgraph_identical_across_programs(self):
        w1, w2, w3 = 0.63, 0.21, 1.7

        def score(wait, cost, urg):
            return pinned((w1 * (wait / cost), w2 * cost, w3 * urg))

        def standalone(wait, cost, urg):
            t = score(wait, cost, urg)
            return (t[0] - t[1]) + t[2]

        def fused(wait, cost, urg):
            # same pinned subgraph buried in a bigger program that
            # invites FMA contraction / reassociation around it
            t = score(wait, cost, urg)
            s = (t[0] - t[1]) + t[2]
            noise = jnp.tanh(wait * cost) * jnp.exp(-urg)
            return s, s * 2.0 + noise

        k = jax.random.PRNGKey(6)
        wait = _vals(k, (513,)) ** 2 + 1.0
        cost = _vals(jax.random.PRNGKey(7), (513,)) ** 2 + 1.0
        urg = _vals(jax.random.PRNGKey(8), (513,))

        a = jax.jit(standalone)(wait, cost, urg)
        b, _ = jax.jit(fused)(wait, cost, urg)
        np.testing.assert_array_equal(_bits(a), _bits(b))

    def test_ema_chain_scan_matches_step_loop(self):
        # sim/engine.py's tail-EMA shape: delta = pinned(alpha * (x - ema));
        # a lax.scan over ticks inside one jit must round exactly like
        # single jitted steps driven from the host
        alpha = jnp.float32(0.15)

        def step(ema, x):
            delta = pinned(alpha * (x - ema))
            return ema + delta, ema + delta

        xs = _vals(jax.random.PRNGKey(9), (200,))
        ema0 = jnp.float32(1.0)

        @jax.jit
        def scanned(e0, xs):
            return jax.lax.scan(step, e0, xs)

        final_scan, trail_scan = scanned(ema0, xs)

        step_j = jax.jit(step)
        e = ema0
        trail = []
        for i in range(xs.shape[0]):
            e, out = step_j(e, xs[i])
            trail.append(out)
        np.testing.assert_array_equal(_bits(final_scan), _bits(e))
        np.testing.assert_array_equal(
            _bits(trail_scan), _bits(jnp.stack(trail)))
