"""Active-window engine pins (DESIGN.md §6).

The contract under test: with window capacity W >= the peak live queue,
the windowed engine is *bit-exact* with the dense engine — the same
decision stream (per tick, per grant), the same final request arrays,
the same scheduler state floats — while doing O(W) work per tick
instead of O(N).  Pinned per-decision and full-horizon across
stationary and nonstationary scenarios (including provider dynamics:
brownout + token-bucket 429s), the same discipline as the B=1 and K=2
pins.

Also covered: the overflow regime (W smaller than the live queue) must
degrade gracefully — FIFO admission, no lost or duplicated requests —
and the compacted window invariants (occupied prefix, request-id
sorted) must hold tick over tick.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sim.engine as eng
from repro.core.policy import base_policy, kclass_policy, strategy
from repro.core.scheduler import IDLE, schedule_batch
from repro.core.types import (
    ABANDONED,
    COMPLETED,
    INFLIGHT,
    PENDING,
    REJECTED,
    init_sim_state,
    init_window_carry,
)
from repro.sim import SimConfig, WorkloadConfig, default_physics, generate, run_sim
from repro.sim import scenarios as scn

REQ_FIELDS = ("status", "submit_ms", "finish_ms", "defer_until",
              "n_defers", "n_throttles")


def _bits_equal(a, b):
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def _run_pair(policy, batch, jitter, sim_cfg, window, dynamics=None):
    phys = default_physics()
    dense = jax.jit(lambda: run_sim(
        policy, batch, jitter, phys, sim_cfg, dynamics,
        collect_decisions=True))()
    win = jax.jit(lambda: run_sim(
        policy, batch, jitter, phys, sim_cfg._replace(window=window),
        dynamics, collect_decisions=True))()
    return dense, win


def _assert_bit_exact(dense, win):
    (fd, td), (fw, tw) = dense, win
    for name in REQ_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(fd.req, name)),
            np.asarray(getattr(fw.req, name)), err_msg=name)
    assert _bits_equal(fd.sched.ema_latency_ratio, fw.sched.ema_latency_ratio)
    assert _bits_equal(fd.sched.deficit, fw.sched.deficit)
    assert int(fd.sched.rr_turn) == int(fw.sched.rr_turn)
    assert int(fd.sched.n_completed_obs) == int(fw.sched.n_completed_obs)
    assert int(fd.provider.inflight) == int(fw.provider.inflight)
    assert _bits_equal(fd.provider.tb_tokens, fw.provider.tb_tokens)
    assert int(fd.provider.n_throttled) == int(fw.provider.n_throttled)
    # per-decision stream: action, target (IDLE rows carry no target —
    # the engines encode them differently), severity bits
    a_act, w_act = np.asarray(td[0]), np.asarray(tw[0])
    np.testing.assert_array_equal(a_act, w_act)
    a_idx = np.where(a_act == IDLE, -1, np.asarray(td[1]))
    w_idx = np.where(w_act == IDLE, -1, np.asarray(tw[1]))
    np.testing.assert_array_equal(a_idx, w_idx)
    assert _bits_equal(td[2], tw[2])


class TestBitExactStationary:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_heavy_high_b4(self, seed):
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=160, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(seed), wl)
        pair = _run_pair(policy, batch, jitter,
                         SimConfig(n_ticks=2000, k_slots=4), window=192)
        _assert_bit_exact(*pair)
        # the pin must bite: work actually completed
        assert int((np.asarray(pair[0][0].req.status) == COMPLETED).sum()) > 10

    def test_b1_slot_discipline(self):
        """k_slots=1 — the windowed pass must reduce to the same
        sequential slot decisions the B=1 pins lock down."""
        policy = base_policy()
        wl = WorkloadConfig(n_requests=128, mix="balanced", congestion="medium")
        batch, jitter = generate(jax.random.PRNGKey(3), wl)
        pair = _run_pair(policy, batch, jitter,
                         SimConfig(n_ticks=2500, k_slots=1), window=128)
        _assert_bit_exact(*pair)

    @pytest.mark.slow
    def test_k4_tenants_b8(self):
        policy = kclass_policy(4)
        wl = WorkloadConfig(n_requests=200, mix="heavy", congestion="high",
                            class_map="tenant4")
        batch, jitter = generate(jax.random.PRNGKey(4), wl)
        pair = _run_pair(policy, batch, jitter,
                         SimConfig(n_ticks=2500, k_slots=8), window=256)
        _assert_bit_exact(*pair)


class TestBitExactNonstationary:
    @pytest.mark.parametrize("name", ["flash_crowd", "storm"])
    def test_scenario(self, name):
        """Nonstationary arrivals + provider dynamics (storm: brownout
        AND token-bucket 429s at once) — the windowed engine must
        reproduce the dense decision stream through every mechanism."""
        sc = scn.get_scenario(name)
        sim_cfg = SimConfig(n_ticks=3000, k_slots=4)
        wl, sched, dyn, _ = scn.build(sc, 160, sim_cfg.n_ticks,
                                      sim_cfg.dt_ms, limiter_classes=2)
        batch, jitter = generate(jax.random.PRNGKey(0), wl, sched)
        policy = strategy("final_adrr_olc")
        pair = _run_pair(policy, batch, jitter, sim_cfg, window=256,
                         dynamics=dyn)
        _assert_bit_exact(*pair)

    def test_rate_limited_throttles_match(self):
        """429 bounces flow through the window translation: the per-
        request throttle counts and bucket state must stay bit-exact."""
        sc = scn.get_scenario("rate_limited")
        sim_cfg = SimConfig(n_ticks=3000, k_slots=4)
        wl, sched, dyn, _ = scn.build(sc, 160, sim_cfg.n_ticks,
                                      sim_cfg.dt_ms, limiter_classes=2)
        batch, jitter = generate(jax.random.PRNGKey(1), wl, sched)
        pair = _run_pair(strategy("final_adrr_olc"), batch, jitter, sim_cfg,
                         window=256, dynamics=dyn)
        _assert_bit_exact(*pair)
        assert int(pair[0][0].provider.n_throttled) > 0  # limiter bit


class TestWindowInternals:
    def _drive(self, w, n_ticks=400, n_req=96):
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=n_req, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(5), wl)
        phys = default_physics()
        state = init_sim_state(batch.n, 2)
        win = init_window_carry(w, batch.n)

        @jax.jit
        def tick(state, win, t):
            now = (t + 1.0) * 25.0
            state = state._replace(now_ms=now)
            state, alive = eng._retire_window(policy, phys, batch, state, win)
            win = eng._compact_and_admit(batch, win, alive, now)
            wb, wr, _ = eng._window_view(batch, state.req, win.slot_req)
            d = schedule_batch(policy, wb, state._replace(req=wr),
                               max_grants=4)
            d = d._replace(req_idx=win.slot_req[jnp.clip(d.req_idx, 0, w - 1)])
            state = eng._apply_batch(policy, phys, batch, jitter, state, d)
            return state, win

        traj = []
        for t in range(n_ticks):
            state, win = tick(state, win, jnp.float32(t))
            traj.append(np.asarray(win.slot_req))
        return batch, state, win, traj

    def test_compaction_invariants(self):
        """Occupied slots form a request-id-sorted prefix every tick —
        the property the first-occurrence tie-breaking proof rests on."""
        batch, _, _, traj = self._drive(w=128)
        n = batch.n
        for slots in traj[::7]:
            occ = slots < n
            k = occ.sum()
            assert occ[:k].all() and not occ[k:].any()  # compacted prefix
            ids = slots[:k]
            assert (np.diff(ids) > 0).all()             # strictly sorted
            assert (slots[k:] == n).all()               # empty sentinel

    def test_overflow_conserves_requests(self):
        """W far below the live queue: admission throttles FIFO, but no
        request is lost, duplicated, or granted before arrival."""
        w = 16
        batch, state, win, traj = self._drive(w=w, n_ticks=600)
        n = batch.n
        for slots in traj[::11]:
            ids = slots[slots < n]
            assert len(set(ids.tolist())) == len(ids)   # no duplicates
        st = np.asarray(state.req.status)
        assert set(np.unique(st)) <= {PENDING, INFLIGHT, COMPLETED,
                                      REJECTED, ABANDONED}
        sub = np.asarray(state.req.submit_ms)
        arr = np.asarray(batch.arrival_ms)
        sent = np.isfinite(sub)
        assert (sub[sent] >= arr[sent]).all()
        # the tiny window still moved real work through the provider
        assert int((st == COMPLETED).sum()) > 0

    def test_overflow_full_run_terminates(self):
        """run_sim end-to-end with an undersized window: the drain must
        still account every request to a terminal state."""
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=120, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(6), wl)
        final = jax.jit(lambda: run_sim(
            policy, batch, jitter, default_physics(),
            SimConfig(n_ticks=3000, k_slots=4, window=24)))()
        st = np.asarray(final.req.status)
        assert ((st == COMPLETED) | (st == REJECTED)
                | (st == ABANDONED)).all()


class TestWindowedPallasBackend:
    def test_dispatch_parity_non_lane_aligned_window(self):
        """The pallas ordering backend inside window mode at W not a
        multiple of the TPU lane width (padding path in
        kernels/sched_score/ops.py): decisions must match the jnp
        backend for the same window view."""
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=160, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(7), wl)
        phys = default_physics()
        w = 96  # not a multiple of 128
        state = init_sim_state(batch.n, 2)
        win = init_window_carry(w, batch.n)

        @jax.jit
        def advance(state, win, t):
            now = (t + 1.0) * 25.0
            state = state._replace(now_ms=now)
            state, alive = eng._retire_window(policy, phys, batch, state, win)
            win = eng._compact_and_admit(batch, win, alive, now)
            wb, wr, _ = eng._window_view(batch, state.req, win.slot_req)
            d = schedule_batch(policy, wb, state._replace(req=wr),
                               max_grants=4)
            d = d._replace(req_idx=win.slot_req[jnp.clip(d.req_idx, 0, w - 1)])
            state = eng._apply_batch(policy, phys, batch, jitter, state, d)
            return state, win

        checked = 0
        for t in range(160):
            state, win = advance(state, win, jnp.float32(t))
            if t % 40 == 17:
                wb, wr, _ = eng._window_view(batch, state.req, win.slot_req)
                ws = state._replace(
                    now_ms=jnp.float32((t + 1.5) * 25.0), req=wr)
                dj = jax.jit(schedule_batch, static_argnames=(
                    "max_grants", "backend"))(
                    policy, wb, ws, max_grants=4, backend="jnp")
                dp = jax.jit(schedule_batch, static_argnames=(
                    "max_grants", "backend"))(
                    policy, wb, ws, max_grants=4, backend="pallas")
                np.testing.assert_array_equal(
                    np.asarray(dj.actions), np.asarray(dp.actions))
                live = np.asarray(dj.actions) != IDLE
                np.testing.assert_array_equal(
                    np.asarray(dj.req_idx)[live], np.asarray(dp.req_idx)[live])
                checked += 1
        assert checked >= 3


class TestRunnerThreading:
    def test_run_cell_windowed_matches_dense(self):
        """The seed-vmapped runner path (metrics included) is identical
        under the windowed engine — window is purely an execution
        strategy, invisible in results.  Sized via the exported
        `window_for` heuristic (which must clear the bit-exactness
        condition here: its floor exceeds this population outright)."""
        from repro.sim import run_cell, window_for
        policy = base_policy()
        wl = WorkloadConfig(n_requests=96, mix="balanced", congestion="medium")
        w = window_for(wl.n_requests)
        assert w >= wl.n_requests  # floor covers small populations
        m_dense = run_cell(policy, wl, seeds=2,
                           sim_cfg=SimConfig(n_ticks=1500, k_slots=4))
        m_win = run_cell(policy, wl, seeds=2,
                         sim_cfg=SimConfig(n_ticks=1500, k_slots=4,
                                           window=w))
        for name in ("global_p95_ms", "completion_rate", "satisfaction",
                     "goodput_rps", "n_rejects", "n_abandoned",
                     "class_p95_ms"):
            a = np.asarray(getattr(m_dense, name))
            b = np.asarray(getattr(m_win, name))
            np.testing.assert_array_equal(a[np.isfinite(a)], b[np.isfinite(b)],
                                          err_msg=name)
