"""Scenario subsystem: nonstationary arrivals, provider dynamics,
windowed metrics, and the stationary bit-exactness anchor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.policy import base_policy
from repro.core.types import ABANDONED, COMPLETED, PENDING, REJECTED
from repro.sim import (
    SCENARIOS,
    SimConfig,
    WorkloadConfig,
    compute_metrics,
    compute_phase_metrics,
    default_physics,
    generate,
    get_scenario,
    run_cell,
    run_scenario_cell,
    run_sim,
    window_for,
)
from repro.sim.provider import (
    ProviderDynamics,
    brownout_schedule,
    load_multiplier,
    service_time_ms,
    token_bucket_schedule,
    token_bucket_windows,
)
from repro.sim.scenarios import (
    Phase,
    Scenario,
    arrival_span_ms,
    build,
    build_arrival_schedule,
    phase_edges_ms,
)
from repro.sim.workload import phase_index, warp_arrivals

SMALL = SimConfig(n_ticks=1500)


class TestArrivalSchedule:
    def test_trivial_schedule_is_identity(self):
        """One phase, unit multiplier: warp is the IEEE identity."""
        sched = build_arrival_schedule(Scenario("x"), 48)
        work = jnp.asarray([0.0, 17.3, 999.9, 1e6], jnp.float32)
        out = warp_arrivals(work, sched)
        assert np.array_equal(np.asarray(out), np.asarray(work))

    def test_burst_phase_compresses_arrivals(self):
        """A phase with multiplier m packs m× the arrivals per unit time."""
        sc = Scenario("b", phases=(Phase(0.5, 0.5), Phase(0.5, 1.5)))
        sched = build_arrival_schedule(sc, 128)
        span = arrival_span_ms(sc, 128)
        b, _ = generate(jax.random.PRNGKey(0), WorkloadConfig(n_requests=128),
                        sched)
        a = np.asarray(b.arrival_ms)
        half = span / 2
        # phase 1 runs at 3x phase 0's rate; allow Poisson noise
        n0 = ((a >= 0) & (a < half)).sum()
        n1 = ((a >= half) & (a < span)).sum()
        assert n1 > 1.8 * n0

    def test_warp_monotone_and_piecewise_linear(self):
        sc = Scenario(
            "w", phases=(Phase(0.25, 0.4), Phase(0.5, 1.6), Phase(0.25, 0.4)))
        sched = build_arrival_schedule(sc, 64)
        work = jnp.linspace(0.0, 2.0 * float(sched.cum_work_ms[-1]), 512)
        t = np.asarray(warp_arrivals(work, sched))
        assert (np.diff(t) > 0).all()
        # inside one phase the warp slope is 1/rate_mult; skip segments
        # that straddle a phase boundary (they blend two slopes)
        p = np.asarray(phase_index(sched, jnp.asarray(t)))
        same = p[:-1] == p[1:]
        slope = (np.diff(t) / np.diff(np.asarray(work)))[same]
        expect = (1.0 / np.asarray(sched.rate_mult)[p[:-1]])[same]
        assert np.allclose(slope, expect, rtol=1e-3)

    def test_mix_shift_changes_buckets_by_phase(self):
        sc = get_scenario("heavy_shift")
        sched = build_arrival_schedule(sc, 2048)
        b, _ = generate(jax.random.PRNGKey(1),
                        WorkloadConfig(n_requests=2048), sched)
        edges = np.asarray(phase_edges_ms(sc, 2048))
        a = np.asarray(b.arrival_ms)
        bkt = np.asarray(b.bucket)
        mid = (a >= edges[1]) & (a < edges[2])
        out = (a < edges[1]) | ((a >= edges[2]) & (a < edges[3]))
        heavy_mid = (bkt[mid] >= 2).mean()
        heavy_out = (bkt[out] >= 2).mean()
        # heavy mix: 60% long/xlong vs 25% under balanced
        assert heavy_mid > 0.45 and heavy_out < 0.35

    def test_phase_fracs_must_sum_to_one(self):
        with pytest.raises(ValueError, match="sum to 1"):
            build_arrival_schedule(
                Scenario("bad", phases=(Phase(0.5), Phase(0.2))), 32)

    def test_constant_mix_keeps_seed_bucket_stream(self):
        """A rate-only schedule must not perturb the bucket stream."""
        key = jax.random.PRNGKey(3)
        wl = WorkloadConfig(n_requests=96)
        plain, _ = generate(key, wl)
        sc = Scenario("r", phases=(Phase(0.5, 0.5), Phase(0.5, 1.5)))
        shaped, _ = generate(key, wl, build_arrival_schedule(sc, 96))
        assert np.array_equal(np.asarray(plain.bucket),
                              np.asarray(shaped.bucket))
        assert np.array_equal(np.asarray(plain.true_tokens),
                              np.asarray(shaped.true_tokens))


class TestStationaryBitExact:
    """The `balanced` scenario is the seed engine, bit for bit."""

    def test_generate_bit_exact(self):
        key = jax.random.PRNGKey(0)
        wl_cfg, sched, dynamics, _ = build(
            SCENARIOS["balanced"], 48, SMALL.n_ticks, SMALL.dt_ms)
        assert dynamics is None
        plain, j0 = generate(key, WorkloadConfig(n_requests=48))
        scen, j1 = generate(key, wl_cfg, sched)
        for name in plain._fields:
            assert np.array_equal(
                np.asarray(getattr(plain, name)),
                np.asarray(getattr(scen, name))), name
        assert np.array_equal(np.asarray(j0), np.asarray(j1))

    @pytest.mark.slow
    def test_run_sim_bit_exact(self):
        key = jax.random.PRNGKey(7)
        policy, phys = base_policy(), default_physics()
        wl_cfg, sched, dynamics, _ = build(
            SCENARIOS["balanced"], 48, SMALL.n_ticks, SMALL.dt_ms)
        b0, j0 = generate(key, WorkloadConfig(n_requests=48))
        f0 = run_sim(policy, b0, j0, phys, SMALL)
        b1, j1 = generate(key, wl_cfg, sched)
        f1 = run_sim(policy, b1, j1, phys, SMALL, dynamics)
        assert np.array_equal(np.asarray(f0.req.status),
                              np.asarray(f1.req.status))
        assert np.array_equal(np.asarray(f0.req.finish_ms),
                              np.asarray(f1.req.finish_ms))
        assert np.array_equal(np.asarray(f0.sched.deficit),
                              np.asarray(f1.sched.deficit))

    @pytest.mark.slow
    def test_scenario_cell_matches_run_cell(self):
        """The full jitted scenario path equals the stationary runner."""
        m0 = run_cell(base_policy(), WorkloadConfig(n_requests=48),
                      seeds=2, sim_cfg=SMALL)
        m1, _ = run_scenario_cell(base_policy(), "balanced", seeds=2,
                                  n_requests=48, sim_cfg=SMALL)
        for name in m0._fields:
            assert np.array_equal(
                np.asarray(getattr(m0, name)),
                np.asarray(getattr(m1, name)), equal_nan=True), name


class TestDenseVsWindowed:
    """The active window is an execution strategy, not a modeling
    change: with W covering the live queue, a scenario cell's aggregate
    AND per-phase metrics match the dense engine bit for bit — the
    contract `benchmarks/scenario_sweep.py --engine` (windowed default)
    rides on.  `rate_limited` exercises provider dynamics (token-bucket
    429s) through both engines; `burst_train` exercises the
    nonstationary arrival warp."""

    @pytest.mark.parametrize("name", ["burst_train", "rate_limited"])
    def test_scenario_cell_metrics_bit_exact(self, name):
        cfg_dense = SimConfig(n_ticks=1000)
        cfg_win = SimConfig(n_ticks=1000, window=window_for(48))
        m_d, pm_d = run_scenario_cell(
            base_policy(), name, seeds=1, n_requests=48, sim_cfg=cfg_dense)
        m_w, pm_w = run_scenario_cell(
            base_policy(), name, seeds=1, n_requests=48, sim_cfg=cfg_win)
        for f in m_d._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(m_d, f)), np.asarray(getattr(m_w, f)),
                err_msg=f"aggregate {f}")
        for f in pm_d._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(pm_d, f)), np.asarray(getattr(pm_w, f)),
                err_msg=f"phase {f}")


class TestLoadMultiplierProperties:
    @given(
        comfort_scale=st.floats(0.2, 1.5),
        lo=st.integers(0, 20),
        step=st.integers(1, 8),
    )
    @settings(max_examples=40, deadline=None)
    def test_monotone_in_inflight_under_any_comfort_scale(
        self, comfort_scale, lo, step
    ):
        phys = default_physics()
        a = float(load_multiplier(phys, lo, comfort_scale))
        b = float(load_multiplier(phys, lo + step, comfort_scale))
        assert b >= a >= 1.0

    @given(inflight=st.integers(0, 40), scale=st.floats(0.2, 0.95))
    @settings(max_examples=40, deadline=None)
    def test_brownout_never_speeds_service(self, inflight, scale):
        """Shrinking the comfort knee can only inflate the multiplier."""
        phys = default_physics()
        base = float(load_multiplier(phys, inflight))
        brown = float(load_multiplier(phys, inflight, scale))
        assert brown >= base - 1e-6

    def test_unit_scale_is_identity(self):
        phys = default_physics()
        for i in range(0, 30, 3):
            assert float(load_multiplier(phys, i, 1.0)) == float(
                load_multiplier(phys, i))
            assert float(service_time_ms(phys, 100.0, i, 1.0, 1.0)) == float(
                service_time_ms(phys, 100.0, i, 1.0))

    def test_below_scaled_knee_unaffected(self):
        """A brownout only bites once inflight passes the *scaled* knee:
        inside the window but under the knee, service is unchanged."""
        phys = default_physics()  # comfort 4
        assert float(load_multiplier(phys, 1, 0.5)) == 1.0
        assert float(load_multiplier(phys, 2, 0.5)) == 1.0
        assert float(load_multiplier(phys, 3, 0.5)) > 1.0


class TestProviderDynamicsEngine:
    def _brownout_runs(self, scale=0.35):
        """Same seed, with and without a mid-run brownout window."""
        sc = SCENARIOS["brownout"]._replace(
            brownouts=((1 / 3, 2 / 3, scale),))
        key = jax.random.PRNGKey(0)
        policy, phys = base_policy(), default_physics()
        sim_cfg = SimConfig(n_ticks=2500)
        wl_cfg, sched, dynamics, edges = build(
            sc, 48, sim_cfg.n_ticks, sim_cfg.dt_ms)
        batch, jitter = generate(key, wl_cfg, sched)
        base = run_sim(policy, batch, jitter, phys, sim_cfg)
        brown = run_sim(policy, batch, jitter, phys, sim_cfg, dynamics)
        span = arrival_span_ms(sc, 48)
        return batch, base, brown, (span / 3, 2 * span / 3)

    @pytest.mark.slow
    def test_brownout_inflates_inside_window_only(self):
        batch, base, brown, (w0, w1) = self._brownout_runs()
        sub_b = np.asarray(base.req.submit_ms)
        sub_n = np.asarray(brown.req.submit_ms)
        fin_b = np.asarray(base.req.finish_ms)
        fin_n = np.asarray(brown.req.finish_ms)
        # prefix determinism: every decision strictly before the window
        # is identical (the schedule is exactly 1.0 there), so requests
        # submitted pre-window got identical service in both runs
        pre = np.isfinite(sub_b) & (sub_b < w0) & np.isfinite(sub_n) \
            & (sub_n < w0)
        assert pre.any()
        assert np.array_equal(fin_b[pre], fin_n[pre])
        # requests submitted inside the window got strictly slower
        # service whenever the provider sat past the scaled knee
        inside = np.isfinite(sub_n) & (sub_n >= w0) & (sub_n < w1)
        done = np.asarray(brown.req.status) == COMPLETED
        assert inside.any()
        svc_n = (fin_n - sub_n)[inside & done]
        assert svc_n.size > 0
        mean_b = np.nanmean((fin_b - sub_b)[np.isfinite(sub_b)])
        assert np.nanmean(svc_n) > mean_b

    def test_brownout_schedule_shape(self):
        s = brownout_schedule(100, 25.0, ((0.2, 0.6, 0.5),), 2000.0)
        t = (np.arange(100) + 1) * 25.0
        inside = (t >= 400.0) & (t < 1200.0)
        assert np.allclose(np.asarray(s)[inside], 0.5)
        assert np.allclose(np.asarray(s)[~inside], 1.0)

    @pytest.mark.slow
    def test_token_bucket_conserves_grants_under_burst(self):
        """Admitted sends over the horizon never exceed capacity + refill."""
        sc = Scenario(
            "tight",
            congestion="high",
            phases=(Phase(0.5, 1.8), Phase(0.5, 0.2)),  # front-loaded burst
            tb_rate_rps=0.4,
            tb_burst=3.0,
            retry_after_ms=800.0,
        )
        sim_cfg = SimConfig(n_ticks=2000)
        wl_cfg, sched, dynamics, _ = build(
            sc, 64, sim_cfg.n_ticks, sim_cfg.dt_ms)
        batch, jitter = generate(jax.random.PRNGKey(2), wl_cfg, sched)
        final = run_sim(base_policy(), batch, jitter, default_physics(),
                        sim_cfg, dynamics)
        status = np.asarray(final.req.status)
        n_admitted = np.isfinite(np.asarray(final.req.submit_ms)).sum()
        # grant budget per class: burst + total refill; K classes
        budget_per_class = 3.0 + float(np.asarray(dynamics.tb_refill).sum(0)[0])
        k = np.asarray(dynamics.tb_capacity).shape[0]
        assert n_admitted <= k * budget_per_class + 1e-6
        # the burst actually hit the limiter, and bounced work retried:
        # some throttled request later completed
        n_throttles = np.asarray(final.req.n_throttles)
        assert int(final.provider.n_throttled) == n_throttles.sum() > 0
        assert ((n_throttles > 0) & (status == COMPLETED)).any()

    @pytest.mark.slow
    def test_throttled_requests_get_retry_after(self):
        """A 429'd request is re-eligible only after retry_after_ms."""
        sc = Scenario(
            "tiny", tb_rate_rps=0.2, tb_burst=1.0, retry_after_ms=2000.0)
        sim_cfg = SimConfig(n_ticks=400)
        wl_cfg, sched, dynamics, _ = build(
            sc, 32, sim_cfg.n_ticks, sim_cfg.dt_ms)
        batch, jitter = generate(jax.random.PRNGKey(4), wl_cfg, sched)
        final = run_sim(base_policy(), batch, jitter, default_physics(),
                        sim_cfg, dynamics)
        thr = np.asarray(final.req.n_throttles) > 0
        assert thr.any()
        # a bounce never rejects and never counts as an overload defer
        assert (np.asarray(final.req.status)[thr] != REJECTED).all()

    @pytest.mark.slow
    def test_limiter_refunds_drr_deficit(self):
        """Bounced sends must not bleed the class's allocation share:
        with the limiter throttling everything, deficits stay finite and
        no request is silently admitted."""
        dynamics = ProviderDynamics(
            comfort_scale=None,
            tb_refill=jnp.zeros((300, 2), jnp.float32),
            tb_capacity=jnp.zeros((2,), jnp.float32),
            retry_after_ms=jnp.float32(100.0),
        )
        sim_cfg = SimConfig(n_ticks=300)
        batch, jitter = generate(
            jax.random.PRNGKey(5), WorkloadConfig(n_requests=24))
        final = run_sim(base_policy(), batch, jitter, default_physics(),
                        sim_cfg, dynamics)
        assert not np.isfinite(np.asarray(final.req.submit_ms)).any()
        # nothing was ever admitted or rejected; the drain abandons the
        # starved pending work
        status = np.asarray(final.req.status)
        assert ((status == PENDING) | (status == ABANDONED)).all()
        assert np.isfinite(np.asarray(final.sched.deficit)).all()
        assert int(final.provider.n_throttled) > 0

    def test_token_bucket_schedule_shapes(self):
        refill, cap = token_bucket_schedule(50, 25.0, (2.0, 1.0), 6.0)
        assert refill.shape == (50, 2) and cap.shape == (2,)
        assert np.allclose(np.asarray(refill)[0], [0.05, 0.025])
        assert np.allclose(np.asarray(cap), 6.0)

    def test_token_bucket_windows_scales_refill(self):
        """Piecewise refill: inside the window the sustained rate drops
        by the multiplier, outside it matches the constant builder;
        overlapping windows compound by minimum."""
        span = 50 * 25.0
        refill, cap = token_bucket_windows(
            50, 25.0, (2.0, 1.0), 6.0,
            ((0.2, 0.6, 0.5), (0.4, 0.8, 0.25)), span)
        base, _ = token_bucket_schedule(50, 25.0, (2.0, 1.0), 6.0)
        refill, base = np.asarray(refill), np.asarray(base)
        assert refill.shape == (50, 2)
        t_frac = (np.arange(50) + 1.0) / 50.0
        outside = (t_frac < 0.2) | (t_frac >= 0.8)
        assert np.array_equal(refill[outside], base[outside])
        only_first = (t_frac >= 0.2) & (t_frac < 0.4)
        assert np.allclose(refill[only_first], 0.5 * base[only_first])
        overlap = (t_frac >= 0.4) & (t_frac < 0.6)
        assert np.allclose(refill[overlap], 0.25 * base[overlap])
        assert np.allclose(np.asarray(cap), 6.0)  # burst untouched

    def test_token_bucket_windows_rejects_negative_mult(self):
        with pytest.raises(ValueError, match="rate_mult"):
            token_bucket_windows(10, 25.0, (1.0,), 2.0,
                                 ((0.0, 1.0, -0.5),), 250.0)

    def test_time_varying_refill_conserves_grants(self):
        """Conservation under a mid-run refill freeze: admitted sends
        never exceed burst + the *windowed* refill integral (strictly
        below the constant-rate budget), and the crunch window shows up
        as a throttle spike."""
        sc = Scenario(
            "crunch_test",
            congestion="high",
            phases=(Phase(0.5, 1.0), Phase(0.5, 1.0)),
            tb_rate_rps=0.8,
            tb_burst=3.0,
            tb_windows=((0.25, 0.75, 0.0),),  # refill freeze mid-run
            retry_after_ms=600.0,
        )
        sim_cfg = SimConfig(n_ticks=2000)
        wl_cfg, sched, dynamics, _ = build(
            sc, 64, sim_cfg.n_ticks, sim_cfg.dt_ms)
        batch, jitter = generate(jax.random.PRNGKey(2), wl_cfg, sched)
        final = run_sim(base_policy(), batch, jitter, default_physics(),
                        sim_cfg, dynamics)
        refill = np.asarray(dynamics.tb_refill)
        n_admitted = np.isfinite(np.asarray(final.req.submit_ms)).sum()
        k = refill.shape[1]
        windowed_budget = k * (3.0 + float(refill.sum(0)[0]))
        constant_budget = k * (3.0 + 0.8 * (25.0 / 1000.0) * 2000)
        assert windowed_budget < constant_budget  # the freeze bites
        assert n_admitted <= windowed_budget + 1e-6
        assert int(final.provider.n_throttled) > 0

    def test_rate_crunch_scenario_runs(self):
        """The registry scenario exercising tb_windows end to end."""
        m, _ = run_scenario_cell(
            base_policy(), "rate_crunch", seeds=1, n_requests=48,
            sim_cfg=SimConfig(n_ticks=1200))
        assert np.isfinite(np.asarray(m.completion_rate)).all()

    def test_limiter_sized_by_policy_classes(self):
        """A policy carrying more classes than the lane scheme must run
        rate-limited scenarios: the bucket vectors are sized by the
        policy's K (the engine's bucket state), not the workload's."""
        from repro.core.policy import kclass_policy
        m, pm = run_scenario_cell(
            kclass_policy(4), "rate_limited", seeds=1, n_requests=24,
            sim_cfg=SimConfig(n_ticks=300))
        assert np.isfinite(np.asarray(m.completion_rate)).all()


class TestPhaseMetrics:
    @pytest.mark.slow
    def test_phase_metrics_match_numpy(self):
        m, pm = run_scenario_cell(
            base_policy(), "burst_train", seeds=1, n_requests=64,
            sim_cfg=SimConfig(n_ticks=2000))
        sc = get_scenario("burst_train")
        edges = np.asarray(phase_edges_ms(sc, 64))
        # reconstruct one seed by hand
        wl_cfg, sched, dynamics, _ = build(sc, 64, 2000, 25.0)
        batch, jitter = generate(jax.random.PRNGKey(0), wl_cfg, sched)
        final = run_sim(base_policy(), batch, jitter, default_physics(),
                        SimConfig(n_ticks=2000), dynamics)
        a = np.asarray(batch.arrival_ms)
        status = np.asarray(final.req.status)
        phase = np.clip(np.searchsorted(edges, a, side="right") - 1, 0,
                        len(edges) - 2)
        n_arr = np.asarray(pm.n_arrived)[0]
        n_done = np.asarray(pm.n_completed)[0]
        for p in range(len(edges) - 1):
            assert n_arr[p] == (phase == p).sum()
            assert n_done[p] == ((phase == p) & (status == COMPLETED)).sum()
        assert n_arr.sum() == 64

    @pytest.mark.slow
    def test_phase_axes_shapes(self):
        m, pm = run_scenario_cell(
            base_policy(), "diurnal", seeds=2, n_requests=32,
            sim_cfg=SimConfig(n_ticks=800))
        assert pm.p95_ms.shape == (2, 7)
        assert pm.class_p95_ms.shape == (2, 7, 2)
        assert pm.shed_by_bucket.shape == (2, 7, 4)
        assert pm.class_satisfaction.shape == (2, 7, 2)

    @pytest.mark.slow
    def test_aggregate_metrics_still_consistent(self):
        """compute_metrics on a scenario run obeys the same invariants."""
        sc = get_scenario("rate_limited")
        sim_cfg = SimConfig(n_ticks=2400)
        wl_cfg, sched, dynamics, edges = build(
            sc, 48, sim_cfg.n_ticks, sim_cfg.dt_ms)
        batch, jitter = generate(jax.random.PRNGKey(1), wl_cfg, sched)
        final = run_sim(base_policy(), batch, jitter, default_physics(),
                        sim_cfg, dynamics)
        met = compute_metrics(batch, final)
        pmet = compute_phase_metrics(batch, final, edges)
        status = np.asarray(final.req.status)
        assert int(met.n_rejects) == (status == REJECTED).sum()
        assert (np.asarray(pmet.shed_by_bucket).sum()
                == (status == REJECTED).sum())
        assert (np.asarray(pmet.n_completed).sum()
                == (status == COMPLETED).sum())


class TestRegistry:
    def test_registry_is_rich_enough(self):
        assert len(SCENARIOS) >= 6
        # at least one of each mechanism
        assert any(len(s.phases) > 1 for s in SCENARIOS.values())
        assert any(s.brownouts for s in SCENARIOS.values())
        assert any(s.tb_rate_rps is not None for s in SCENARIOS.values())
        assert any(
            p.mix is not None for s in SCENARIOS.values() for p in s.phases)

    def test_scenarios_are_hashable_static_specs(self):
        for sc in SCENARIOS.values():
            hash(sc)

    def test_mean_rate_multiplier_is_one(self):
        """Offered work matches the stationary regime of the same name."""
        for sc in SCENARIOS.values():
            mean = sum(p.frac * p.rate_mult for p in sc.phases)
            assert mean == pytest.approx(1.0, abs=1e-6), sc.name

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            get_scenario("nope")
