"""Tests for the K-class generalization of the three-layer stack.

Covers the acceptance points of the K-class refactor:
  (a) the vectorized K=2 scheduler reproduces the seed two-lane
      implementation's `SlotDecision`s bit-exactly (a verbatim port of
      the seed's per-class Python-loop scheduler serves as reference);
  (b) DRR deficit conservation — the refund on defer/reject — holds at
      K=8;
  (c) `masked_percentile` respects `RequestBatch.valid` padding.
Plus scheme plumbing: lane-scheme parsing, tenant assignment leaving
the base random streams untouched, and policy/workload K mismatch
detection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import drr, ordering, overload
from repro.core.policy import (
    base_policy,
    kclass_policy,
    n_classes,
    per_bucket_policy,
    strategy,
)
from repro.core.scheduler import IDLE, effective_class, schedule_slot
from repro.core.types import INFLIGHT, RequestBatch, SHORT, init_sim_state
from repro.sim import SimConfig, WorkloadConfig, compute_metrics, run_cell
from repro.sim.engine import run_sim
from repro.sim.metrics import masked_percentile
from repro.sim.provider import default_physics
from repro.sim.workload import generate, n_classes_of


def mk_batch(n=8, arrival=None, bucket=None, p50=None, cls=None, valid=None):
    arrival = jnp.asarray(
        arrival if arrival is not None else np.arange(n) * 10.0, jnp.float32)
    bucket = jnp.asarray(bucket if bucket is not None else np.zeros(n), jnp.int32)
    p50 = jnp.asarray(p50 if p50 is not None else np.full(n, 100.0), jnp.float32)
    if cls is None:
        cls = jnp.where(bucket == SHORT, 0, 1).astype(jnp.int32)
    else:
        cls = jnp.asarray(cls, jnp.int32)
    valid = (jnp.ones((n,), bool) if valid is None
             else jnp.asarray(valid, bool))
    return RequestBatch(
        arrival_ms=arrival,
        bucket=bucket,
        cls=cls,
        true_tokens=p50,
        p50=p50,
        p90=p50 * 1.8,
        deadline_budget_ms=jnp.full((n,), 5000.0, jnp.float32),
        valid=valid,
    )


# ---------------------------------------------------------------------------
# (a) Seed-reference bit-exactness at K=2
# ---------------------------------------------------------------------------
# The functions below are a verbatim port of the seed's two-lane scheduler
# (per-class Python loop, hardcoded N_CLASSES=2, [::-1] borrowing) kept as
# the behavioral oracle for the vectorized class axis.

_SEED_N_CLASSES = 2


def _seed_effective_weights(cfg, severity):
    w = cfg.drr_weights
    scale = jnp.asarray([1.0 + cfg.congestion_kappa * severity, 1.0])
    return w * scale


def _seed_allocate(cfg, *, backlog, head_cost, inflight_cls, inflight_total,
                   severity, deficit, rr_turn):
    under_cap = inflight_total < cfg.max_inflight
    cap_eff = cfg.class_cap * jnp.asarray(
        [1.0, jnp.maximum(1.0 - cfg.cap_kappa * jnp.minimum(severity, 1.2), 0.3)]
    )
    cap_eff = jnp.maximum(cap_eff, 1.0)
    open_cls = inflight_cls < cap_eff
    has_work = (backlog > 0) & open_cls
    mode = int(cfg.alloc_mode)

    if mode == 0:  # naive
        return (jnp.int32(0), (backlog > 0).any() & under_cap,
                jnp.asarray(True), deficit, rr_turn)
    if mode == 1:  # quota
        cls_id = jnp.where(has_work[0], 0, 1)
        return (jnp.int32(cls_id), has_work.any() & under_cap,
                jnp.asarray(False), deficit, rr_turn)
    if mode == 2:  # adrr
        w_eff = _seed_effective_weights(cfg, severity)
        accrue = cfg.drr_quantum * w_eff * has_work
        lone = has_work & (~has_work[::-1])
        borrow = cfg.drr_quantum * w_eff[::-1] * lone
        d = jnp.minimum(deficit + accrue + borrow, cfg.deficit_cap)
        affordable = has_work & (d >= jnp.minimum(head_cost, cfg.deficit_cap))
        pref = jnp.where(
            affordable, d * cfg.drr_weights / cfg.drr_weights.sum(), -jnp.inf)
        cls_id = jnp.argmax(pref)
        ok = affordable.any() & under_cap
        d = jnp.where(
            ok, d - jax.nn.one_hot(cls_id, _SEED_N_CLASSES) * head_cost[cls_id], d)
        d = jnp.where(has_work, d, 0.0)
        return jnp.int32(cls_id), ok, jnp.asarray(False), d, rr_turn
    if mode == 3:  # fq
        first = rr_turn % _SEED_N_CLASSES
        second = (rr_turn + 1) % _SEED_N_CLASSES
        cls_id = jnp.where(has_work[first], first, second)
        ok = has_work.any() & under_cap
        turn = jnp.where(ok, cls_id + 1, rr_turn)
        return jnp.int32(cls_id), ok, jnp.asarray(False), deficit, jnp.int32(turn)
    # sp
    cls_id = jnp.where(has_work[0], 0, 1)
    return (jnp.int32(cls_id), has_work.any() & under_cap,
            jnp.asarray(False), deficit, rr_turn)


def _seed_select_for_class(batch, mask, c, now, cfg):
    fifo_idx, fifo_any = ordering.select_fifo(batch, mask)
    sc_idx, sc_any = ordering.select_scored(batch, mask, now, cfg)
    use_score = c == 1
    return (jnp.where(use_score, sc_idx, fifo_idx),
            jnp.where(use_score, sc_any, fifo_any))


def _seed_schedule_slot(cfg, batch, state):
    """Verbatim port of the seed two-lane schedule_slot (Python loop)."""
    now = state.now_ms
    elig = ordering.eligibility(batch, state.req.status, state.req.defer_until, now)
    eff_cls = jnp.where(cfg.route_by_class > 0, batch.cls, 0).astype(jnp.int32)

    cand_idx, cand_ok, head_cost = [], [], []
    for c in range(_SEED_N_CLASSES):
        mask = elig & (eff_cls == c)
        idx, ok = _seed_select_for_class(batch, mask, c, now, cfg)
        cand_idx.append(idx)
        cand_ok.append(ok)
        head_cost.append(jnp.where(ok, batch.p50[idx], jnp.inf))
    cand_idx = jnp.stack(cand_idx)
    cand_ok = jnp.stack(cand_ok)
    head_cost = jnp.stack(head_cost)

    backlog = jnp.stack(
        [(elig & (eff_cls == c)).sum() for c in range(_SEED_N_CLASSES)]
    ).astype(jnp.int32)
    inflight_mask = state.req.status == INFLIGHT
    inflight_cls = jnp.stack(
        [(inflight_mask & (eff_cls == c)).sum() for c in range(_SEED_N_CLASSES)]
    ).astype(jnp.int32)
    inflight_total = state.provider.inflight

    sev = overload.severity_score(
        cfg, inflight_total=inflight_total, n_pending=elig.sum(),
        ema_latency_ratio=state.sched.ema_latency_ratio)

    cls_id, send_ok, ignore_class, deficit, rr_turn = _seed_allocate(
        cfg, backlog=backlog, head_cost=head_cost, inflight_cls=inflight_cls,
        inflight_total=inflight_total, severity=sev,
        deficit=state.sched.deficit, rr_turn=state.sched.rr_turn)

    fifo_idx, fifo_ok = ordering.select_fifo(batch, elig)
    idx = jnp.where(ignore_class, fifo_idx, cand_idx[cls_id])
    ok = jnp.where(ignore_class, fifo_ok, cand_ok[cls_id]) & send_ok

    act = overload.admission_action(
        cfg, severity=sev, bucket=batch.bucket[idx],
        n_defers=state.req.n_defers[idx])
    action = jnp.where(ok, act, IDLE).astype(jnp.int32)

    refund = (
        jax.nn.one_hot(cls_id, _SEED_N_CLASSES)
        * head_cost[cls_id]
        * ((action == overload.DEFER) | (action == overload.REJECT))
        * (~ignore_class)
    )
    deficit = jnp.where(
        jnp.isfinite(deficit + refund), deficit + refund, deficit)
    return action, idx.astype(jnp.int32), sev, deficit, rr_turn


def _mixed_batch(n=24, seed=0):
    rng = np.random.default_rng(seed)
    arrival = np.sort(rng.uniform(0, 400.0, n)).astype(np.float32)
    bucket = rng.integers(0, 4, n)
    p50 = np.float32([60, 150, 600, 2000])[bucket] * rng.uniform(0.7, 1.3, n)
    return mk_batch(n, arrival=arrival, bucket=bucket, p50=np.float32(p50))


@pytest.mark.slow
class TestSeedBitExact:
    @pytest.mark.parametrize("name", [
        "final_adrr_olc", "adaptive_drr", "fair_queuing", "short_priority",
        "quota_tiered", "direct_naive",
    ])
    def test_decisions_match_seed_reference(self, name):
        """Drive a sequence of slots with engine-style state updates and
        require identical action/req_idx/deficit/rr_turn to the seed port
        whenever the slot is live (idle slots leave no trace in the
        engine, and the seed's dead-branch cls_id differs by design)."""
        cfg = strategy(name)
        batch = _mixed_batch()
        state = init_sim_state(batch.n)._replace(
            now_ms=jnp.float32(50.0),
            sched=init_sim_state(batch.n).sched._replace(
                ema_latency_ratio=jnp.float32(2.5)),  # non-trivial severity
        )
        live_slots = 0
        for step in range(40):
            d = schedule_slot(cfg, batch, state)
            ra, ri, rs, rd, rt = _seed_schedule_slot(cfg, batch, state)
            assert int(d.action) == int(ra), f"step {step}: action diverged"
            if int(d.action) != IDLE:
                live_slots += 1
                assert int(d.req_idx) == int(ri), f"step {step}: idx diverged"
            assert np.array_equal(np.asarray(d.deficit), np.asarray(rd)), (
                f"step {step}: deficit diverged: {d.deficit} vs {rd}")
            # the seed stored an unwrapped FQ pointer (cls_id + 1, which
            # can reach K) and re-moduloed it on read; the fixed scheduler
            # stores (cls_id + 1) % K — identical rotation, wrapped store
            assert int(d.rr_turn) == int(rt) % _SEED_N_CLASSES
            assert float(d.severity) == float(rs)

            # engine-style transition so the state stream stays shared
            state = state._replace(
                sched=state.sched._replace(deficit=d.deficit, rr_turn=d.rr_turn))
            if int(d.action) == overload.ADMIT:
                i = int(d.req_idx)
                state = state._replace(
                    req=state.req._replace(
                        status=state.req.status.at[i].set(INFLIGHT)),
                    provider=state.provider._replace(
                        inflight=state.provider.inflight + 1))
            elif int(d.action) == overload.DEFER:
                i = int(d.req_idx)
                state = state._replace(req=state.req._replace(
                    defer_until=state.req.defer_until.at[i].set(
                        state.now_ms + 100.0),
                    n_defers=state.req.n_defers.at[i].add(1)))
            if step % 8 == 7:
                # drain the provider so caps reopen and sends keep flowing
                state = state._replace(
                    req=state.req._replace(status=jnp.where(
                        state.req.status == INFLIGHT, 2, state.req.status)),
                    provider=state.provider._replace(
                        inflight=jnp.int32(0)))
            state = state._replace(now_ms=state.now_ms + jnp.float32(25.0))
        if name not in ("direct_naive",):
            assert live_slots > 5  # the comparison actually exercised sends

    def test_full_sim_matches_seed_reference_metrics(self):
        """End-to-end: per-class K=2 metrics equal the seed's bucket-keyed
        scalars where they alias (lane 0 == short bucket under paper2)."""
        wl = WorkloadConfig(n_requests=48, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(3), wl)
        final = run_sim(strategy("final_adrr_olc"), batch, jitter,
                        default_physics(), SimConfig(n_ticks=1500))
        m = compute_metrics(batch, final)
        lat = np.asarray(final.req.finish_ms - batch.arrival_ms)
        done = np.asarray(final.req.status) == 2
        short = done & (np.asarray(batch.bucket) == SHORT)
        if short.sum() > 0:
            ref = float(np.quantile(lat[short], 0.95, method="inverted_cdf"))
            assert float(m.class_p95_ms[0]) == pytest.approx(ref, rel=1e-5)
            assert float(m.class_p95_ms[0]) == pytest.approx(
                float(m.short_p95_ms), rel=1e-5)


# ---------------------------------------------------------------------------
# (b) DRR deficit conservation (refund on defer/reject) at K=8
# ---------------------------------------------------------------------------

class TestDeficitConservationK8:
    def _k8_setup(self, reject=False):
        k = 8
        # thresholds so severe that any heavy candidate defers (or rejects)
        thr = 0.01 if not reject else 10.0
        rej = 10.0 if not reject else 0.01
        cfg = kclass_policy(
            k,
            defer_thr=jnp.asarray([jnp.inf, thr, thr, thr], jnp.float32),
            reject_thr=jnp.asarray([jnp.inf, rej, rej, rej], jnp.float32),
        )
        n = 32
        rng = np.random.default_rng(1)
        bucket = rng.integers(1, 4, n)  # no shorts: every pick can block
        batch = mk_batch(
            n,
            arrival=np.sort(rng.uniform(0, 50.0, n)).astype(np.float32),
            bucket=bucket,
            p50=np.float32([0, 150, 600, 2000])[bucket],
            cls=rng.integers(0, k, n),
        )
        state = init_sim_state(n, k)._replace(
            now_ms=jnp.float32(100.0),
            sched=init_sim_state(n, k).sched._replace(
                ema_latency_ratio=jnp.float32(3.0),
                deficit=jnp.full((k,), 4000.0, jnp.float32)),
        )
        return cfg, batch, state

    @pytest.mark.parametrize("reject", [False, True])
    @pytest.mark.slow
    def test_refund_restores_charged_deficit(self, reject):
        cfg, batch, state = self._k8_setup(reject)
        d = schedule_slot(cfg, batch, state)
        want = overload.REJECT if reject else overload.DEFER
        assert int(d.action) == want

        # reconstruct the allocation inputs and replay layer 1 alone
        elig = ordering.eligibility(
            batch, state.req.status, state.req.defer_until, state.now_ms)
        eff = effective_class(cfg, batch)
        k = n_classes(cfg)
        kn = (eff[None, :] == jnp.arange(k)[:, None]) & elig[None, :]
        cand_idx, cand_ok = ordering.select_per_class(
            batch, kn, state.now_ms, cfg)
        head_cost = jnp.where(cand_ok, batch.p50[cand_idx], jnp.inf)
        sev = overload.severity_score(
            cfg, inflight_total=state.provider.inflight,
            n_pending=elig.sum(),
            ema_latency_ratio=state.sched.ema_latency_ratio)
        choice = drr.allocate(
            cfg, backlog=kn.sum(axis=1).astype(jnp.int32),
            head_cost=head_cost,
            inflight_cls=jnp.zeros((k,), jnp.int32),
            inflight_total=state.provider.inflight, severity=sev,
            deficit=state.sched.deficit, rr_turn=state.sched.rr_turn)
        assert bool(choice.send_ok)
        c = int(choice.cls_id)
        # layer 1 charged head_cost; the overload block must have refunded
        # it exactly — deficit conservation across the blocked release
        charged = np.asarray(choice.deficit)
        refunded = np.asarray(d.deficit)
        expect = charged.copy()
        expect[c] += float(head_cost[c])
        np.testing.assert_allclose(refunded, expect, rtol=0, atol=0)

    def test_admit_path_keeps_charge(self):
        """When the release goes through, the charge is NOT refunded."""
        cfg, batch, state = self._k8_setup()
        cfg = cfg._replace(olc_enabled=jnp.float32(0.0))  # always admit
        d0 = schedule_slot(cfg, batch, state)
        assert int(d0.action) == overload.ADMIT
        i = int(d0.req_idx)
        c = int(effective_class(cfg, batch)[i])
        # the admitted class paid p50 out of its (accrued, capped) deficit:
        # its balance sits below the cap by at least the head cost
        assert float(d0.deficit[c]) <= float(cfg.deficit_cap) - float(
            batch.p50[i]) + 1e-3


# ---------------------------------------------------------------------------
# (c) masked_percentile honors the valid mask / padding
# ---------------------------------------------------------------------------

class TestMaskedPercentilePadding:
    def test_padding_excluded(self):
        vals = jnp.asarray([1.0, 2.0, 3.0, 4.0, 1e9, 1e9], jnp.float32)
        mask = jnp.asarray([True, True, True, True, False, False])
        out = float(masked_percentile(vals, mask, 0.95))
        assert out == pytest.approx(4.0)

    def test_metrics_ignore_padded_requests(self):
        """A padded (valid=False) slot with garbage latency must not leak
        into any per-class or scalar metric."""
        n = 8
        batch = mk_batch(
            n,
            arrival=np.zeros(n, np.float32),
            bucket=[0, 0, 1, 2, 3, 0, 0, 0],
            cls=[0, 0, 1, 1, 1, 0, 0, 0],
            valid=[True, True, True, True, True, False, False, False],
        )
        state = init_sim_state(n)
        # mark everything completed; padded slots get absurd latencies
        finish = jnp.asarray(
            [100.0, 200.0, 300.0, 400.0, 500.0, 1e8, 1e8, 1e8], jnp.float32)
        state = state._replace(req=state.req._replace(
            status=jnp.full((n,), 2, jnp.int32), finish_ms=finish))
        m = compute_metrics(batch, state)
        assert float(m.class_p95_ms[0]) == pytest.approx(200.0)
        assert float(m.class_p95_ms[1]) == pytest.approx(500.0)
        assert float(m.global_p95_ms) == pytest.approx(500.0)
        assert int(m.class_n_requests.sum()) == 5

    def test_all_padded_class_is_nan(self):
        batch = mk_batch(4, cls=[0, 0, 0, 0])
        state = init_sim_state(4)
        m = compute_metrics(batch, state)  # nothing completed
        assert np.isnan(float(m.class_p95_ms[1]))

    def test_metrics_infer_k_from_state(self):
        """A direct compute_metrics call must not merge K=8 lanes into a
        2-class view: K is inferred from the deficit vector."""
        batch = mk_batch(8, cls=np.arange(8))
        state = init_sim_state(8, 8)
        m = compute_metrics(batch, state)
        assert m.class_p95_ms.shape == (8,)
        assert np.array_equal(np.asarray(m.class_n_requests), np.ones(8))


# ---------------------------------------------------------------------------
# Lane schemes + K plumbing
# ---------------------------------------------------------------------------

class TestLaneSchemes:
    def test_n_classes_of(self):
        assert n_classes_of("paper2") == 2
        assert n_classes_of("bucket4") == 4
        assert n_classes_of("tenant8") == 8
        with pytest.raises(ValueError):
            n_classes_of("nope")
        with pytest.raises(ValueError):
            n_classes_of("tenant0")

    @pytest.mark.slow
    def test_tenant_assignment_preserves_base_streams(self):
        """tenant<K> draws from a folded key: every other field must stay
        bit-identical to the paper2 (seed) generator."""
        key = jax.random.PRNGKey(11)
        a, _ = generate(key, WorkloadConfig(n_requests=64))
        b, _ = generate(key, WorkloadConfig(n_requests=64, class_map="tenant4"))
        for field in ("arrival_ms", "bucket", "true_tokens", "p50", "p90"):
            assert np.array_equal(
                np.asarray(getattr(a, field)), np.asarray(getattr(b, field))), field
        assert np.asarray(b.cls).min() >= 0 and np.asarray(b.cls).max() <= 3
        assert np.unique(np.asarray(b.cls)).size > 1

    def test_bucket4_maps_identity(self):
        b, _ = generate(jax.random.PRNGKey(0),
                        WorkloadConfig(n_requests=64, class_map="bucket4"))
        assert np.array_equal(np.asarray(b.cls), np.asarray(b.bucket))

    def test_policy_workload_k_mismatch_raises(self):
        wl = WorkloadConfig(n_requests=16, class_map="tenant8")
        with pytest.raises(ValueError, match="tenant8"):
            run_cell(base_policy(), wl, seeds=1, sim_cfg=SimConfig(n_ticks=10))

    def test_kclass_policy_validation(self):
        with pytest.raises(ValueError):
            kclass_policy(0)
        with pytest.raises(ValueError):
            kclass_policy(4, weights=[1.0, 2.0])
        cfg = per_bucket_policy()
        assert n_classes(cfg) == 4
        assert cfg.class_cap.shape == (4,)

    @pytest.mark.slow
    def test_k8_full_sim_terminates_and_accounts(self):
        """Every request reaches a terminal state at K=8 and per-class
        counts partition the batch."""
        wl = WorkloadConfig(n_requests=48, mix="heavy", congestion="high",
                            class_map="tenant8")
        m = run_cell(kclass_policy(8), wl, seeds=2,
                     sim_cfg=SimConfig(n_ticks=1500))
        assert m.class_p95_ms.shape == (2, 8)
        assert np.array_equal(
            np.asarray(m.class_n_requests.sum(axis=1)), [48, 48])

    def test_schedule_slot_trace_has_no_class_loop(self):
        """Acceptance criterion: trace size is O(1) in K — the jaxpr for
        K=8 must not blow up 4x over K=2 (a per-class Python loop would)."""
        b2 = mk_batch(16)
        b8 = mk_batch(16, cls=np.arange(16) % 8)
        s2 = init_sim_state(16, 2)._replace(now_ms=jnp.float32(500.0))
        s8 = init_sim_state(16, 8)._replace(now_ms=jnp.float32(500.0))
        n2 = len(jax.make_jaxpr(schedule_slot)(base_policy(), b2, s2).eqns)
        n8 = len(jax.make_jaxpr(schedule_slot)(kclass_policy(8), b8, s8).eqns)
        assert n8 <= n2 + 5  # identical modulo constant plumbing
