"""Fleet dispatch pins (DESIGN.md §10).

Contracts under test:

- **P=1 transparency** — a single-endpoint fleet is bit-exact with
  today's single-provider engine: same decision stream, same request
  arrays, same service-time bit patterns.  The fleet axis must be a
  pure generalization, not a parallel implementation.
- **Dense/windowed parity at P>1** — routing, the per-endpoint
  limiter, and the failover requeue all ride the windowed engine's
  bit-exact contract.
- **Failover** — killing an endpoint mid-run requeues its in-flight
  work (visible in `FleetState.n_requeued` and per-request throttle
  counts) and the run still drains every request to a terminal state.
- **Skew** — routing sends more traffic to faster endpoints.
- **FleetProvider** — the live-path adapter routes, drains down
  endpoints gracefully, merges completions in ticket order, and
  passes through transparently at P=1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import base_policy, strategy
from repro.core.routing import UNAVAIL_MS, route_requests
from repro.core.types import COMPLETED, init_fleet_state
from repro.sim import (
    Fleet,
    FleetDynamics,
    SimConfig,
    WorkloadConfig,
    default_physics,
    generate,
    run_sim,
    uniform_fleet_physics,
)
from repro.sim import scenarios as scn

REQ_FIELDS = ("status", "submit_ms", "finish_ms", "defer_until",
              "n_defers", "n_throttles")


def _bits_equal(a, b):
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def _mk_fleet(p, speed_mult=None, comfort_mult=None, avail=None,
              tb_refill=None, tb_capacity=None, retry_after_ms=1500.0):
    fphys = uniform_fleet_physics(default_physics(), p,
                                  speed_mult=speed_mult,
                                  comfort_mult=comfort_mult)
    dyn = FleetDynamics(avail=avail, comfort_scale=None,
                        tb_refill=tb_refill, tb_capacity=tb_capacity,
                        retry_after_ms=jnp.float32(retry_after_ms))
    return Fleet(phys=fphys, dyn=dyn)


def _assert_same_run(a, b, *, compare_endpoint=False):
    """Request arrays, scheduler floats, and decision stream bit-equal
    between two (final, trace) run_sim results."""
    (fa, ta), (fb, tb) = a, b
    for name in REQ_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(fa.req, name)),
            np.asarray(getattr(fb.req, name)), err_msg=name)
    if compare_endpoint:
        np.testing.assert_array_equal(
            np.asarray(fa.req.endpoint), np.asarray(fb.req.endpoint))
    assert _bits_equal(fa.sched.ema_latency_ratio, fb.sched.ema_latency_ratio)
    assert _bits_equal(fa.sched.deficit, fb.sched.deficit)
    assert int(fa.sched.rr_turn) == int(fb.sched.rr_turn)
    a_act, b_act = np.asarray(ta[0]), np.asarray(tb[0])
    np.testing.assert_array_equal(a_act, b_act)
    from repro.core.scheduler import IDLE
    a_idx = np.where(a_act == IDLE, -1, np.asarray(ta[1]))
    b_idx = np.where(b_act == IDLE, -1, np.asarray(tb[1]))
    np.testing.assert_array_equal(a_idx, b_idx)
    assert _bits_equal(ta[2], tb[2])


class TestP1Transparency:
    """fleet=Fleet(P=1) must compile to the single-provider program."""

    @pytest.mark.parametrize("seed", [0, 1])
    def test_bit_exact_with_plain_engine(self, seed):
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=120, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(seed), wl)
        phys = default_physics()
        sim_cfg = SimConfig(n_ticks=2000, k_slots=4)
        plain = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg,
            collect_decisions=True))()
        fleet = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg,
            fleet=_mk_fleet(1), collect_decisions=True))()
        _assert_same_run(plain, fleet)
        # service times bit-identical: the P==1 gather must reproduce
        # the exact scalar physics program, not a re-rounded variant
        assert _bits_equal(plain[0].req.finish_ms, fleet[0].req.finish_ms)
        assert int((np.asarray(plain[0].req.status) == COMPLETED).sum()) > 10
        # the fleet run carries its bookkeeping without disturbance
        assert fleet[0].fleet is not None
        assert int(np.asarray(fleet[0].fleet.n_requeued).sum()) == 0

    def test_bit_exact_windowed(self):
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=96, mix="balanced",
                            congestion="medium")
        batch, jitter = generate(jax.random.PRNGKey(2), wl)
        phys = default_physics()
        sim_cfg = SimConfig(n_ticks=2000, k_slots=4, window=128)
        plain = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg,
            collect_decisions=True))()
        fleet = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg,
            fleet=_mk_fleet(1), collect_decisions=True))()
        _assert_same_run(plain, fleet)


class TestFleetEngineParity:
    """Dense vs windowed at P>1: the fleet layers ride the bit-exact
    window contract."""

    def _run_pair(self, policy, batch, jitter, sim_cfg, window, fleet):
        phys = default_physics()
        dense = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg, fleet=fleet,
            collect_decisions=True))()
        win = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg._replace(window=window),
            fleet=fleet, collect_decisions=True))()
        return dense, win

    def test_p4_uniform(self, fleet_batch=None):
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=120, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(3), wl)
        pair = self._run_pair(policy, batch, jitter,
                              SimConfig(n_ticks=2000, k_slots=4),
                              window=160, fleet=_mk_fleet(4))
        _assert_same_run(*pair, compare_endpoint=True)
        d, w = pair[0][0].fleet, pair[1][0].fleet
        np.testing.assert_array_equal(np.asarray(d.inflight),
                                      np.asarray(w.inflight))
        np.testing.assert_array_equal(np.asarray(d.n_requeued),
                                      np.asarray(w.n_requeued))

    def test_p4_failover_requeues_and_recovers(self):
        """Endpoint 0 dies for ticks [400, 1200): its in-flight work is
        requeued (PENDING + Retry-After defer + throttle bump), both
        engines agree, and the horizon still completes everything."""
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=120, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(4), wl)
        T = 6000
        avail = jnp.ones((T, 4), jnp.float32).at[400:1200, 0].set(0.0)
        fleet = _mk_fleet(4, avail=avail)
        pair = self._run_pair(policy, batch, jitter,
                              SimConfig(n_ticks=T, k_slots=4),
                              window=160, fleet=fleet)
        _assert_same_run(*pair, compare_endpoint=True)
        final = pair[0][0]
        requeued = np.asarray(final.fleet.n_requeued)
        assert requeued.sum() > 0          # the failover actually bit
        assert requeued[1:].sum() == 0     # only the dead endpoint
        np.testing.assert_array_equal(
            requeued, np.asarray(pair[1][0].fleet.n_requeued))
        # requeued work carries the throttle bump; every request still
        # reaches a terminal state (heavy/high legitimately abandons a
        # tail — the outage must not strand anyone mid-flight)
        from repro.core.types import ABANDONED, REJECTED
        st = np.asarray(final.req.status)
        assert ((st == COMPLETED) | (st == REJECTED)
                | (st == ABANDONED)).all()
        assert int((st == COMPLETED).sum()) > 90
        assert int(np.asarray(final.req.n_throttles).sum()) >= requeued.sum()

    def test_p4_per_endpoint_token_bucket(self):
        """A starved bucket on every endpoint throttles grants
        per-(endpoint, class); counts agree dense vs windowed."""
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=120, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(5), wl)
        # starvation math: ~15 requests land per (endpoint, class) bucket
        # but refill only grants ~4 tokens over the horizon, so some
        # admits must bounce off the limiter
        T, P, K = 4000, 4, 2
        refill = jnp.full((T, P, K), 0.001, jnp.float32)
        cap = jnp.full((P, K), 1.0, jnp.float32)
        fleet = _mk_fleet(4, tb_refill=refill, tb_capacity=cap)
        pair = self._run_pair(policy, batch, jitter,
                              SimConfig(n_ticks=T, k_slots=4),
                              window=160, fleet=fleet)
        _assert_same_run(*pair, compare_endpoint=True)
        thr = np.asarray(pair[0][0].fleet.n_throttled)
        assert thr.sum() > 0
        np.testing.assert_array_equal(
            thr, np.asarray(pair[1][0].fleet.n_throttled))


class TestRoutingBehavior:
    def test_skew_prefers_fast_endpoints(self):
        """speed_mult (0.5, 1, 1, 2): the cheapest-cost endpoint takes
        the most completions, the 2x-slow one the least."""
        policy = strategy("final_adrr_olc")
        wl = WorkloadConfig(n_requests=160, mix="heavy", congestion="high")
        batch, jitter = generate(jax.random.PRNGKey(6), wl)
        fleet = _mk_fleet(4, speed_mult=(0.5, 1.0, 1.0, 2.0))
        final = jax.jit(lambda: run_sim(
            policy, batch, jitter, default_physics(),
            SimConfig(n_ticks=3000, k_slots=4), fleet=fleet))()
        ep = np.asarray(final.req.endpoint)
        done = np.asarray(final.req.status) == COMPLETED
        counts = np.bincount(ep[done], minlength=4)
        assert counts.sum() > 50
        assert counts[0] > counts[3]

    def test_route_requests_unit(self):
        """The routing layer in isolation: load balance, failover
        masking, and P=1 degeneracy."""
        fphys = uniform_fleet_physics(default_physics(), 3)
        fs = init_fleet_state(3, 2)._replace(
            inflight=jnp.asarray([8, 0, 0], jnp.int32))
        p50 = jnp.full((5,), 200.0, jnp.float32)
        ep, route = route_requests(fphys, fs, p50)
        assert np.asarray(ep).shape == (5,) and np.asarray(route).shape == (5,)
        # loaded endpoint 0 loses to the idle ones; ties break low
        np.testing.assert_array_equal(np.asarray(ep), np.ones(5) * 1)
        assert (np.asarray(route) > 0).all()
        assert (np.asarray(route) < UNAVAIL_MS * 1e-3).all()
        # endpoint 1 down -> 2 wins (0 is congested)
        ep2, _ = route_requests(
            fphys, fs, p50, avail_t=jnp.asarray([1.0, 0.0, 1.0]))
        np.testing.assert_array_equal(np.asarray(ep2), np.ones(5) * 2)

    def test_fleet_scenarios_registered(self):
        for name in ("fleet_failover", "fleet_skew", "fleet_brownout"):
            sc = scn.get_scenario(name)
            assert sc.fleet is not None and sc.fleet.p == 4
            fleet = scn.build_fleet(sc, default_physics(), 3000, 25.0,
                                    120, 2)
            assert fleet.phys.base_ms.shape == (4,)

    def test_fleet_scenario_end_to_end(self):
        """Registry fleet scenario through the seed-vmapped runner."""
        from repro.sim import run_scenario_cell
        m, pm = run_scenario_cell(
            base_policy(), "fleet_skew", seeds=2, n_requests=96,
            sim_cfg=SimConfig(n_ticks=2000, k_slots=4))
        assert float(np.nanmean(np.asarray(m.completion_rate))) > 0.3


class TestFleetProviderLive:
    def _children(self, fphys_np, **kw):
        from repro.client import MockProvider
        from repro.sim.provider import ProviderPhysics
        return [MockProvider(ProviderPhysics(
            *(float(np.asarray(a)[i]) for a in fphys_np)), **kw)
            for i in range(np.asarray(fphys_np.base_ms).shape[0])]

    def _mk(self, p=4, speed_mult=None, avail=None):
        from repro.client import FleetProvider
        fphys = uniform_fleet_physics(default_physics(), p,
                                      speed_mult=speed_mult)
        fphys_np = type(fphys)(*(np.asarray(a) for a in fphys))
        return FleetProvider(self._children(fphys_np), fphys_np,
                             avail=avail)

    def _req(self, i, p50=100.0):
        from repro.client import Request
        return Request(rid=i, prompt=None, max_new=p50, p50=p50, bucket=1)

    def test_routing_balances_and_skews(self):
        fp = self._mk(4, speed_mult=(0.5, 1.0, 1.0, 2.0))
        for i in range(16):
            assert fp.submit(self._req(i), now_ms=50.0).accepted
        by_ep = fp.inflight_by_endpoint()
        assert fp.inflight() == 16
        assert by_ep[0] > by_ep[3]      # fast endpoint loads first
        assert (by_ep > 0).sum() >= 2   # comfort pressure spreads load

    def test_poll_merges_in_ticket_order(self):
        fp = self._mk(4)
        for i in range(10):
            assert fp.submit(self._req(i), now_ms=50.0).accepted
        comps = fp.poll(1e9)
        assert [c.ticket for c in comps] == sorted(c.ticket for c in comps)
        assert len(comps) == 10 and fp.inflight() == 0

    def test_down_endpoint_drains_gracefully(self):
        """An endpoint that goes down stops receiving but still
        completes what it holds — the live-path failure model."""
        avail = np.ones((400, 2), np.float32)
        avail[4:, 0] = 0.0  # endpoint 0 dies after ~100ms
        fp = self._mk(2, avail=avail)
        r = fp.submit(self._req(0), now_ms=50.0)
        assert r.accepted and fp.n_routed[0] == 1
        for i in range(1, 7):
            assert fp.submit(self._req(i), now_ms=500.0).accepted
        assert fp.n_routed[0] == 1      # nothing new landed on the corpse
        assert fp.inflight_by_endpoint()[0] == 1
        comps = fp.poll(1e9)            # ...but its work still drains
        assert len(comps) == 7

    def test_whole_fleet_down_bounces_with_retry_after(self):
        avail = np.zeros((10, 2), np.float32)
        fp = self._mk(2, avail=avail)
        res = fp.submit(self._req(0), now_ms=50.0)
        assert not res.accepted and res.retry_after_ms == 1500.0
        assert fp.n_refused == 1

    def test_p1_passthrough_matches_bare_child(self):
        """P=1 fleet forwards inflight_hint and prices service exactly
        like the bare MockProvider."""
        from repro.client import MockProvider
        phys = default_physics()
        bare = MockProvider(phys)
        fp = self._mk(1)
        for i in range(6):
            rb = bare.submit(self._req(i), now_ms=50.0, inflight_hint=i)
            rf = fp.submit(self._req(i), now_ms=50.0, inflight_hint=i)
            assert rb.accepted and rf.accepted
        cb = bare.poll(1e9)
        cf = fp.poll(1e9)
        np.testing.assert_array_equal(
            np.asarray([c.finish_ms for c in cb], np.float32),
            np.asarray([c.finish_ms for c in cf], np.float32))

    def test_from_fleet_scenario(self):
        from repro.client import FleetProvider
        sc = scn.get_scenario("fleet_failover")
        fp = FleetProvider.from_fleet_scenario(
            sc, n_requests=120, n_ticks=6000, dt_ms=25.0, k=4)
        assert fp.p == 4 and fp._avail_rows.shape == (6000, 4)
        # inside the fail window, routing avoids the failed endpoint
        t_down = int(np.argmin(fp._avail_rows[:, 0]))
        ep, _ = fp.route(100.0, (t_down + 1) * 25.0)
        assert ep != 0
        with pytest.raises(ValueError):
            FleetProvider.from_fleet_scenario(
                scn.get_scenario("flash_crowd"), 120, 3000, 25.0, 4)
