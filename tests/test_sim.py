"""Integration + property tests for the JAX discrete-event simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.policy import strategy
from repro.core.types import (
    ABANDONED, COMPLETED, INFLIGHT, PENDING, REJECTED, SHORT,
)
from repro.sim import (
    SimConfig, WorkloadConfig, compute_metrics, default_physics, generate,
    run_cell, run_sim, summarize,
)
from repro.sim.metrics import masked_percentile
from repro.sim.provider import load_multiplier, service_time_ms, unloaded_latency_ms

SMALL = SimConfig(n_ticks=1500)


def run_one(name="final_adrr_olc", wl=None, seed=0, sim_cfg=SMALL):
    wl = wl or WorkloadConfig(n_requests=48, mix="balanced", congestion="medium")
    batch, jitter = generate(jax.random.PRNGKey(seed), wl)
    final = run_sim(strategy(name), batch, jitter, default_physics(), sim_cfg)
    return batch, final


class TestProvider:
    def test_latency_linear_in_tokens(self):
        phys = default_physics()
        t = jnp.asarray([100.0, 200.0, 400.0])
        lat = unloaded_latency_ms(phys, t)
        d1 = float(lat[1] - lat[0])
        d2 = float(lat[2] - lat[1])
        assert d2 == pytest.approx(2 * d1, rel=1e-5)

    def test_load_multiplier_monotone_and_convex(self):
        phys = default_physics()
        ms = [float(load_multiplier(phys, i)) for i in range(0, 30, 3)]
        assert all(b >= a for a, b in zip(ms, ms[1:]))
        assert ms[0] == pytest.approx(1.0)
        diffs = np.diff(ms)
        assert all(d2 >= d1 - 1e-6 for d1, d2 in zip(diffs, diffs[1:]))

    def test_service_time_includes_jitter(self):
        phys = default_physics()
        s1 = service_time_ms(phys, 100.0, 0, 1.0)
        s2 = service_time_ms(phys, 100.0, 0, 1.05)
        assert float(s2) == pytest.approx(float(s1) * 1.05, rel=1e-5)


class TestWorkload:
    def test_arrivals_sorted_positive(self):
        wl = WorkloadConfig(n_requests=64)
        b, _ = generate(jax.random.PRNGKey(0), wl)
        a = np.asarray(b.arrival_ms)
        assert (np.diff(a) >= 0).all() and (a > 0).all()

    def test_bucket_token_ranges(self):
        wl = WorkloadConfig(n_requests=256)
        b, _ = generate(jax.random.PRNGKey(1), wl)
        lo = np.asarray([16, 65, 257, 1025])[np.asarray(b.bucket)]
        hi = np.asarray([64, 256, 1024, 4096])[np.asarray(b.bucket)]
        t = np.asarray(b.true_tokens)
        assert (t >= lo - 1).all() and (t <= hi + 1).all()

    def test_class_routing(self):
        wl = WorkloadConfig(n_requests=128)
        b, _ = generate(jax.random.PRNGKey(2), wl)
        assert (np.asarray(b.cls) == (np.asarray(b.bucket) != SHORT)).all()

    def test_information_levels(self):
        k = jax.random.PRNGKey(3)
        oracle, _ = generate(k, WorkloadConfig(information="oracle"))
        assert np.allclose(oracle.p50, oracle.true_tokens)
        neutral, _ = generate(k, WorkloadConfig(information="class_only"))
        assert np.unique(np.asarray(neutral.p50)).size == 1
        coarse, _ = generate(k, WorkloadConfig(information="coarse"))
        rel = np.abs(np.asarray(coarse.p50) / np.asarray(coarse.true_tokens) - 1)
        assert rel.max() <= 0.25 + 1e-5 and rel.mean() > 0.01

    def test_predictor_noise_bounds(self):
        k = jax.random.PRNGKey(4)
        clean, _ = generate(k, WorkloadConfig(information="oracle"))
        noisy, _ = generate(k, WorkloadConfig(information="oracle", predictor_noise=0.6))
        ratio = np.asarray(noisy.p50) / np.asarray(clean.p50)
        assert (ratio >= 0.4 - 1e-5).all() and (ratio <= 1.6 + 1e-5).all()

    def test_mix_proportions(self):
        wl = WorkloadConfig(n_requests=2048, mix="heavy")
        b, _ = generate(jax.random.PRNGKey(5), wl)
        frac = np.bincount(np.asarray(b.bucket), minlength=4) / 2048
        assert np.allclose(frac, [0.2, 0.2, 0.3, 0.3], atol=0.05)


class TestEngine:
    @pytest.mark.slow
    def test_conservation(self):
        """Every request ends in exactly one terminal/annotated state."""
        b, final = run_one()
        s = np.asarray(final.req.status)
        assert ((s == COMPLETED) | (s == REJECTED) | (s == ABANDONED)
                | (s == PENDING) | (s == INFLIGHT)).all()
        # after drain, nothing is left pending or inflight
        assert ((s == COMPLETED) | (s == REJECTED) | (s == ABANDONED)).all()

    @pytest.mark.slow
    def test_light_load_all_complete_in_time(self):
        wl = WorkloadConfig(n_requests=12, congestion="medium")
        b, final = run_one(wl=wl)
        s = np.asarray(final.req.status)
        assert (s == COMPLETED).all()
        lat = np.asarray(final.req.finish_ms - b.arrival_ms)
        assert (lat <= np.asarray(b.deadline_budget_ms) * 3).all()

    def test_finish_after_submit_after_arrival(self):
        b, final = run_one()
        done = np.asarray(final.req.status) == COMPLETED
        sub = np.asarray(final.req.submit_ms)[done]
        fin = np.asarray(final.req.finish_ms)[done]
        arr = np.asarray(b.arrival_ms)[done]
        assert (sub >= arr - 25.0 - 1e-3).all()  # within one tick quantum
        assert (fin > sub).all()

    @pytest.mark.slow
    def test_shorts_never_rejected_final_olc(self):
        wl = WorkloadConfig(n_requests=96, mix="heavy", congestion="high")
        b, final = run_one(wl=wl, sim_cfg=SimConfig(n_ticks=4000))
        s = np.asarray(final.req.status)
        shorts = np.asarray(b.bucket) == SHORT
        assert (s[shorts] != REJECTED).all()

    @pytest.mark.slow
    def test_rejections_concentrate_on_expensive(self):
        """Paper Fig 5: xlong bears the majority of rejections."""
        wl = WorkloadConfig(n_requests=128, mix="heavy", congestion="high")
        b, final = run_one(wl=wl, sim_cfg=SimConfig(n_ticks=4000))
        s = np.asarray(final.req.status)
        bkt = np.asarray(b.bucket)
        rej = s == REJECTED
        if rej.sum() > 0:
            assert bkt[rej].min() >= 2  # only long/xlong under the ladder
            assert (bkt[rej] == 3).sum() >= (bkt[rej] == 2).sum()

    @pytest.mark.slow
    def test_naive_admits_everything_instantly(self):
        b, final = run_one("direct_naive")
        done = np.asarray(final.req.status) == COMPLETED
        wait = np.asarray(final.req.submit_ms) - np.asarray(b.arrival_ms)
        assert (wait[done] <= 50.0 + 1e-3).all()  # within 2 ticks

    @pytest.mark.slow
    def test_deterministic_given_seed(self):
        b1, f1 = run_one(seed=7)
        b2, f2 = run_one(seed=7)
        assert np.array_equal(np.asarray(f1.req.status), np.asarray(f2.req.status))
        assert np.allclose(np.asarray(f1.req.finish_ms), np.asarray(f2.req.finish_ms))


class TestMetrics:
    @given(q=st.floats(0.05, 0.99), n_valid=st.integers(1, 50))
    @settings(max_examples=40, deadline=None)
    def test_masked_percentile_matches_numpy(self, q, n_valid):
        rng = np.random.default_rng(0)
        vals = rng.uniform(0, 100, size=64).astype(np.float32)
        mask = np.zeros(64, bool)
        mask[rng.choice(64, size=n_valid, replace=False)] = True
        ours = float(masked_percentile(jnp.asarray(vals), jnp.asarray(mask), q))
        ref = float(np.quantile(vals[mask], q, method="inverted_cdf"))
        assert ours == pytest.approx(ref, rel=1e-5)

    def test_masked_percentile_empty_nan(self):
        out = masked_percentile(jnp.arange(4.0), jnp.zeros(4, bool), 0.95)
        assert np.isnan(float(out))

    @pytest.mark.slow
    def test_metrics_cr_excludes_rejects(self):
        wl = WorkloadConfig(n_requests=128, mix="heavy", congestion="high")
        b, final = run_one(wl=wl, sim_cfg=SimConfig(n_ticks=4000))
        m = compute_metrics(b, final)
        s = np.asarray(final.req.status)
        n_rej = (s == REJECTED).sum()
        n_done = (s == COMPLETED).sum()
        assert float(m.completion_rate) == pytest.approx(n_done / (128 - n_rej), rel=1e-5)
        assert int(m.n_rejects) == n_rej

    @pytest.mark.slow
    def test_goodput_counts_only_met(self):
        b, final = run_one()
        m = compute_metrics(b, final)
        done = np.asarray(final.req.status) == COMPLETED
        met = done & (np.asarray(final.req.finish_ms)
                      <= np.asarray(b.arrival_ms + b.deadline_budget_ms))
        expect = met.sum() / (float(m.makespan_ms) / 1000.0)
        assert float(m.goodput_rps) == pytest.approx(expect, rel=1e-4)


class TestRunner:
    @pytest.mark.slow
    def test_run_cell_shapes_and_seed_variation(self):
        wl = WorkloadConfig(n_requests=48)
        m = run_cell(strategy("final_adrr_olc"), wl, seeds=3, sim_cfg=SMALL)
        assert m.short_p95_ms.shape == (3,)
        s = summarize(m)
        assert "short_p95_ms" in s and np.isfinite(s["short_p95_ms"][0])

    @pytest.mark.slow
    def test_policy_vmap_over_stacked_configs(self):
        """Stacked PolicyConfigs vmap into one compiled sweep."""
        import jax
        cfgs = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            strategy("adaptive_drr"), strategy("final_adrr_olc"))
        wl = WorkloadConfig(n_requests=32)
        batch, jitter = generate(jax.random.PRNGKey(0), wl)
        phys = default_physics()

        def one(cfg):
            final = run_sim(cfg, batch, jitter, phys, SMALL)
            return compute_metrics(batch, final).completion_rate

        crs = jax.vmap(one)(cfgs)
        assert crs.shape == (2,) and np.isfinite(np.asarray(crs)).all()
