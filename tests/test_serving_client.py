"""First-ever serving-path coverage: the streaming `ClientSession`
(DESIGN.md §7).

The headline pin: driven in virtual time over `MockProvider`,
`ClientSession` reproduces the windowed sim engine's decision sequence
— same action, same target request, tick for tick, grant for grant —
on generated traces (the `balanced` regime plus a nonstationary one).
The session and engine share `schedule_batch`, `_complete_and_timeout`,
and the provider physics, so this is the sim↔live parity contract made
executable.  Severity is compared to 1 ulp rather than bitwise: the
EMA's trailing multiply-add contracts to an FMA inside the engine's
scan fusion but not in the session's standalone programs, a 1-ulp
rounding difference LLVM applies below the reach of
`core.numerics.pinned` (decisions pinned here are robust to it).

Also covered: the 429/Retry-After boundary under a rate_crunch-style
throttle schedule (bounces honored, no resubmission before the window,
recovery after it lifts, the retry-policy hook), drain lifecycle,
open-ended submission, p90 defaulting, and the deprecated
`ScheduledClient` shim.
"""
from __future__ import annotations

import time

import jax
import numpy as np
import pytest

from repro.client import (
    AsyncBlackBoxProvider,
    ClientSession,
    MockProvider,
    Request,
    SessionConfig,
    default_p90,
    expo_retry,
)
from repro.core.policy import strategy
from repro.core.scheduler import IDLE
from repro.sim import SimConfig, WorkloadConfig, default_physics, generate, run_sim
from repro.sim import scenarios as scn
from repro.sim.workload import P90_OVER_P50_NP


def batch_to_requests(batch, jitter) -> list[Request]:
    """Replay a generated RequestBatch as session submissions (arrival
    order == request-id order, the generator's native sort)."""
    arr = np.asarray(batch.arrival_ms)
    bucket = np.asarray(batch.bucket)
    cls = np.asarray(batch.cls)
    tok = np.asarray(batch.true_tokens)
    p50 = np.asarray(batch.p50)
    p90 = np.asarray(batch.p90)
    jit = np.asarray(jitter)
    return [
        Request(
            rid=i, prompt=None, max_new=float(tok[i]), p50=float(p50[i]),
            bucket=int(bucket[i]), p90=float(p90[i]), cls=int(cls[i]),
            arrival_s=float(arr[i]) / 1e3, jitter=float(jit[i]),
        )
        for i in range(batch.n)
    ]


def drive_session(sess: ClientSession, n_ticks: int):
    """n_ticks virtual polls; returns (actions (T,B), rids (T,B),
    severity (T,))."""
    acts, rids, sevs = [], [], []
    for _ in range(n_ticks):
        r = sess.poll()
        acts.append(r.actions)
        rids.append(r.req_rids)
        sevs.append(r.severity)
    return np.stack(acts), np.stack(rids), np.asarray(sevs, np.float32)


def assert_decision_parity(trace, s_acts, s_rids, s_sevs):
    e_acts = np.asarray(trace[0])
    e_idxs = np.asarray(trace[1])
    e_sevs = np.asarray(trace[2], np.float32)
    np.testing.assert_array_equal(s_acts, e_acts)
    live = e_acts != IDLE
    np.testing.assert_array_equal(s_rids[live], e_idxs[live])
    # 1 ulp on severity (see module docstring); decisions above are exact
    np.testing.assert_allclose(s_sevs, e_sevs, rtol=3e-7, atol=1e-9)


class TestDecisionParity:
    """Acceptance pin: ClientSession over MockProvider == the windowed
    sim engine's decision stream."""

    def _pair(self, wl, seed, n_ticks, window, k_slots, policy_name):
        policy = strategy(policy_name)
        batch, jitter = generate(jax.random.PRNGKey(seed), wl)
        phys = default_physics()
        sim_cfg = SimConfig(n_ticks=n_ticks, k_slots=k_slots, dt_ms=25.0,
                            window=window)
        _, trace = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg,
            collect_decisions=True))()
        sess = ClientSession(
            MockProvider(phys, dt_ms=25.0), policy,
            SessionConfig(window=window, max_grants=k_slots, dt_ms=25.0),
            clock="virtual", phys=phys)
        for r in batch_to_requests(batch, jitter):
            sess.submit(r)
        return trace, sess

    def test_balanced_pinned(self):
        wl = WorkloadConfig(n_requests=48, mix="balanced",
                            congestion="medium")
        trace, sess = self._pair(wl, seed=0, n_ticks=900, window=64,
                                 k_slots=4, policy_name="final_adrr_olc")
        s_acts, s_rids, s_sevs = drive_session(sess, 900)
        assert_decision_parity(trace, s_acts, s_rids, s_sevs)
        # the pin must bite: real admits and completions happened
        assert sess.stats.n_admitted > 10
        assert sess.stats.n_completed > 10

    def test_balanced_seed1(self):
        wl = WorkloadConfig(n_requests=48, mix="balanced",
                            congestion="medium")
        trace, sess = self._pair(wl, seed=1, n_ticks=900, window=64,
                                 k_slots=4, policy_name="final_adrr_olc")
        assert_decision_parity(trace, *drive_session(sess, 900))

    @pytest.mark.slow
    def test_heavy_high_overload_path(self):
        """Overload regime (arrivals compressed 3x): defers/rejects flow
        through the same parity — the cost ladder fires, not just
        admits."""
        wl = WorkloadConfig(n_requests=96, mix="heavy", congestion="high",
                            arrival_scale=3.0)
        trace, sess = self._pair(wl, seed=2, n_ticks=1200, window=128,
                                 k_slots=4, policy_name="final_adrr_olc")
        s_acts, s_rids, s_sevs = drive_session(sess, 1200)
        assert_decision_parity(trace, s_acts, s_rids, s_sevs)
        assert sess.stats.n_rejected + sess.stats.n_deferred > 0

    @pytest.mark.slow
    def test_flash_crowd_nonstationary(self):
        """Nonstationary arrivals (no provider dynamics): the time-warped
        trace replays identically through the live path."""
        sc = scn.get_scenario("flash_crowd")
        sim_cfg = SimConfig(n_ticks=1200, k_slots=4, dt_ms=25.0, window=128)
        wl, sched, dyn, _ = scn.build(sc, 96, sim_cfg.n_ticks, sim_cfg.dt_ms)
        assert dyn is None
        policy = strategy("final_adrr_olc")
        batch, jitter = generate(jax.random.PRNGKey(3), wl, sched)
        phys = default_physics()
        _, trace = jax.jit(lambda: run_sim(
            policy, batch, jitter, phys, sim_cfg,
            collect_decisions=True))()
        sess = ClientSession(
            MockProvider(phys, dt_ms=25.0), policy,
            SessionConfig(window=128, max_grants=4, dt_ms=25.0),
            clock="virtual", phys=phys)
        for r in batch_to_requests(batch, jitter):
            sess.submit(r)
        assert_decision_parity(trace, *drive_session(sess, 1200))


class TestThrottleBackoff:
    """The 429/Retry-After boundary under a rate_crunch-style schedule:
    sustained refill collapses mid-run, the bucket drains, bounces carry
    Retry-After, and the session parks bounced work for exactly that
    long."""

    def _crunch_provider(self, phys, n_ticks=2000, dt=25.0,
                         retry_after=1500.0):
        t = np.arange(n_ticks)
        # 1.2 grants/s sustained, frozen to 10% for the middle third
        refill = np.full((n_ticks, 2), 1.2 * dt / 1000.0, np.float32)
        mid = (t >= n_ticks // 3) & (t < 2 * n_ticks // 3)
        refill[mid] *= 0.1
        return MockProvider(
            phys, dt_ms=dt, tb_refill=refill,
            tb_capacity=np.full(2, 4.0, np.float32),
            retry_after_ms=retry_after)

    def _arrival_burst(self, n, gap_ms=120.0):
        return [
            Request(rid=i, prompt=None, max_new=40.0 + i, p50=40.0 + i,
                    bucket=0, arrival_s=i * gap_ms / 1e3)
            for i in range(n)
        ]

    @staticmethod
    def _patient_policy():
        """The crunch outlasts the shorts' stale timeout; relax the
        timeout multiple so the test isolates Retry-After behavior and
        post-crunch recovery from client-side abandonment."""
        import jax.numpy as jnp
        return strategy("final_adrr_olc")._replace(
            timeout_mult=jnp.full((4,), 30.0, jnp.float32))

    def test_throttles_happen_and_backoff_is_honored(self):
        phys = default_physics()
        prov = self._crunch_provider(phys)
        sess = ClientSession(
            prov, self._patient_policy(),
            SessionConfig(window=64, max_grants=4, dt_ms=25.0),
            clock="virtual", phys=phys)
        for r in self._arrival_burst(40):
            sess.submit(r)
        throttle_at: dict[int, float] = {}   # rid -> bounce time
        resubmit_gap_ok = True
        for _ in range(2400):
            r = sess.poll()
            for rid in r.throttled:
                throttle_at[rid] = r.now_ms
            for rid in r.admitted:
                if rid in throttle_at:
                    # bounced earlier: must not resubmit before Retry-After
                    if r.now_ms < throttle_at[rid] + prov.retry_after_ms:
                        resubmit_gap_ok = False
            if sess.unfinished == 0:
                break
        assert prov.n_throttled > 0, "crunch never produced a 429"
        assert sess.stats.n_throttled == prov.n_throttled
        assert resubmit_gap_ok, "a bounced request resubmitted early"
        # recovery: after the window lifts everything completes
        assert sess.unfinished == 0
        assert sess.stats.n_completed == 40
        # the session's per-request bookkeeping saw the bounces too
        assert sum(r.n_throttles for r in sess.requests()) \
            == prov.n_throttled

    def test_retry_policy_hook(self):
        """expo_retry grows the park time geometrically per bounce of
        the same request — the pluggable Retry-After policy.  The
        default ±20% jitter smears each delay, so the spacing bound is
        the jittered floor 0.8 * growth^(i-1) * retry_after."""
        phys = default_physics()
        prov = self._crunch_provider(phys, retry_after=400.0)
        sess = ClientSession(
            prov, self._patient_policy(),
            SessionConfig(window=64, max_grants=4, dt_ms=25.0),
            clock="virtual", phys=phys,
            retry_policy=expo_retry(mult=1.0, growth=3.0))
        for r in self._arrival_burst(40, gap_ms=80.0):
            sess.submit(r)
        bounces: dict[int, list[float]] = {}
        for _ in range(3000):
            r = sess.poll()
            for rid in r.throttled:
                bounces.setdefault(rid, []).append(r.now_ms)
            if sess.unfinished == 0:
                break
        multi = {rid: ts for rid, ts in bounces.items() if len(ts) >= 2}
        assert prov.n_throttled > 0
        assert multi, "no request bounced twice — the hook went unexercised"
        # the delay applied after the i-th bounce of a request is at
        # least 0.8 * retry_after * growth^(i-1); the gap to its next
        # bounce must respect it
        for rid, ts in multi.items():
            for i in range(1, len(ts)):
                grown = 400.0 * 3.0 ** (i - 1)
                assert ts[i] - ts[i - 1] >= 0.8 * min(grown, 60_000.0) - 1e-3

    def test_expo_retry_jitter_distribution(self):
        """The jitter decorrelates a synchronized 429 cohort: delays for
        the same (retry_after, n_throttles) spread uniformly over
        base * [1 - j, 1 + j] instead of collapsing to one value, and
        replays are deterministic under the same seed."""
        policy = expo_retry(mult=1.0, growth=2.0, jitter=0.2, seed=7)
        base = 400.0 * 2.0 ** 2  # third bounce
        draws = np.asarray([policy(400.0, 3) for _ in range(400)])
        assert draws.min() >= 0.8 * base - 1e-9
        assert draws.max() <= 1.2 * base + 1e-9
        # genuinely spread (a lockstep cohort would be a point mass) and
        # roughly uniform: both halves of the band are populated
        assert np.unique(draws).size > 390
        assert draws.std() > 0.08 * base
        lo_half = (draws < base).mean()
        assert 0.35 < lo_half < 0.65
        # seeded determinism: an identical policy replays identically
        replay = expo_retry(mult=1.0, growth=2.0, jitter=0.2, seed=7)
        assert [replay(400.0, 3) for _ in range(400)] == list(draws)
        # jitter=0 recovers the exact geometric schedule (and the cap)
        exact = expo_retry(mult=1.0, growth=3.0, jitter=0.0)
        assert exact(400.0, 1) == 400.0
        assert exact(400.0, 3) == 3600.0
        assert exact(400.0, 20) == 60_000.0


class TestSessionLifecycle:
    def test_open_ended_submission(self):
        """Requests submitted mid-flight (after polling started) are
        admitted and completed — the API is a stream, not a batch."""
        phys = default_physics()
        sess = ClientSession(
            MockProvider(phys, dt_ms=25.0), strategy("final_adrr_olc"),
            SessionConfig(window=16, max_grants=2, dt_ms=25.0),
            clock="virtual", phys=phys)
        sess.submit(Request(rid=0, prompt=None, max_new=30.0, p50=30.0,
                            bucket=0))
        for _ in range(40):
            sess.poll()
        late = Request(rid=1, prompt=None, max_new=30.0, p50=30.0, bucket=0,
                       arrival_s=sess.now_ms() / 1e3)
        sess.submit(late)
        out = sess.drain(max_polls=4000)
        assert [r.status for r in out] == ["completed", "completed"]
        assert out[1].finish_s > out[0].finish_s

    def test_window_overflow_queues_fifo(self):
        """More live work than W: the queue holds the overflow and every
        request still terminates (the engine's overflow contract)."""
        phys = default_physics()
        sess = ClientSession(
            MockProvider(phys, dt_ms=25.0), strategy("final_adrr_olc"),
            SessionConfig(window=4, max_grants=2, dt_ms=25.0),
            clock="virtual", phys=phys)
        for i in range(16):
            sess.submit(Request(rid=i, prompt=None, max_new=25.0, p50=25.0,
                                bucket=0))
        out = sess.drain(max_polls=8000)
        assert all(r.status in ("completed", "rejected", "abandoned")
                   for r in out)
        assert sum(r.status == "completed" for r in out) > 0
        assert sess._n_live <= 4

    def test_inflight_tracks_provider_concurrency(self):
        """The session's concurrency accounting equals the provider's
        actual outstanding count every epoch (no blocking brackets)."""
        phys = default_physics()
        prov = MockProvider(phys, dt_ms=25.0)
        sess = ClientSession(
            prov, strategy("final_adrr_olc"),
            SessionConfig(window=32, max_grants=4, dt_ms=25.0),
            clock="virtual", phys=phys)
        for i in range(24):
            sess.submit(Request(rid=i, prompt=None, max_new=200.0,
                                p50=200.0, bucket=1))
        saw_concurrent = False
        for _ in range(1500):
            sess.poll()
            sess_inflight = int(np.asarray(sess._state.provider.inflight))
            assert sess_inflight == prov.inflight()
            saw_concurrent |= prov.inflight() > 1
            if sess.unfinished == 0:
                break
        assert saw_concurrent, "never had >1 request in flight"

    def test_p90_defaulting(self):
        r = Request(rid=0, prompt=None, max_new=100.0, p50=100.0, bucket=2)
        assert r.resolved_p90() == pytest.approx(
            100.0 * float(P90_OVER_P50_NP[2]))
        assert default_p90(1.0, 0) == pytest.approx((64.0 / 16.0) ** 0.4)
        explicit = Request(rid=0, prompt=None, max_new=100.0, p50=100.0,
                           bucket=2, p90=555.0)
        assert explicit.resolved_p90() == 555.0


class TestDonationSafety:
    """The fused tick's perf contract: the (W,) pool is donated (the
    device reuses the buffers in place, the host never rematerializes
    them), and a drained session's polls are host-only no-ops."""

    def _session(self, window=16):
        phys = default_physics()
        return ClientSession(
            MockProvider(phys, dt_ms=25.0), strategy("final_adrr_olc"),
            SessionConfig(window=window, max_grants=2, dt_ms=25.0),
            clock="virtual", phys=phys)

    def test_fused_tick_donates_pool_buffers(self):
        """Every (W,)-sized device buffer of the pre-poll (batch, state)
        pool must be consumed by the fused step — a silently dropped
        donation would double the pool's memory and re-copy it every
        poll.  (A handful of scalar/(K,) fields legitimately escape:
        the deferred-apply decision in `_pending` keeps aliases of
        deficit/rr_turn/inflight alive across the epoch boundary, so
        XLA declines those donations — bytes, not the O(W) pool.)"""
        sess = self._session()
        sess.submit(Request(rid=0, prompt=None, max_new=25.0, p50=25.0,
                            bucket=0))
        sess.poll()  # fold the warmup-fresh pool through one real epoch
        w = sess.cfg.window
        before = [x for x in jax.tree_util.tree_leaves(
            (sess._win_batch, sess._dev_state)) if x.size >= w]
        assert len(before) >= 14  # the pool really is (W,)-columnar
        sess.poll()
        assert all(x.is_deleted() for x in before)

    def test_stale_post_donation_read_raises(self):
        """The invariant reprolint RPL002 enforces statically, verified
        dynamically: a binding captured before a poll is donated into
        the fused tick, and a host read of the stale Array must raise
        (deleted buffer) rather than silently observe freed memory.
        `poll()` itself stays safe because it rebinds `_win_batch` /
        `_dev_state` from the tick's results in the same statement."""
        sess = self._session()
        sess.submit(Request(rid=0, prompt=None, max_new=25.0, p50=25.0,
                            bucket=0))
        sess.poll()  # fold the warmup-fresh pool through one real epoch
        w = sess.cfg.window
        stale = [x for x in jax.tree_util.tree_leaves(
            (sess._win_batch, sess._dev_state)) if x.size >= w]
        assert stale, "expected (W,)-sized donated leaves"
        sess.poll()  # donates every captured buffer
        for leaf in stale:
            assert leaf.is_deleted()
            with pytest.raises(RuntimeError):
                np.asarray(leaf)  # any host materialization must fail

    def test_post_drain_poll_is_transfer_free(self):
        """After drain() the pool is empty and the epoch is a fixpoint:
        poll() must replay the cached result without touching the
        device at all — no transfers in either direction."""
        sess = self._session()
        for i in range(4):
            sess.submit(Request(rid=i, prompt=None, max_new=25.0, p50=25.0,
                                bucket=0))
        sess.drain(max_polls=4000)
        assert sess._idle_cache is not None
        with jax.transfer_guard("disallow"):
            r1 = sess.poll()
            r2 = sess.poll()
        assert not r1.progressed and not r2.progressed
        assert r1.n_live == 0
        assert r2.now_ms > r1.now_ms  # the clock still advances

    def test_submit_after_drain_invalidates_idle_cache(self):
        """A new submission must break the fixpoint: the next poll goes
        back through the device and the request completes."""
        sess = self._session()
        sess.submit(Request(rid=0, prompt=None, max_new=25.0, p50=25.0,
                            bucket=0))
        sess.drain(max_polls=4000)
        assert sess._idle_cache is not None
        sess.submit(Request(rid=1, prompt=None, max_new=25.0, p50=25.0,
                            bucket=0, arrival_s=sess.now_ms() / 1e3))
        assert sess._idle_cache is None
        out = sess.drain(max_polls=4000)
        assert out[1].status == "completed"


class _EchoProvider:
    """Blocking stand-in for the real engine (submit(prompt, max_new))."""

    def submit(self, prompt, max_new):
        time.sleep(0.002)
        return np.arange(int(max_new), dtype=np.int32)


class TestWallClockAndShim:
    def test_async_blackbox_adapter(self):
        """Wall-clock session over the threaded adapter: non-blocking
        submits, multiple inflight, outputs delivered."""
        prov = AsyncBlackBoxProvider(_EchoProvider(), max_workers=4)
        phys = default_physics()
        sess = ClientSession(
            prov, strategy("final_adrr_olc"),
            SessionConfig(window=16, max_grants=4, time_scale=50.0),
            clock="wall", phys=phys)
        for i in range(6):
            sess.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                                max_new=5.0, p50=5.0, bucket=0))
        out = sess.drain()
        prov.shutdown()
        assert all(r.status == "completed" for r in out)
        assert all(r.output is not None and len(r.output) == 5 for r in out)

    def test_adapter_max_inflight_throttles(self):
        """The adapter's concurrency cap emits real 429s the session
        backs off from — Retry-After at the real-engine boundary."""
        prov = AsyncBlackBoxProvider(_EchoProvider(), max_workers=2,
                                     max_inflight=1, retry_after_ms=50.0)
        sess = ClientSession(
            prov, strategy("final_adrr_olc"),
            SessionConfig(window=16, max_grants=4, time_scale=50.0),
            clock="wall", phys=default_physics())
        for i in range(8):
            sess.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                                max_new=4.0, p50=4.0, bucket=0))
        out = sess.drain()
        prov.shutdown()
        assert all(r.status == "completed" for r in out)
        assert prov.n_throttled > 0

    def test_scheduled_client_shim(self):
        """The deprecated closed-list surface still runs end to end over
        the new session (and warns)."""
        reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                        max_new=4.0 + i, p50=4.0 + i, bucket=0,
                        arrival_s=0.02 * i) for i in range(5)]
        from repro.serving import ScheduledClient
        with pytest.warns(DeprecationWarning):
            client = ScheduledClient(_EchoProvider(),
                                     strategy("final_adrr_olc"))
        out = client.run(reqs, time_scale=40.0)
        assert all(r.status == "completed" for r in out)
        assert all(r.output is not None for r in out)
