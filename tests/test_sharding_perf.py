"""Regression tests for the §Perf hillclimb fixes (EXPERIMENTS.md §Perf).

Each of these locked in a large dry-run win; a regression would silently
re-replicate terabytes on the production mesh, so they are asserted at
the unit level (no 512-device mesh needed).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, get_smoke
from repro.models import init_caches, init_model
from repro.models.model import cache_axes, lm_loss
from repro.sharding.rules import DEFAULT_ACT_RULES, constrain, spec_for


class TestCacheSharding:
    """§Perf/qwen-decode iteration 1: KV caches must shard with ACT rules
    (cache_batch -> data, cache_seq -> model), never silently replicate."""

    def test_kv_cache_spec_shards_batch_and_seq(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        axes = ("layers", "cache_batch", "cache_seq", "kv_heads", None)
        spec = spec_for(axes, (64, 128, 32768, 40, 128), mesh,
                        DEFAULT_ACT_RULES)
        assert spec[1] == "data"
        assert spec[2] == "model"
        # kv_heads must NOT claim model again (one mesh axis per spec)
        assert spec[3] is None

    def test_launch_cache_shardings_not_replicated(self):
        from repro.launch.specs import _abstract_caches, _cache_shardings
        cfg = get("qwen1.5-32b")
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sds = _abstract_caches(cfg, 128, 32768)
        sh = _cache_shardings(cfg, sds, mesh)
        spec = sh["kv"].k.spec
        assert "data" in spec and "model" in spec, (
            f"KV cache replicated again: {spec}")


class TestPaddedVocab:
    """§Perf/internvl2-train iteration 1: odd vocabs pad to x128 so the
    LM head shards; padded logit columns are masked to -inf."""

    def test_padded_vocab_multiple_of_128(self):
        for name in ("internvl2-1b", "mamba2-780m", "hymba-1.5b",
                     "phi3.5-moe-42b-a6.6b"):
            cfg = get(name)
            assert cfg.padded_vocab % 128 == 0
            assert cfg.padded_vocab >= cfg.vocab
            assert cfg.padded_vocab - cfg.vocab < 128

    def test_param_shapes_use_padded_vocab(self):
        cfg = get_smoke("internvl2-1b")
        params = jax.eval_shape(
            lambda k: init_model(k, cfg).params, jax.random.PRNGKey(0))
        assert params["embed"].shape[0] == cfg.padded_vocab

    def test_padded_logits_masked(self):
        import dataclasses
        cfg = dataclasses.replace(get_smoke("internvl2-1b"), vocab=1000)
        assert cfg.padded_vocab == 1024
        model = init_model(jax.random.PRNGKey(0), cfg)
        from repro.models.model import forward_train
        toks = jnp.zeros((1, 8), jnp.int32)
        logits, _ = forward_train(model.params, cfg, toks, remat=False)
        pad = np.asarray(logits[..., cfg.vocab:])
        assert np.all(np.isneginf(pad)), "padding columns must be -inf"
        assert np.all(np.isfinite(np.asarray(logits[..., :cfg.vocab])))

    def test_loss_finite_with_padding(self):
        cfg = get_smoke("internvl2-1b")
        model = init_model(jax.random.PRNGKey(1), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
        loss = lm_loss(model.params, cfg, toks, toks, remat=False)
        assert np.isfinite(float(loss))


class TestConstrain:
    """§Perf/internvl2-train iteration 2: logical-axis sharding constraint
    helper — must be a no-op outside a mesh and apply inside one."""

    def test_noop_outside_mesh(self):
        x = jnp.ones((4, 8))
        y = constrain(x, "batch", None)
        assert y is x or np.array_equal(np.asarray(y), np.asarray(x))

    def test_applies_inside_mesh(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))

        def f(x):
            return constrain(x, "batch", None) * 2

        with mesh:
            out = jax.jit(f)(jnp.ones((4, 8)))
        np.testing.assert_allclose(np.asarray(out), 2.0)


class TestCacheAxesTree:
    def test_cache_axes_match_cache_tree(self):
        cfg = get_smoke("hymba-1.5b")
        caches = jax.eval_shape(lambda: init_caches(cfg, 2, 32))
        axes = cache_axes(cfg)
        # every cache leaf has a same-rank logical-axes tuple
        leaves = jax.tree.leaves(caches)
        axleaves = jax.tree.leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        assert len(leaves) == len(axleaves)
        for leaf, ax in zip(leaves, axleaves):
            assert len(leaf.shape) == len(ax)
