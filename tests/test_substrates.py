"""Substrate tests: optimizer math, data pipeline, checkpointing,
serving engine generation, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke
from repro.data import DataConfig, make_batches
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.models import init_model
from repro.serving import generate
from repro.training import adamw
from repro.training.train_step import init_train_state, train_step


class TestAdamW:
    def test_single_step_matches_reference_math(self):
        tc = TrainConfig(lr=1e-2, warmup_steps=0, total_steps=10**9,
                         weight_decay=0.0, grad_clip=1e9)
        p = {"w": jnp.asarray([1.0, 2.0], jnp.float32)}
        g = {"w": jnp.asarray([0.1, -0.2], jnp.float32)}
        st0 = adamw.init(p)
        newp, st1, _ = adamw.apply(st0, g, tc, jnp.float32)
        # bias-corrected adam first step: update = lr * g/|g| elementwise
        m = (1 - 0.9) * np.asarray(g["w"])
        v = (1 - 0.95) * np.asarray(g["w"]) ** 2
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.95)
        expect = np.asarray(p["w"]) - 1e-2 * mh / (np.sqrt(vh) + tc.eps)
        np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-5)
        assert int(st1.step) == 1

    def test_weight_decay_pulls_toward_zero(self):
        tc = TrainConfig(lr=1e-2, warmup_steps=0, weight_decay=0.5,
                         total_steps=10**9)
        p = {"w": jnp.asarray([10.0], jnp.float32)}
        g = {"w": jnp.asarray([0.0], jnp.float32)}
        newp, _, _ = adamw.apply(adamw.init(p), g, tc, jnp.float32)
        assert float(newp["w"][0]) < 10.0

    def test_grad_clip_limits_update(self):
        tc = TrainConfig(lr=1.0, warmup_steps=0, grad_clip=1e-3,
                         weight_decay=0.0, total_steps=10**9)
        p = {"w": jnp.ones((4,), jnp.float32)}
        g = {"w": jnp.full((4,), 100.0, jnp.float32)}
        _, st1, m = adamw.apply(adamw.init(p), g, tc, jnp.float32)
        assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)
        # clipped: m should be tiny
        assert float(jnp.abs(st1.m["w"]).max()) < 1e-3

    def test_lr_schedule_shape(self):
        tc = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(adamw.lr_schedule(tc, s)) for s in [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[3] < 1e-3
        assert lrs[4] == pytest.approx(1e-4, rel=1e-2)

    def test_microbatched_grads_match_whole_batch(self):
        cfg = get_smoke("stablelm-1.6b")
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype="float32")
        model = init_model(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        tc1 = TrainConfig(microbatches=1)
        tc4 = TrainConfig(microbatches=4)
        s1, m1 = train_step(init_train_state(model, tc1), batch, cfg, tc1)
        s4, m4 = train_step(init_train_state(model, tc4), batch, cfg, tc4)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         s1.params, s4.params)
        assert max(jax.tree.leaves(d)) < 1e-4


class TestTrainingLoop:
    def test_loss_decreases_on_structured_data(self):
        cfg = get_smoke("stablelm-1.6b")
        tc = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=60)
        model = init_model(jax.random.PRNGKey(0), cfg)
        state = init_train_state(model, tc)
        data = make_batches(DataConfig(vocab=cfg.vocab, seq_len=64, batch=8))
        step = jax.jit(lambda s, b: train_step(s, b, cfg, tc))
        losses = []
        for i, b in zip(range(60), data):
            state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, losses[::10]


class TestData:
    def test_shapes_and_range(self):
        it = make_batches(DataConfig(vocab=512, seq_len=64, batch=4))
        b = next(it)
        assert b["tokens"].shape == (4, 64) and b["labels"].shape == (4, 64)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 512

    def test_labels_are_shifted_tokens(self):
        it = make_batches(DataConfig(vocab=128, seq_len=16, batch=2))
        b = next(it)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_rank_sharding_differs(self):
        b0 = next(make_batches(DataConfig(vocab=128, seq_len=16, batch=2, rank=0)))
        b1 = next(make_batches(DataConfig(vocab=128, seq_len=16, batch=2, rank=1)))
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_deterministic_by_seed(self):
        b0 = next(make_batches(DataConfig(vocab=128, seq_len=16, batch=2, seed=7)))
        b1 = next(make_batches(DataConfig(vocab=128, seq_len=16, batch=2, seed=7)))
        np.testing.assert_array_equal(b0["tokens"], b1["tokens"])


class TestCheckpoint:
    def test_roundtrip_nested_state(self):
        cfg = get_smoke("qwen1.5-32b")
        model = init_model(jax.random.PRNGKey(0), cfg)
        state = init_train_state(model, TrainConfig())
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 3, state, {"arch": cfg.name})
            assert latest_step(d) == 3
            zeroed = jax.tree.map(jnp.zeros_like, state)
            restored = restore_checkpoint(d, 3, zeroed)
            ok = jax.tree.map(
                lambda a, b: bool(jnp.allclose(a.astype(jnp.float32),
                                               b.astype(jnp.float32))),
                restored, state)
            assert all(jax.tree.leaves(ok))

    def test_missing_key_raises(self):
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 0, {"a": jnp.ones(3)})
            with pytest.raises(ValueError):
                restore_checkpoint(d, 0, {"a": jnp.ones(3), "b": jnp.ones(2)})


class TestServingEngine:
    def test_greedy_generation_deterministic_and_valid(self):
        cfg = get_smoke("stablelm-1.6b")
        model = init_model(jax.random.PRNGKey(0), cfg)
        sc = ServeConfig(max_seq=96, temperature=0.0)
        prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
        o1 = generate(model.params, cfg, sc, prompt, 12)
        o2 = generate(model.params, cfg, sc, prompt, 12)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        assert o1.shape == (2, 12)
        assert np.asarray(o1).min() >= 0 and np.asarray(o1).max() < cfg.vocab

    def test_generation_matches_stepwise_forward(self):
        """Greedy generate == repeated argmax over full forward (the
        engine's cache path against the no-cache oracle)."""
        import dataclasses
        from repro.models import forward_train
        cfg = dataclasses.replace(get_smoke("stablelm-1.6b"), dtype="float32")
        model = init_model(jax.random.PRNGKey(0), cfg)
        sc = ServeConfig(max_seq=64, temperature=0.0)
        prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0, cfg.vocab)
        gen = np.asarray(generate(model.params, cfg, sc, prompt, 6))[0]
        seq = np.asarray(prompt)[0].tolist()
        for _ in range(6):
            logits, _ = forward_train(
                model.params, cfg, jnp.asarray([seq]), None, remat=False)
            seq.append(int(jnp.argmax(logits[0, -1])))
        np.testing.assert_array_equal(gen, seq[6:])


class TestShardingRules:
    def test_divisibility_fallback(self):
        os.environ.setdefault("XLA_FLAGS", "")
        from repro.sharding.rules import spec_for
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # heads=14 not divisible by model=1? (1 divides everything) -> kept
        assert spec_for(("embed", "heads"), (896, 14), mesh) == P(("data",), "model")

    @given(dim=st.sampled_from([14, 25, 96, 128]),
           axis=st.sampled_from(["heads", "mlp", "vocab"]))
    @settings(max_examples=12, deadline=None)
    def test_property_never_invalid(self, dim, axis):
        from repro.sharding.rules import spec_for
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = spec_for((axis,), (dim,), mesh)
        size = 1  # all axes size 1 in this mesh
        assert dim % size == 0  # trivially consistent; exercised on 512-dev
                                # meshes in the dry-run itself
