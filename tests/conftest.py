"""Test bootstrap: a deterministic fallback for `hypothesis`.

The property tests use a small slice of the hypothesis API (`given`,
`settings`, `strategies.{floats,integers,booleans,sampled_from}`).  The
container does not ship hypothesis, and the suite must not die at
collection because of an optional dev dependency — so when the real
library is absent we install a minimal, seeded, deterministic stand-in
into `sys.modules` before any test module imports it.  With real
hypothesis installed (see requirements.txt extras) the shim is unused.
"""
from __future__ import annotations

import random
import sys
import types

try:  # real hypothesis wins when available
    import hypothesis  # noqa: F401
except ImportError:
    _SHIM_SEED = 0x5EED

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def integers(min_value=0, max_value=10, **_kw):
        lo, hi = int(min_value), int(max_value)
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    def lists(elements, min_size=0, max_size=8, **_kw):
        return _Strategy(
            lambda rng: [
                elements.example(rng)
                for _ in range(rng.randint(min_size, max_size))
            ]
        )

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            inner = getattr(fn, "_shim_wrapped", fn)

            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", None)
                n = n if n is not None else getattr(fn, "_shim_max_examples", 20)
                rng = random.Random(_SHIM_SEED)
                # capped: shim examples are a smoke-level property check
                for _ in range(min(n, 25)):
                    drawn_args = [s.example(rng) for s in arg_strategies]
                    drawn_kw = {
                        k: s.example(rng) for k, s in kw_strategies.items()
                    }
                    inner(*args, *drawn_args, **kwargs, **drawn_kw)

            wrapper._shim_wrapped = inner
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            if hasattr(fn, "_shim_max_examples"):
                wrapper._shim_max_examples = fn._shim_max_examples
            return wrapper

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = floats
    _st.integers = integers
    _st.booleans = booleans
    _st.sampled_from = sampled_from
    _st.lists = lists

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__version__ = "0.0-shim"
    _hyp.IS_FALLBACK_SHIM = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
