"""Chaos coverage: fault injection (sim/faults.py), the resilience
watchdog (client/resilience.py), and duplicate-safe ingestion.

The contract under test, per layer:

  * `fault_draw` is deterministic in (seed, salt, ticket) and a neutral
    `FaultSchedule` collapses to the honest provider (`faults=None`
    builds the exact pre-fault path — the decision-parity pins in
    tests/test_serving_client.py keep holding because of this);
  * `MockProvider.poll` delivers in (finish_ms, ticket) order even when
    service times invert along the submit stream (the dict-insertion-
    order bug this PR fixes);
  * hostile Retry-After hints (negative/NaN/inf) are clamped to 0 at
    every consumer boundary — the session's retry hook and the fleet
    router's dry-penalty — instead of minting past-dated defers or NaN
    routing costs;
  * ingestion is idempotent: duplicate, reordered, and late-arriving
    completion deliveries leave the session's device state, host
    mirrors, and metrics bit-exactly what a clean delivery produces
    (the hypothesis property test);
  * the watchdog recovers silent drops and stuck requests to full
    completion while the trusting control demonstrably loses work, and
    nothing ever retires twice;
  * `drain(max_idle_ms=...)` turns "a completion that will never
    arrive" into a diagnostic error instead of an infinite wait.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.client import (
    ClientSession,
    Completion,
    MockProvider,
    Request,
    ResilienceConfig,
    SessionConfig,
    SubmitResult,
    Watchdog,
    expo_retry,
    sanitize_retry_after_ms,
)
from repro.client.fleet import FleetProvider
from repro.core.policy import fair_queuing, final_adrr_olc
from repro.core.scheduler import charge_resubmit
from repro.sim import get_scenario
from repro.sim.faults import FaultSchedule, fault_draw
from repro.sim.provider import (
    FleetPhysics,
    default_physics,
    token_bucket_schedule,
)
from repro.sim.scenarios import build
from repro.sim.workload import generate

from tests.test_serving_client import batch_to_requests


def _scenario_requests(name: str, n: int, n_ticks: int, seed: int,
                       dt_ms: float = 25.0):
    sc = get_scenario(name)
    wl_cfg, sched, _, _ = build(sc, n, n_ticks, dt_ms)
    batch, jitter = generate(jax.random.PRNGKey(seed), wl_cfg, sched)
    return batch_to_requests(batch, jitter)


# ---------------------------------------------------------------------------
# fault draws + the neutral-schedule collapse
# ---------------------------------------------------------------------------

class TestFaultDraw:
    def test_deterministic_and_key_sensitive(self):
        fs = FaultSchedule(seed=7, drop_frac=0.3, stuck_frac=0.3,
                           dup_frac=0.3)
        a = [fault_draw(fs, 0, t) for t in range(64)]
        b = [fault_draw(fs, 0, t) for t in range(64)]
        assert a == b  # replayable
        # ticket and salt both move the stream
        assert a != [fault_draw(fs, 1, t) for t in range(64)]
        assert any(fault_draw(fs, 0, t) != fault_draw(fs, 0, t + 64)
                   for t in range(64))

    def test_frequencies_roughly_match(self):
        fs = FaultSchedule(seed=3, drop_frac=0.2, stuck_frac=0.5,
                           dup_frac=0.8)
        n = 2000
        draws = [fault_draw(fs, 0, t) for t in range(n)]
        assert abs(sum(d.drop for d in draws) / n - 0.2) < 0.05
        assert abs(sum(d.stuck for d in draws) / n - 0.5) < 0.05
        assert abs(sum(d.dup for d in draws) / n - 0.8) < 0.05

    def test_neutral_schedule_collapses_to_none(self):
        assert not FaultSchedule().injects
        assert FaultSchedule(drop_frac=0.1).injects
        assert FaultSchedule(retry_lie_mult=0.5).injects
        # a provider built with a neutral schedule takes the honest path
        assert MockProvider(faults=FaultSchedule())._faults is None
        assert MockProvider(faults=None)._faults is None
        # the Scenario property applies the same collapse
        sc = get_scenario("balanced")._replace(
            fault_schedule=FaultSchedule())
        assert sc.faults is None
        assert get_scenario("silent_drop").faults is not None

    def test_fault_scenarios_are_registered(self):
        for name in ("silent_drop", "stuck_tail", "dup_storm"):
            assert get_scenario(name).faults is not None


# ---------------------------------------------------------------------------
# Retry-After sanitization (session hook + fleet dry-penalty)
# ---------------------------------------------------------------------------

class TestSanitizeRetryAfter:
    def test_clamp(self):
        assert sanitize_retry_after_ms(float("nan")) == 0.0
        assert sanitize_retry_after_ms(float("inf")) == 0.0
        assert sanitize_retry_after_ms(float("-inf")) == 0.0
        assert sanitize_retry_after_ms(-1500.0) == 0.0
        assert sanitize_retry_after_ms(0.0) == 0.0
        assert sanitize_retry_after_ms(1500.0) == 1500.0

    def test_retry_policies_survive_hostile_hints(self):
        pol = expo_retry(jitter=0.0)
        for hostile in (float("nan"), float("-inf"), -42.0):
            d = pol(sanitize_retry_after_ms(hostile), 1)
            assert np.isfinite(d) and d >= 0.0

    def test_fleet_dry_penalty_stays_finite(self):
        class NaNBouncer:
            def submit(self, req, now_ms, inflight_hint=None):
                return SubmitResult(False, float("nan"))

            def poll(self, now_ms):
                return []

            def inflight(self):
                return 0

            def next_event_ms(self, now_ms):
                return None

        phys = default_physics()
        fphys = FleetPhysics(*(jnp.asarray(a)[None] for a in phys))
        fleet = FleetProvider([NaNBouncer()], fphys)
        req = Request(rid=0, prompt=None, max_new=100.0, p50=100.0, bucket=0)
        res = fleet.submit(req, 100.0)
        assert not res.accepted
        # an unsanitized NaN penalty would poison every later argmin
        assert np.isfinite(fleet._dry_penalty).all()
        assert np.isfinite(fleet._dry_until).all()
        ep, cost = fleet.route(100.0, 200.0)
        assert np.isfinite(cost)

    def test_session_survives_lying_retry_after(self):
        """A rate-limited provider whose Retry-After hints are negative:
        the session must neither crash nor thrash, and the workload
        still drains to completion after the bucket refills."""
        n_ticks = 4000
        refill, cap = token_bucket_schedule(n_ticks, 25.0, (0.5, 0.5), 1.5)
        prov = MockProvider(
            dt_ms=25.0, tb_refill=np.asarray(refill),
            tb_capacity=np.asarray(cap),
            faults=FaultSchedule(retry_lie_mult=-1.0))
        sess = ClientSession(prov, final_adrr_olc(), SessionConfig(),
                             clock="virtual")
        for r in _scenario_requests("balanced", 24, n_ticks, seed=0):
            sess.submit(r)
        out = sess.drain(max_polls=n_ticks)
        # every request reaches a terminal state (the backlog the tight
        # limiter builds may push a straggler into a policy reject —
        # that is the overload ladder working, not a hang)
        assert sess.unfinished == 0
        assert all(r.status in ("completed", "rejected", "abandoned")
                   for r in out)
        assert sum(r.status == "completed" for r in out) >= 0.9 * len(out)
        assert sess.stats.n_throttled > 0  # the limiter actually bit


# ---------------------------------------------------------------------------
# MockProvider delivery order + fault mechanics
# ---------------------------------------------------------------------------

class TestMockProviderFaults:
    def test_poll_orders_by_finish_not_insertion(self):
        """Ticket 0 is submitted first but finishes last (jitter-
        inverted service): delivery must be (finish, ticket)-sorted,
        not dict-insertion-ordered."""
        prov = MockProvider(dt_ms=25.0)
        slow = Request(rid=0, prompt=None, max_new=400.0, p50=400.0,
                       bucket=2, jitter=10.0)
        fast = Request(rid=1, prompt=None, max_new=400.0, p50=400.0,
                       bucket=2, jitter=0.1)
        t0 = prov.submit(slow, 25.0).ticket
        t1 = prov.submit(fast, 25.0).ticket
        comps = prov.poll(1e9)
        assert [c.ticket for c in comps] == [t1, t0]
        assert comps[0].finish_ms < comps[1].finish_ms

    def test_drop_stuck_dup_mechanics(self):
        fs = FaultSchedule(seed=5, drop_frac=0.25, dup_frac=0.25,
                           dup_extra=2, dup_delay_ms=50.0,
                           dup_jitter_ms=3.0)
        prov = MockProvider(dt_ms=25.0, faults=fs)
        n = 64
        for i in range(n):
            r = Request(rid=i, prompt=None, max_new=50.0, p50=50.0,
                        bucket=0, jitter=1.0)
            assert prov.submit(r, 25.0).accepted
        first = prov.poll(5e4)
        late = prov.poll(1e9)   # drains the delayed dup redeliveries
        assert prov.n_dropped > 0 and prov.n_duped > 0
        # dropped tickets appear nowhere; duped tickets appear 1+extra
        # times in total with diverging finish stamps
        seen: dict[int, list[float]] = {}
        for c in first + late:
            seen.setdefault(c.ticket, []).append(c.finish_ms)
        for t in range(n):
            d = fault_draw(fs, 0, t)
            if d.drop:
                assert t not in seen
            elif d.dup:
                assert len(seen[t]) == 1 + fs.dup_extra
                assert len(set(seen[t])) == 1 + fs.dup_extra
            else:
                assert len(seen[t]) == 1
        assert prov.inflight() == 0

    def test_stuck_inflates_service(self):
        fs = FaultSchedule(seed=0, stuck_frac=1.0, stuck_mult=400.0)
        honest, faulty = MockProvider(dt_ms=25.0), MockProvider(
            dt_ms=25.0, faults=fs)
        r = Request(rid=0, prompt=None, max_new=100.0, p50=100.0,
                    bucket=1, jitter=1.0)
        honest.submit(r, 25.0)
        faulty.submit(r, 25.0)
        (f_honest,), = ({f for f, _ in honest._outstanding.values()},)
        (f_stuck,), = ({f for f, _ in faulty._outstanding.values()},)
        assert f_stuck - 25.0 == pytest.approx(
            400.0 * (f_honest - 25.0), rel=1e-5)
        assert faulty.n_stuck == 1


# ---------------------------------------------------------------------------
# charge_resubmit + Watchdog bookkeeping
# ---------------------------------------------------------------------------

class TestChargeResubmit:
    def test_debits_adrr_only(self):
        adrr, fq = final_adrr_olc(), fair_queuing()
        deficit = jnp.asarray([4.0, 8.0], jnp.float32)
        charge = jnp.asarray([1.5, 0.0], jnp.float32)
        out = charge_resubmit(adrr, deficit, charge)
        np.testing.assert_array_equal(np.asarray(out), [2.5, 8.0])
        # non-ADRR allocators ignore the charge entirely
        np.testing.assert_array_equal(
            np.asarray(charge_resubmit(fq, deficit, charge)),
            np.asarray(deficit))

    def test_zero_and_hostile_charges_are_noops(self):
        adrr = final_adrr_olc()
        deficit = jnp.asarray([4.0, 8.0], jnp.float32)
        for charge in ([0.0, 0.0], [np.nan, 1.0], [np.inf, 0.0]):
            out = charge_resubmit(
                adrr, deficit, jnp.asarray(charge, jnp.float32))
            np.testing.assert_array_equal(
                np.asarray(out), np.asarray(deficit))


class TestWatchdog:
    def _req(self, p90=100.0):
        return Request(rid=0, prompt=None, max_new=100.0, p50=100.0,
                       bucket=0, p90=p90)

    def test_deadline_and_budget_lifecycle(self):
        wd = Watchdog(ResilienceConfig(timeout_mult=2.0,
                                       min_deadline_ms=1.0,
                                       max_resubmits=1),
                      default_physics())
        req = self._req()
        d = wd.deadline_ms(req)
        assert d > 0 and np.isfinite(d)
        wd.note_admit(7, req, ticket=11, now_ms=100.0)
        assert wd.overdue(100.0 + d - 1.0) == []
        assert wd.overdue(100.0 + d) == [7]
        assert wd.budget_left(7)
        wd.note_resubmit(7, req, ticket=12, now_ms=100.0 + d)
        assert not wd.budget_left(7)
        # bounce pushes the next check out without consuming budget
        wd.note_bounced(7, 500.0, 200.0 + d)
        assert wd.overdue(200.0 + d + 499.0) == []
        assert wd.overdue(200.0 + d + 500.0) == [7]
        wd.give_up(7)
        assert wd.overdue(1e12) == []          # gave up: no more scans
        assert wd.next_deadline_ms() == float("inf")
        assert sorted(wd.note_terminal(7)) == [11, 12]  # both racing tickets
        assert wd.note_terminal(7) == []       # idempotent

    def test_next_deadline_is_min_pending(self):
        wd = Watchdog(ResilienceConfig(), default_physics())
        wd.note_admit(1, self._req(p90=50.0), ticket=1, now_ms=0.0)
        wd.note_admit(2, self._req(p90=5000.0), ticket=2, now_ms=0.0)
        assert wd.next_deadline_ms() == pytest.approx(
            wd.deadline_ms(self._req(p90=50.0)))


# ---------------------------------------------------------------------------
# duplicate-safe ingestion: the idempotence property
# ---------------------------------------------------------------------------

class _PerturbingProvider:
    """Wraps an honest provider and breaks DELIVERY only: completions
    may be duplicated in the same poll (identical payload), redelivered
    in later polls with a diverging finish stamp (the dead-ticket path,
    including arbitrarily late — after retirement), and every poll's
    batch is shuffled.  First delivery of each ticket is never delayed,
    so the information content of the stream is unchanged — which is
    exactly why the session's state must be unchanged too."""

    def __init__(self, inner, rng, dup_p: float, late_p: float):
        self.inner = inner
        self._rng = rng
        self._dup_p = dup_p
        self._late_p = late_p
        self._poll_no = 0
        self._late: list[tuple[int, Completion]] = []

    def submit(self, req, now_ms, inflight_hint=None):
        return self.inner.submit(req, now_ms, inflight_hint=inflight_hint)

    def poll(self, now_ms):
        self._poll_no += 1
        fresh = list(self.inner.poll(now_ms))
        out = list(fresh)
        for c in fresh:
            if self._rng.random() < self._dup_p:
                out.append(c)  # same-poll dup: identical payload copy
            if self._rng.random() < self._late_p:
                at = self._poll_no + self._rng.randint(1, 400)
                self._late.append((at, Completion(
                    c.ticket,
                    c.finish_ms + self._rng.uniform(1.0, 1e4), None)))
        due = [c for at, c in self._late if at <= self._poll_no]
        if due:
            self._late = [(at, c) for at, c in self._late
                          if at > self._poll_no]
            out.extend(due)
        self._rng.shuffle(out)
        return out

    def inflight(self):
        return self.inner.inflight()

    def next_event_ms(self, now_ms):
        return self.inner.next_event_ms(now_ms)


def _run_fixed(provider, reqs, n_ticks: int) -> ClientSession:
    sess = ClientSession(provider, final_adrr_olc(), SessionConfig(),
                         clock="virtual")
    for r in reqs:
        sess.submit(r)
    for _ in range(n_ticks):
        sess.poll()
    return sess


class TestIngestionIdempotence:
    N, TICKS = 24, 700

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=3),
           perturb_seed=st.integers(min_value=0, max_value=10_000),
           dup_p=st.floats(min_value=0.0, max_value=1.0),
           late_p=st.floats(min_value=0.0, max_value=1.0))
    def test_duplicate_reorder_late_deliveries_are_invisible(
            self, seed, perturb_seed, dup_p, late_p):
        """Bit-exact idempotence: a delivery layer that duplicates,
        reorders, and re-sends retired tickets produces the same device
        state, host mirrors, per-request outcomes, and metrics as clean
        exactly-once delivery."""
        import random
        reqs = _scenario_requests("balanced", self.N, self.TICKS, seed)
        clean = _run_fixed(MockProvider(dt_ms=25.0), reqs, self.TICKS)
        perturbed = _run_fixed(
            _PerturbingProvider(MockProvider(dt_ms=25.0),
                                random.Random(perturb_seed), dup_p, late_p),
            [r.__class__(**{f.name: getattr(r, f.name)
                            for f in r.__dataclass_fields__.values()})
             for r in reqs],
            self.TICKS)
        assert clean.stats.n_dup_discarded == 0
        assert clean.stats.n_late_discarded == 0
        # device state + window batch, leaf for leaf, bit for bit
        for a, b in zip(jax.tree_util.tree_leaves(clean._state),
                        jax.tree_util.tree_leaves(perturbed._state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(clean._win_batch),
                        jax.tree_util.tree_leaves(perturbed._win_batch)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # host mirrors
        for name in ("_slot_rid", "_slot_status", "_slot_arrival",
                     "_slot_thresh", "_slot_finish"):
            np.testing.assert_array_equal(getattr(clean, name),
                                          getattr(perturbed, name))
        assert clean._n_live == perturbed._n_live
        # metrics + per-request outcomes
        for f in ("n_polls", "n_admitted", "n_completed", "n_abandoned",
                  "n_rejected", "n_deferred", "n_throttled"):
            assert getattr(clean.stats, f) == getattr(perturbed.stats, f)
        for rc, rp in zip(clean.requests(), perturbed.requests()):
            assert (rc.status, rc.finish_s) == (rp.status, rp.finish_s)


# ---------------------------------------------------------------------------
# recovery: watchdog on vs trusting control, zero double-retires
# ---------------------------------------------------------------------------

def _terminal_consistency(sess: ClientSession) -> int:
    """Terminal-counter excess over per-request terminal statuses — a
    double-retired slot shows up as a positive excess."""
    n_status = sum(1 for r in sess.requests()
                   if r.status in ("completed", "abandoned", "rejected"))
    return (sess.stats.n_completed + sess.stats.n_abandoned
            + sess.stats.n_rejected) - n_status


class TestRecovery:
    RES = ResilienceConfig(timeout_mult=3.0, max_resubmits=3)

    def _run(self, name, resilience, n=32, n_ticks=9000, seed=0):
        sc = get_scenario(name)
        prov = MockProvider.from_scenario(sc, n, n_ticks, 25.0, 2)
        sess = ClientSession(prov, final_adrr_olc(), SessionConfig(),
                             clock="virtual", resilience=resilience)
        for r in _scenario_requests(name, n, n_ticks, seed):
            sess.submit(r)
        polls = 0
        while sess.unfinished and polls < n_ticks:
            sess.poll()
            polls += 1
        return sess, prov

    @pytest.mark.parametrize("name", ["silent_drop", "stuck_tail"])
    def test_watchdog_recovers_what_the_control_loses(self, name):
        on, prov_on = self._run(name, self.RES)
        off, prov_off = self._run(name, None)
        n = len(on.requests())
        comp_on = sum(r.status == "completed" for r in on.requests()) / n
        comp_off = sum(r.status == "completed" for r in off.requests()) / n
        # the fault actually fired, the watchdog actually worked
        assert prov_on.n_dropped + prov_on.n_stuck > 0
        assert on.stats.n_resubmitted > 0
        assert comp_on >= 0.99
        assert on.unfinished == 0
        # the trusting control visibly loses the faulted work
        assert comp_off <= comp_on - 0.05
        assert off.unfinished > 0  # wedged INFLIGHT slots, forever
        # nothing retired twice, with or without the watchdog
        assert _terminal_consistency(on) == 0
        assert _terminal_consistency(off) == 0

    def test_dup_storm_completes_without_double_retire(self):
        on, _ = self._run("dup_storm", self.RES, n_ticks=6000)
        off, _ = self._run("dup_storm", None, n_ticks=6000)
        for sess in (on, off):
            assert all(r.status == "completed" for r in sess.requests())
            assert sess.stats.n_dup_discarded > 0
            assert _terminal_consistency(sess) == 0

    def test_clean_workload_resilience_is_invisible(self):
        """On an honest provider the armed watchdog is a no-op: same
        decisions, same outcomes, same completion stream as the
        trusting session (the resilient trace is a distinct compiled
        program — this pins its value-equivalence)."""
        n, ticks = 24, 1500
        out = []
        for res in (None, ResilienceConfig()):
            sess = ClientSession(MockProvider(dt_ms=25.0), final_adrr_olc(),
                                 SessionConfig(), clock="virtual",
                                 resilience=res)
            for r in _scenario_requests("balanced", n, ticks, seed=1):
                sess.submit(r)
            acts = []
            for _ in range(ticks):
                acts.append(sess.poll().actions)
            out.append((sess, np.stack(acts)))
        (off, a_off), (on, a_on) = out
        assert on.stats.n_resubmitted == 0 and on.stats.n_gave_up == 0
        np.testing.assert_array_equal(a_off, a_on)
        for ro, rn in zip(off.requests(), on.requests()):
            assert (ro.status, ro.finish_s) == (rn.status, rn.finish_s)


# ---------------------------------------------------------------------------
# drain liveness guard
# ---------------------------------------------------------------------------

class TestDrainLiveness:
    def test_max_idle_raises_diagnostic(self):
        """Every completion silently dropped + no watchdog: drain must
        fail fast with a diagnostic naming the wedged state, not wait
        forever."""
        prov = MockProvider(dt_ms=25.0,
                            faults=FaultSchedule(seed=1, drop_frac=1.0))
        sess = ClientSession(prov, final_adrr_olc(), SessionConfig(),
                             clock="virtual")
        for r in _scenario_requests("balanced", 4, 2000, seed=0):
            sess.submit(r)
        with pytest.raises(RuntimeError) as ei:
            sess.drain(max_idle_ms=2_000.0)
        msg = str(ei.value)
        assert "no progress" in msg
        assert "live slots" in msg
        assert "inflight" in msg
        assert "rid=" in msg

    def test_max_idle_not_triggered_on_healthy_drain(self):
        sess = ClientSession(MockProvider(dt_ms=25.0), final_adrr_olc(),
                             SessionConfig(), clock="virtual")
        for r in _scenario_requests("balanced", 8, 2000, seed=0):
            sess.submit(r)
        out = sess.drain(max_polls=4000, max_idle_ms=60_000.0)
        assert all(r.status == "completed" for r in out)
