"""Per-kernel allclose sweeps (interpret=True on CPU) against the pure-jnp
oracles, over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional dev dependency: conftest.py installs a deterministic fallback
# shim when the real library is absent, so this normally never skips.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.sched_score.ops import (
    sched_compact_topb,
    sched_score_argmax,
    sched_score_topb,
)
from repro.kernels.sched_score.ref import (
    sched_compact_topb_ref,
    sched_score_argmax_ref,
    sched_score_topb_ref,
)
from repro.kernels.ssd_scan.ops import ssd_intra
from repro.kernels.ssd_scan.ref import ssd_intra_ref

TOLS = {jnp.float32: dict(atol=3e-5, rtol=3e-5),
        jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,KV,hd,window,bq,bk",
        [
            (1, 512, 4, 4, 64, 0, 128, 128),     # MHA
            (2, 512, 8, 2, 64, 0, 256, 128),     # GQA
            (1, 1024, 4, 1, 128, 0, 256, 256),   # MQA, wide head
            (1, 512, 4, 2, 64, 200, 128, 128),   # sliding window
            (1, 768, 6, 3, 32, 0, 256, 256),     # non-pow2 heads
        ])
    def test_matches_oracle(self, dtype, B, S, H, KV, hd, window, bq, bk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (B, S, H, hd), dtype)
        k = rand(ks[1], (B, S, KV, hd), dtype)
        v = rand(ks[2], (B, S, KV, hd), dtype)
        out = flash_attention(q, k, v, window=window, bq=bq, bk=bk)
        ref = flash_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOLS[dtype])

    def test_block_shape_invariance(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = rand(ks[0], (1, 1024, 4, 64), jnp.float32)
        k = rand(ks[1], (1, 1024, 2, 64), jnp.float32)
        v = rand(ks[2], (1, 1024, 2, 64), jnp.float32)
        o1 = flash_attention(q, k, v, bq=128, bk=256)
        o2 = flash_attention(q, k, v, bq=512, bk=512)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,KV,hd,n_valid,bk",
        [
            (1, 1024, 8, 8, 64, 1000, 256),
            (4, 2048, 8, 2, 64, 1, 512),         # single valid entry
            (2, 1024, 16, 2, 128, 555, 256),
            (1, 4096, 4, 1, 64, 4096, 1024),     # fully valid, MQA
        ])
    def test_matches_oracle(self, dtype, B, S, H, KV, hd, n_valid, bk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (B, H, hd), dtype)
        k = rand(ks[1], (B, S, KV, hd), dtype)
        v = rand(ks[2], (B, S, KV, hd), dtype)
        valid = jnp.arange(S) < n_valid
        out = decode_attention(q, k, v, valid, bk=bk)
        ref = decode_attention_ref(q, k, v, valid)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOLS[dtype])

    def test_ring_mask_pattern(self):
        """Non-contiguous validity (ring cache wrap) handled exactly."""
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        B, S, H, KV, hd = 1, 512, 4, 2, 64
        q = rand(ks[0], (B, H, hd), jnp.float32)
        k = rand(ks[1], (B, S, KV, hd), jnp.float32)
        v = rand(ks[2], (B, S, KV, hd), jnp.float32)
        valid = (jnp.arange(S) % 3) != 1
        out = decode_attention(q, k, v, valid, bk=128)
        ref = decode_attention_ref(q, k, v, valid)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


class TestSSDScan:
    @pytest.mark.parametrize(
        "B,nc,Q,H,P,N",
        [
            (1, 2, 32, 2, 16, 16),
            (2, 4, 64, 4, 32, 32),
            (1, 1, 128, 8, 64, 128),   # mamba2-780m native tile
            (2, 3, 16, 5, 8, 24),      # odd head count
        ])
    def test_matches_oracle(self, B, nc, Q, H, P, N):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        xc = jax.random.normal(ks[0], (B, nc, Q, H, P), jnp.float32)
        Bc = jax.random.normal(ks[1], (B, nc, Q, N)) * 0.5
        Cc = jax.random.normal(ks[2], (B, nc, Q, N)) * 0.5
        dtc = jax.nn.softplus(jax.random.normal(ks[3], (B, nc, Q, H)))
        A = jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
        cum = jnp.cumsum(-A[None, None, None, :] * dtc, axis=2)
        y1, s1 = ssd_intra(xc, Bc, Cc, dtc, cum)
        y2, s2 = ssd_intra_ref(xc, Bc, Cc, dtc, cum)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-4, rtol=1e-4)

    def test_end_to_end_through_model_path(self):
        """ssd_chunked(impl='pallas') == ssd_chunked(impl='xla')."""
        from repro.models.ssm import ssd_chunked
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        B, S, H, P, N = 2, 96, 3, 16, 16
        x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        A = jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
        y1, s1 = ssd_chunked(x, Bm, Cm, dt, A, chunk=32, impl="pallas")
        y2, s2 = ssd_chunked(x, Bm, Cm, dt, A, chunk=32, impl="xla")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


class TestSchedScore:
    @given(seed=st.integers(0, 1000), nb=st.sampled_from([1, 2, 8]),
           density=st.floats(0.01, 1.0))
    @settings(max_examples=25, deadline=None)
    def test_property_matches_oracle(self, seed, nb, density):
        n = 512 * nb
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        wait = jax.random.uniform(ks[0], (n,)) * 1e4
        cost = jax.random.uniform(ks[1], (n,)) * 4000 + 16
        urg = jax.random.uniform(ks[2], (n,)) * 2
        mask = jax.random.bernoulli(ks[3], density, (n,))
        w = jnp.asarray([1.0, 0.6, 0.8, 512.0])
        i1, s1 = sched_score_argmax(wait, cost, urg, mask, w, blk=512)
        i2, s2 = sched_score_argmax_ref(wait, cost, urg, mask, w)
        assert float(s1) == pytest.approx(float(s2), rel=1e-5)
        if bool(mask.any()):
            assert bool(mask[int(i1)])

    def test_all_masked_returns_sentinel(self):
        n = 512
        z = jnp.zeros((n,))
        w = jnp.asarray([1.0, 0.6, 0.8, 512.0])
        i, s = sched_score_argmax(z, z + 100, z, jnp.zeros((n,), bool), w)
        assert float(s) <= -1e29


class TestSchedScoreTopB:
    """Fused partial top-B vs the `lax.top_k` oracle: exact index AND
    exact score equality, including first-occurrence tie-breaking — the
    property the windowed scheduler's bit-exact contract rests on."""

    W = jnp.asarray([1.0, 0.8, 0.5, 650.0], jnp.float32)

    def _features(self, n, seed, density=0.7):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        wait = jax.random.uniform(ks[0], (n,)) * 5e3
        cost = jax.random.uniform(ks[1], (n,)) * 3000 + 0.5
        urg = jax.random.uniform(ks[2], (n,)) * 2
        mask = jax.random.bernoulli(ks[3], density, (n,))
        return wait, cost, urg, mask

    def _check(self, n, b, blk=2048, seed=0, density=0.7):
        wait, cost, urg, mask = self._features(n, seed, density)
        ik, sk = sched_score_topb(wait, cost, urg, mask, self.W, b, blk=blk)
        ir, sr = sched_score_topb_ref(wait, cost, urg, mask, self.W,
                                      min(b, n))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))

    @given(seed=st.integers(0, 1000), nb=st.sampled_from([1, 2, 5]),
           b=st.sampled_from([1, 4, 16]), density=st.floats(0.0, 1.0))
    @settings(max_examples=20, deadline=None)
    def test_property_matches_topk(self, seed, nb, b, density):
        self._check(512 * nb, b, blk=512, seed=seed, density=density)

    @pytest.mark.parametrize("n", [7, 96, 130, 1000, 5000])
    def test_non_lane_aligned_lengths(self, n):
        """Queue lengths that are not multiples of the TPU lane width or
        the block size exercise the mask=False padding in ops.py."""
        self._check(n, min(8, n), blk=512, seed=3)

    def test_window_sized_queues(self):
        """Window capacities the engine actually uses, aligned or not."""
        for w in (96, 128, 192, 4096):
            self._check(w, 16, blk=1024, seed=4)

    def test_tie_breaking_first_occurrence(self):
        """Duplicate feature rows produce exact score ties; the kernel
        must rank equal scores by ascending index like lax.top_k."""
        n, half = 512, 256
        wait, cost, urg, _ = self._features(n, seed=9, density=1.0)
        wait = wait.at[half:].set(wait[:half])
        cost = cost.at[half:].set(cost[:half])
        urg = urg.at[half:].set(urg[:half])
        mask = jnp.ones((n,), bool)
        ik, sk = sched_score_topb(wait, cost, urg, mask, self.W, 32, blk=128)
        ir, sr = sched_score_topb_ref(wait, cost, urg, mask, self.W, 32)
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))

    def test_b_exceeds_eligible(self):
        """b far above the eligible count: the exhausted region must
        still mirror top_k (first-occurrence over masked sentinels)."""
        self._check(64, 32, blk=128, seed=5, density=0.05)
        self._check(100, 16, seed=6, density=0.0)  # nothing eligible

    def test_b_equals_n(self):
        self._check(16, 16, seed=7)

    def test_fifo_weight_row_matches_topk_on_arrival(self):
        """The FIFO emulation (weights [1,0,0,1], -arrival in the wait
        slot) must reproduce lax.top_k(-arrival) exactly — this is the
        rank_fifo pallas path."""
        n, b = 300, 8
        arrival = jax.random.uniform(jax.random.PRNGKey(8), (n,)) * 1e5
        mask = jax.random.bernoulli(jax.random.PRNGKey(9), 0.5, (n,))
        w_fifo = jnp.asarray([1.0, 0.0, 0.0, 1.0], jnp.float32)
        ones, zeros = jnp.ones((n,)), jnp.zeros((n,))
        ik, _ = sched_score_topb(-arrival, ones, zeros, mask, w_fifo, b)
        key = jnp.where(mask, arrival, jnp.inf)
        _, ir = jax.lax.top_k(-key, b)
        live = np.asarray(mask.sum())
        np.testing.assert_array_equal(
            np.asarray(ik)[:live], np.asarray(ir)[:live])


class TestSchedScoreRoute:
    """Route-term parity: every sched_score kernel with a (5,) weights
    vector and a route feature row must match its oracle exactly — the
    fleet scheduler's endpoint-aware score rides this fifth term."""

    W5 = jnp.asarray([1.0, 0.8, 0.5, 650.0, 400.0], jnp.float32)

    def _features(self, n, seed, density=0.7):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        wait = jax.random.uniform(ks[0], (n,)) * 5e3
        cost = jax.random.uniform(ks[1], (n,)) * 3000 + 0.5
        urg = jax.random.uniform(ks[2], (n,)) * 2
        mask = jax.random.bernoulli(ks[3], density, (n,))
        route = jax.random.uniform(ks[4], (n,)) * 3.0
        return wait, cost, urg, mask, route

    @given(seed=st.integers(0, 1000), density=st.floats(0.01, 1.0))
    @settings(max_examples=15, deadline=None)
    def test_argmax_matches_oracle(self, seed, density):
        wait, cost, urg, mask, route = self._features(512, seed, density)
        i1, s1 = sched_score_argmax(wait, cost, urg, mask, self.W5,
                                    route, blk=512)
        i2, s2 = sched_score_argmax_ref(wait, cost, urg, mask, self.W5,
                                        route)
        assert float(s1) == float(s2)
        if bool(mask.any()):
            assert int(i1) == int(i2)

    @given(seed=st.integers(0, 1000), b=st.sampled_from([1, 8, 16]),
           density=st.floats(0.0, 1.0))
    @settings(max_examples=15, deadline=None)
    def test_topb_matches_oracle(self, seed, b, density):
        wait, cost, urg, mask, route = self._features(512, seed, density)
        ik, sk = sched_score_topb(wait, cost, urg, mask, self.W5, b,
                                  route, blk=512)
        ir, sr = sched_score_topb_ref(wait, cost, urg, mask, self.W5, b,
                                      route)
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))

    @given(seed=st.integers(0, 1000), b=st.sampled_from([1, 8, 32]),
           density=st.floats(0.0, 1.0))
    @settings(max_examples=15, deadline=None)
    def test_compact_topb_matches_oracle(self, seed, b, density):
        w = 256
        ks = jax.random.split(jax.random.PRNGKey(seed + 7), 2)
        req = jax.random.permutation(
            ks[0], jnp.arange(w * 3, dtype=jnp.int32))[:w]
        alive = jax.random.bernoulli(ks[1], density, (w,))
        wait, cost, urg, _, route = self._features(w, seed, density)
        ck, nk, ik, sk = sched_compact_topb(
            req, alive, wait, cost, urg, self.W5, b, route, blk=128)
        cr, nr, ir, sr = sched_compact_topb_ref(
            req, alive, wait, cost, urg, self.W5, min(b, w), route)
        assert int(nk) == int(nr)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))

    def test_route_none_matches_four_weight(self):
        """Omitting route with a (4,) weights vector is the pre-fleet
        path — it must stay byte-identical to passing route=None."""
        wait, cost, urg, mask, _ = self._features(512, seed=3)
        w4 = self.W5[:4]
        i1, s1 = sched_score_topb(wait, cost, urg, mask, w4, 8, blk=512)
        i2, s2 = sched_score_topb(wait, cost, urg, mask, w4, 8, None,
                                  blk=512)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))

    def test_zero_route_weight_matches_no_route(self):
        """w_route == 0 with an arbitrary route row ranks identically to
        the route-free kernel (score algebra appends `- 0 * route`,
        which is exact in float)."""
        wait, cost, urg, mask, route = self._features(512, seed=5)
        w5 = jnp.asarray([1.0, 0.8, 0.5, 650.0, 0.0], jnp.float32)
        ik, sk = sched_score_topb(wait, cost, urg, mask, w5, 8, route,
                                  blk=512)
        ir, sr = sched_score_topb(wait, cost, urg, mask, w5[:4], 8,
                                  blk=512)
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


class TestCompactTopB:
    """Fused compaction + score + top-B tick megakernel vs the two-pass
    oracle (XLA cumsum-scatter, then `sched_score_topb` over the
    compacted pool): exact equality on the compacted ids, the live
    count, and the (idx, score) ranking — including first-occurrence
    ties, the exhausted region, and an undersized (fully live) window."""

    W = jnp.asarray([1.0, 0.8, 0.5, 650.0], jnp.float32)

    def _pool(self, w, seed, density=0.7):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        req = jax.random.permutation(
            ks[0], jnp.arange(w * 3, dtype=jnp.int32))[:w]
        alive = jax.random.bernoulli(ks[1], density, (w,))
        wait = jax.random.uniform(ks[2], (w,)) * 5e3
        cost = jax.random.uniform(ks[3], (w,)) * 3000 + 0.5
        urg = jax.random.uniform(ks[4], (w,)) * 2
        return req, alive, wait, cost, urg

    def _check(self, w, b, seed=0, density=0.7, blk=128):
        req, alive, wait, cost, urg = self._pool(w, seed, density)
        ck, nk, ik, sk = sched_compact_topb(
            req, alive, wait, cost, urg, self.W, b, blk=blk)
        cr, nr, ir, sr = sched_compact_topb_ref(
            req, alive, wait, cost, urg, self.W, min(b, w))
        assert int(nk) == int(nr)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))

    @given(seed=st.integers(0, 1000), w=st.sampled_from([128, 256, 512]),
           b=st.sampled_from([1, 8, 32]), density=st.floats(0.0, 1.0))
    @settings(max_examples=15, deadline=None)
    def test_property_matches_two_pass(self, seed, w, b, density):
        self._check(w, b, seed=seed, density=density)

    def test_two_pass_kernel_parity(self):
        """The fused kernel must agree with literally running the
        existing two kernels back to back (compaction in XLA, ranking
        via `sched_score_topb`) — the path it replaces."""
        w, b = 512, 16
        req, alive, wait, cost, urg = self._pool(w, seed=11)
        ck, nk, ik, sk = sched_compact_topb(
            req, alive, wait, cost, urg, self.W, b)
        pos = jnp.cumsum(alive.astype(jnp.int32)) - 1
        tgt = jnp.where(alive, pos, w)
        cw = jnp.zeros((w,)).at[tgt].set(wait, mode="drop")
        cc = jnp.ones((w,)).at[tgt].set(cost, mode="drop")
        cu = jnp.zeros((w,)).at[tgt].set(urg, mode="drop")
        mask = jnp.arange(w) < nk
        i2, s2 = sched_score_topb(cw, cc, cu, mask, self.W, b)
        live = min(int(nk), b)
        np.testing.assert_array_equal(
            np.asarray(ik)[:live], np.asarray(i2)[:live])
        np.testing.assert_array_equal(
            np.asarray(sk)[:live], np.asarray(s2)[:live])

    def test_tie_breaking_first_occurrence(self):
        """Duplicate feature rows tie exactly; ranking must resolve by
        ascending compacted index (stable compaction keeps slot order,
        so this is also ascending slot order)."""
        w, half = 256, 128
        req, alive, wait, cost, urg = self._pool(w, seed=9, density=1.0)
        wait = wait.at[half:].set(wait[:half])
        cost = cost.at[half:].set(cost[:half])
        urg = urg.at[half:].set(urg[:half])
        alive = jnp.ones((w,), bool).at[::7].set(False)  # shift positions
        ck, nk, ik, sk = sched_compact_topb(
            req, alive, wait, cost, urg, self.W, 32)
        cr, nr, ir, sr = sched_compact_topb_ref(
            req, alive, wait, cost, urg, self.W, 32)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))

    def test_exhausted_region(self):
        """b far above the live count: ranks >= n_live must yield the
        (rank, NEG) sentinel rows exactly like top_k over the compacted
        sentinel tail."""
        self._check(128, 32, seed=5, density=0.05)
        self._check(128, 16, seed=6, density=0.0)  # nothing alive

    def test_undersized_window_fully_live(self):
        """A fully live pool (the undersized-W overflow regime: every
        slot occupied, the queue overflow waiting outside) compacts to
        the identity and still ranks exactly."""
        self._check(256, 16, seed=7, density=1.0)

    def test_non_lane_aligned_width(self):
        self._check(100, 8, seed=4, density=0.5)
        self._check(7, 4, seed=8, density=0.6)

    @pytest.mark.parametrize("w,blk", [(1024, 128), (4096, 256)])
    def test_real_queue_depths(self, w, blk):
        """The windowed engine's production capacities (window_for caps
        at 4096).  On CPU this validates via interpret mode; on TPU the
        same call compiles the kernel (interpret_mode() is False) —
        the compiled non-interpret parity pass."""
        self._check(w, 64, seed=3, density=0.6, blk=blk)

    @pytest.mark.skipif(jax.default_backend() != "tpu",
                        reason="compiled non-interpret pass needs a TPU")
    @pytest.mark.parametrize("w", [1024, 4096])
    def test_compiled_non_interpret_parity(self, w):
        """Explicit compiled-mode parity at real queue depths: force
        interpret=False regardless of backend detection."""
        req, alive, wait, cost, urg = self._pool(w, seed=12, density=0.6)
        ck, nk, ik, sk = sched_compact_topb(
            req, alive, wait, cost, urg, self.W, 64, interpret=False)
        cr, nr, ir, sr = sched_compact_topb_ref(
            req, alive, wait, cost, urg, self.W, 64)
        assert int(nk) == int(nr)
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
