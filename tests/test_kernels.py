"""Per-kernel allclose sweeps (interpret=True on CPU) against the pure-jnp
oracles, over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional dev dependency: conftest.py installs a deterministic fallback
# shim when the real library is absent, so this normally never skips.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.sched_score.ops import sched_score_argmax
from repro.kernels.sched_score.ref import sched_score_argmax_ref
from repro.kernels.ssd_scan.ops import ssd_intra
from repro.kernels.ssd_scan.ref import ssd_intra_ref

TOLS = {jnp.float32: dict(atol=3e-5, rtol=3e-5),
        jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,KV,hd,window,bq,bk",
        [
            (1, 512, 4, 4, 64, 0, 128, 128),     # MHA
            (2, 512, 8, 2, 64, 0, 256, 128),     # GQA
            (1, 1024, 4, 1, 128, 0, 256, 256),   # MQA, wide head
            (1, 512, 4, 2, 64, 200, 128, 128),   # sliding window
            (1, 768, 6, 3, 32, 0, 256, 256),     # non-pow2 heads
        ])
    def test_matches_oracle(self, dtype, B, S, H, KV, hd, window, bq, bk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (B, S, H, hd), dtype)
        k = rand(ks[1], (B, S, KV, hd), dtype)
        v = rand(ks[2], (B, S, KV, hd), dtype)
        out = flash_attention(q, k, v, window=window, bq=bq, bk=bk)
        ref = flash_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOLS[dtype])

    def test_block_shape_invariance(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = rand(ks[0], (1, 1024, 4, 64), jnp.float32)
        k = rand(ks[1], (1, 1024, 2, 64), jnp.float32)
        v = rand(ks[2], (1, 1024, 2, 64), jnp.float32)
        o1 = flash_attention(q, k, v, bq=128, bk=256)
        o2 = flash_attention(q, k, v, bq=512, bk=512)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,KV,hd,n_valid,bk",
        [
            (1, 1024, 8, 8, 64, 1000, 256),
            (4, 2048, 8, 2, 64, 1, 512),         # single valid entry
            (2, 1024, 16, 2, 128, 555, 256),
            (1, 4096, 4, 1, 64, 4096, 1024),     # fully valid, MQA
        ])
    def test_matches_oracle(self, dtype, B, S, H, KV, hd, n_valid, bk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (B, H, hd), dtype)
        k = rand(ks[1], (B, S, KV, hd), dtype)
        v = rand(ks[2], (B, S, KV, hd), dtype)
        valid = jnp.arange(S) < n_valid
        out = decode_attention(q, k, v, valid, bk=bk)
        ref = decode_attention_ref(q, k, v, valid)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOLS[dtype])

    def test_ring_mask_pattern(self):
        """Non-contiguous validity (ring cache wrap) handled exactly."""
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        B, S, H, KV, hd = 1, 512, 4, 2, 64
        q = rand(ks[0], (B, H, hd), jnp.float32)
        k = rand(ks[1], (B, S, KV, hd), jnp.float32)
        v = rand(ks[2], (B, S, KV, hd), jnp.float32)
        valid = (jnp.arange(S) % 3) != 1
        out = decode_attention(q, k, v, valid, bk=128)
        ref = decode_attention_ref(q, k, v, valid)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


class TestSSDScan:
    @pytest.mark.parametrize(
        "B,nc,Q,H,P,N",
        [
            (1, 2, 32, 2, 16, 16),
            (2, 4, 64, 4, 32, 32),
            (1, 1, 128, 8, 64, 128),   # mamba2-780m native tile
            (2, 3, 16, 5, 8, 24),      # odd head count
        ])
    def test_matches_oracle(self, B, nc, Q, H, P, N):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        xc = jax.random.normal(ks[0], (B, nc, Q, H, P), jnp.float32)
        Bc = jax.random.normal(ks[1], (B, nc, Q, N)) * 0.5
        Cc = jax.random.normal(ks[2], (B, nc, Q, N)) * 0.5
        dtc = jax.nn.softplus(jax.random.normal(ks[3], (B, nc, Q, H)))
        A = jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
        cum = jnp.cumsum(-A[None, None, None, :] * dtc, axis=2)
        y1, s1 = ssd_intra(xc, Bc, Cc, dtc, cum)
        y2, s2 = ssd_intra_ref(xc, Bc, Cc, dtc, cum)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-4, rtol=1e-4)

    def test_end_to_end_through_model_path(self):
        """ssd_chunked(impl='pallas') == ssd_chunked(impl='xla')."""
        from repro.models.ssm import ssd_chunked
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        B, S, H, P, N = 2, 96, 3, 16, 16
        x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        A = jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
        y1, s1 = ssd_chunked(x, Bm, Cm, dt, A, chunk=32, impl="pallas")
        y2, s2 = ssd_chunked(x, Bm, Cm, dt, A, chunk=32, impl="xla")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


class TestSchedScore:
    @given(seed=st.integers(0, 1000), nb=st.sampled_from([1, 2, 8]),
           density=st.floats(0.01, 1.0))
    @settings(max_examples=25, deadline=None)
    def test_property_matches_oracle(self, seed, nb, density):
        n = 512 * nb
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        wait = jax.random.uniform(ks[0], (n,)) * 1e4
        cost = jax.random.uniform(ks[1], (n,)) * 4000 + 16
        urg = jax.random.uniform(ks[2], (n,)) * 2
        mask = jax.random.bernoulli(ks[3], density, (n,))
        w = jnp.asarray([1.0, 0.6, 0.8, 512.0])
        i1, s1 = sched_score_argmax(wait, cost, urg, mask, w, blk=512)
        i2, s2 = sched_score_argmax_ref(wait, cost, urg, mask, w)
        assert float(s1) == pytest.approx(float(s2), rel=1e-5)
        if bool(mask.any()):
            assert bool(mask[int(i1)])

    def test_all_masked_returns_sentinel(self):
        n = 512
        z = jnp.zeros((n,))
        w = jnp.asarray([1.0, 0.6, 0.8, 512.0])
        i, s = sched_score_argmax(z, z + 100, z, jnp.zeros((n,), bool), w)
        assert float(s) <= -1e29
