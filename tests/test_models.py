"""Model-zoo correctness: SSD math, flash-XLA attention oracle checks,
prefill/decode vs full-forward consistency, sliding-window ring caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models import decode_step, forward_train, init_model, prefill
from repro.models.attention import _sdpa, causal_mask, flash_xla
from repro.models.ssm import ssd_chunked, ssd_step


def f32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:  # dropless for exact path comparison
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=-1.0))
    return cfg


# ---------------------------------------------------------------------------
# SSD (Mamba2) math
# ---------------------------------------------------------------------------

class TestSSD:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_chunked_matches_sequential(self, chunk):
        key = jax.random.PRNGKey(1)
        B, S, H, P, N = 2, 64, 3, 8, 16
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
        Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        A = jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)

        y_c, st_c = ssd_chunked(x, Bm, Cm, dt, A, chunk=chunk)

        st = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(S):
            a = jnp.exp(-A[None, :] * dt[:, t])
            st = a[:, :, None, None] * st + jnp.einsum(
                "bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bm[:, t])
            ys.append(jnp.einsum("bhpn,bn->bhp", st, Cm[:, t]))
        y_seq = jnp.stack(ys, 1)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_seq),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(st),
                                   atol=1e-4, rtol=1e-4)

    def test_unaligned_length_padding(self):
        key = jax.random.PRNGKey(2)
        B, S, H, P, N = 1, 37, 2, 4, 8
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        A = jnp.ones((H,))
        y16, st16 = ssd_chunked(x, Bm, Cm, dt, A, chunk=16)
        y37, st37 = ssd_chunked(x, Bm, Cm, dt, A, chunk=64)
        np.testing.assert_allclose(np.asarray(y16), np.asarray(y37), atol=1e-4)
        np.testing.assert_allclose(np.asarray(st16), np.asarray(st37), atol=1e-4)

    def test_step_matches_chunked_with_state_carry(self):
        """prefill(0:t) + step(t) == chunked(0:t+1)."""
        key = jax.random.PRNGKey(3)
        B, S, H, P, N = 2, 33, 2, 4, 8
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
        Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        A = jnp.ones((H,))
        D = jnp.zeros((H,))
        _, st_prefix = ssd_chunked(x[:, :-1], Bm[:, :-1], Cm[:, :-1],
                                   dt[:, :-1], A, chunk=16)
        y_step, st_step = ssd_step(x[:, -1], Bm[:, -1], Cm[:, -1],
                                   dt[:, -1], A, D, st_prefix)
        y_all, st_all = ssd_chunked(x, Bm, Cm, dt, A, chunk=16)
        np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_all[:, -1]),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_step), np.asarray(st_all),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# Blocked (flash-style) XLA attention vs dense oracle
# ---------------------------------------------------------------------------

class TestFlashXLA:
    @pytest.mark.parametrize("window", [0, 1536])
    def test_matches_dense_sdpa(self, window):
        key = jax.random.PRNGKey(0)
        B, S, H, KV, hd = 1, 4096, 4, 2, 32
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
        out_f = flash_xla(q, k, v, window)
        m = causal_mask(S, S, window)[None, None, None]
        out_d = _sdpa(q, k, v, m)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Full-model consistency: forward == prefill + decode, for every arch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = f32(get_smoke(arch))
    m = init_model(jax.random.PRNGKey(0), cfg)
    B, S, P = 2, 48, cfg.prefix_len or 0
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    pe = (jax.random.normal(jax.random.PRNGKey(9), (B, P, cfg.d_model)) * 0.02
          if P else None)
    logits_full, _ = forward_train(m.params, cfg, toks, pe, remat=False)
    assert np.isfinite(np.asarray(logits_full)).all()

    lp, caches = prefill(m.params, cfg, toks[:, : S - 1], max_seq=80,
                         prefix_embeds=pe)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(logits_full[:, P + S - 2]), atol=2e-4)
    ld, _ = decode_step(m.params, cfg, toks[:, S - 1:], jnp.int32(P + S - 1),
                        caches)
    np.testing.assert_allclose(
        np.asarray(ld[:, 0]), np.asarray(logits_full[:, P + S - 1]), atol=2e-4)


def test_sliding_window_ring_decode():
    """Decode far past the window: ring cache must agree with the full
    forward under the same windowed mask (starcoder2 family, window=64)."""
    cfg = f32(get_smoke("starcoder2-3b"))  # sliding_window = 64
    m = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 150  # well past the 64-token window
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    logits_full, _ = forward_train(m.params, cfg, toks, None, remat=False)

    _, caches = prefill(m.params, cfg, toks[:, : S - 8], max_seq=S)
    errs = []
    for i in range(S - 8, S):
        ld, caches = decode_step(m.params, cfg, toks[:, i:i + 1],
                                 jnp.int32(i), caches)
        errs.append(float(jnp.abs(ld[:, 0] - logits_full[:, i]).max()))
    assert max(errs) < 2e-4, errs


def test_remat_matches_no_remat():
    cfg = f32(get_smoke("qwen1.5-32b"))
    m = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 32), 0, cfg.vocab)
    l1, _ = forward_train(m.params, cfg, toks, None, remat=False)
    l2, _ = forward_train(m.params, cfg, toks, None, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With a finite capacity factor outputs differ from dropless only on
    dropped tokens; aux loss stays near 1x uniform."""
    cfg = get_smoke("phi3.5-moe-42b-a6.6b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    m = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (4, 128), 0, cfg.vocab)
    _, aux = forward_train(m.params, cfg, toks, None, remat=False)
    # Switch-style aux ~ weight * 1.0 for near-uniform routing
    assert 0.0 < float(aux) < 5 * cfg.moe.router_aux_weight
