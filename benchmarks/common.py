"""Shared benchmark harness utilities: cell execution, CSV emission,
and the persistent JAX compilation cache every benchmark driver enables
on import."""
from __future__ import annotations

import csv
import os
import time

# Benchmarks run on XLA's legacy CPU runtime: the thunk runtime's
# dispatch overhead roughly doubles the per-call latency of the small
# fused session/tick programs these drivers time (it washes out on the
# big scan programs).  Set before the first `import jax` in the process
# — `enable_compilation_cache()` below imports jax, and every driver
# imports this module first.  Deliberately scoped to benchmarks: the
# legacy LLVM emitter contracts FMAs inside fusion kernels *below* the
# HLO level, so `core.numerics.pinned` cannot equalize rounding between
# the dense and windowed engine programs there (1-ulp severity drift in
# limiter scenarios; optimized HLO is bit-identical across runtimes —
# verified by diffing `.compile().as_text()`).  The test suite runs the
# default runtime, where the cross-engine bit-exact contract holds.
_XLA_FLAG = "--xla_cpu_use_thunk_runtime=false"
if _XLA_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _XLA_FLAG).strip()

from repro.core.policy import PolicyConfig
from repro.sim import SimConfig, WorkloadConfig, run_cell, summarize

TABLE_DIR = os.path.join(os.path.dirname(__file__), "..", "paper_results", "tables")


def enable_compilation_cache() -> str:
    """Turn on JAX's persistent compilation cache for benchmark runs.

    The scheduler microbenchmarks pay ~1-4 s of XLA compile per (K, B,
    N, W) cell (BENCH_scheduler.json `compile_seconds`), and the sweep
    grid keeps growing — a warm cache turns repeat local runs and CI
    re-runs into pure execution.  Honors `JAX_COMPILATION_CACHE_DIR`
    (the CI cache points it at a restored directory); defaults to a
    gitignored `.jax_cache/` at the repo root.  Thresholds drop to zero
    so the many small-but-numerous scheduler programs are cached too.
    Returns the cache directory.
    """
    import jax

    cache_dir = os.environ.get(
        "JAX_COMPILATION_CACHE_DIR",
        os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                     ".jax_cache")))
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return cache_dir


# every benchmark driver imports this module first, so enabling here
# covers the whole suite (harmless under pytest, which doesn't)
enable_compilation_cache()

SIM = SimConfig(n_ticks=14000)
N_REQ = 160
SEEDS = 5


def merge_rows(fresh: list[dict], old: list[dict], keys: tuple) -> list[dict]:
    """Merge bench artifact rows: fresh rows win; committed rows for
    cells not re-measured (e.g. the --scale-only N=1e6 cells in a
    regular run) are preserved so a default bench run cannot silently
    drop them.  Shared by every driver that writes keyed row lists into
    BENCH_scheduler.json."""
    measured = {tuple(r[k] for k in keys) for r in fresh}
    kept = [r for r in old if tuple(r.get(k) for k in keys) not in measured]
    return fresh + kept

METRIC_COLS = [
    "short_p95_ms", "short_p90_ms", "long_p90_ms", "global_p95_ms",
    "global_std_ms", "completion_rate", "satisfaction", "goodput_rps",
    "makespan_ms", "n_rejects", "n_defer_events", "n_abandoned",
]


def cell(policy: PolicyConfig, mix: str, congestion: str,
         information: str = "coarse", predictor_noise: float = 0.0,
         n_req: int = N_REQ, seeds: int = SEEDS):
    wl = WorkloadConfig(n_requests=n_req, mix=mix, congestion=congestion,
                        information=information,
                        predictor_noise=predictor_noise)
    m = run_cell(policy, wl, seeds=seeds, sim_cfg=SIM)
    return summarize(m)


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(TABLE_DIR, exist_ok=True)
    path = os.path.join(TABLE_DIR, f"{name}.csv")
    cols = list(rows[0].keys())
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=cols)
        w.writeheader()
        w.writerows(rows)
    return path


def row_from_summary(tag: dict, s: dict) -> dict:
    out = dict(tag)
    for k in METRIC_COLS:
        out[f"{k}_mean"] = round(s[k][0], 3)
        out[f"{k}_std"] = round(s[k][1], 3)
    return out


def fmt(s: dict, keys=("short_p95_ms", "global_p95_ms", "completion_rate",
                       "satisfaction", "goodput_rps")) -> str:
    return " ".join(
        f"{k.split('_ms')[0]}={s[k][0]:.0f}±{s[k][1]:.0f}"
        if "ms" in k else f"{k}={s[k][0]:.2f}±{s[k][1]:.2f}"
        for k in keys)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
