"""Paper Table 4 (§4.6): Fair Queuing vs Short-Priority vs FIFO under a
heavy-dominated (70% long/xlong) workload.

All three variants share the same class caps and no overload control so
ONLY the allocation layer differs (the paper's point: the allocation
layer accommodates different fairness objectives without changing the
rest of the stack).
"""
import jax.numpy as jnp

from repro.core.policy import ALLOC_FQ, ALLOC_NAIVE, ALLOC_SP, base_policy

from benchmarks.common import cell, row_from_summary, write_csv


def _variant(mode):
    # Allocation-ONLY contrast: per-class quotas and congestion adaptation
    # are disabled so the three variants share one global concurrency
    # bottleneck (max_inflight) and differ purely in which class gets the
    # next send opportunity — the paper's §4.6 framing. Work is allowed to
    # wait out the full horizon (large timeout) so long-request P90
    # measures queueing delay rather than abandonment truncation.
    return base_policy(
        alloc_mode=jnp.asarray(mode, jnp.int32),
        olc_enabled=jnp.float32(0.0),
        cap_kappa=jnp.float32(0.0),
        congestion_kappa=jnp.float32(0.0),
        class_cap=jnp.asarray([1e9, 1e9], jnp.float32),
        max_inflight=jnp.float32(4.0),
        timeout_mult=jnp.full((4,), 10.0, jnp.float32),
    )


VARIANTS = [("direct_fifo", ALLOC_NAIVE), ("short_priority", ALLOC_SP),
            ("fair_queuing", ALLOC_FQ)]


def run(verbose=True):
    rows = []
    res = {}
    for name, mode in VARIANTS:
        s = cell(_variant(mode), "heavy70", "high")
        res[name] = s
        rows.append(row_from_summary({"policy": name}, s))
        if verbose:
            print(f"  {name:16s} shortP90={s['short_p90_ms'][0]:7.0f} "
                  f"longP90={s['long_p90_ms'][0]:7.0f} "
                  f"stdev={s['global_std_ms'][0]:7.0f} CR={s['completion_rate'][0]:.2f}")
    path = write_csv("fair_queuing_summary", rows)

    fifo, sp, fq = (res[n] for n, _ in VARIANTS)
    sp_gain = 1 - sp["short_p90_ms"][0] / fifo["short_p90_ms"][0]
    fq_gain = 1 - fq["short_p90_ms"][0] / fifo["short_p90_ms"][0]
    sp_tax = sp["long_p90_ms"][0] / fifo["long_p90_ms"][0] - 1
    fq_tax = fq["long_p90_ms"][0] / fifo["long_p90_ms"][0] - 1
    print(f"  short P90 gain vs FIFO: SP {sp_gain:+.0%}, FQ {fq_gain:+.0%}")
    print(f"  long P90 tax vs FIFO:   SP {sp_tax:+.0%}, FQ {fq_tax:+.0%}")
    # Paper Table 4 ordinal claims that transfer to a work-conserving
    # client (see EXPERIMENTS.md for the +116%-tax divergence note):
    print(f"  [{'PASS' if fq_gain > 0 and sp_gain > 0 else 'WARN'}] both "
          f"allocation policies improve short tails over FIFO")
    print(f"  [{'PASS' if fq_tax <= sp_tax + 0.05 else 'WARN'}] FQ pays no "
          f"more fairness tax than Short-Priority (±5%)")
    print(f"  [{'PASS' if fq['global_std_ms'][0] <= sp['global_std_ms'][0] * 1.02 else 'WARN'}] "
          f"FQ latency stdev <= Short-Priority (more uniform treatment)")
    return path


if __name__ == "__main__":
    run()
