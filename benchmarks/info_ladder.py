"""Paper Table 1 / Fig 2: four-level information ladder with Final (OLC)
fixed — the evaluation's premise test.

Validates: removing magnitude (no_info) inflates short P95 by large
multiplicative factors; coarse ~ oracle; class-only sits between.
"""
from repro.core.policy import strategy, with_information
from repro.sim.workload import REGIMES

from benchmarks.common import cell, fmt, row_from_summary, write_csv

LEVELS = ["no_info", "class_only", "coarse", "oracle"]


def run(verbose=True):
    rows = []
    for mix, cong in REGIMES:
        for level in LEVELS:
            pol = with_information(strategy("final_adrr_olc"), level)
            s = cell(pol, mix, cong, information=level)
            rows.append(row_from_summary(
                {"regime": f"{mix}/{cong}", "information": level}, s))
            if verbose:
                print(f"  {mix}/{cong:6s} {level:10s} {fmt(s)}")
    path = write_csv("prior_ablation_summary", rows)
    by = {(r["regime"], r["information"]): r for r in rows}
    for reg in ["balanced/high", "heavy/high"]:
        blind = by[(reg, "no_info")]["short_p95_ms_mean"]
        coarse = by[(reg, "coarse")]["short_p95_ms_mean"]
        oracle = by[(reg, "oracle")]["short_p95_ms_mean"]
        print(f"  [{'PASS' if blind > 2.5 * coarse else 'WARN'}] {reg}: "
              f"no-info inflates short P95 {blind/coarse:.1f}x over coarse")
        print(f"  [{'PASS' if coarse < 1.5 * oracle else 'WARN'}] {reg}: "
              f"coarse ~ oracle ({coarse:.0f} vs {oracle:.0f} ms)")
    return path


if __name__ == "__main__":
    run()
