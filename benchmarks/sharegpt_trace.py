"""Paper Table 6 (§4.1 real-trace validation): replay the published
ShareGPT-English bucket distribution (12% short / 42% medium / 46% long /
<1% xlong — the raw corpus is not available offline, DESIGN.md §3)
under high congestion.

Validates: the policy ORDERING holds off the synthetic mixes —
final_adrr_olc beats naive on short tails and satisfaction.
"""
from repro.core.policy import strategy

from benchmarks.common import cell, fmt, row_from_summary, write_csv

STRATS = ["direct_naive", "quota_tiered", "final_adrr_olc"]


def run(verbose=True):
    rows = []
    res = {}
    for name in STRATS:
        s = cell(strategy(name), "sharegpt", "high")
        res[name] = s
        rows.append(row_from_summary({"strategy": name}, s))
        if verbose:
            print(f"  {name:16s} {fmt(s)} mk={s['makespan_ms'][0]/1000:.1f}s")
    path = write_csv("sharegpt_trace_summary", rows)
    ok1 = res["final_adrr_olc"]["short_p95_ms"][0] * 2 < res["direct_naive"]["short_p95_ms"][0]
    ok2 = res["final_adrr_olc"]["satisfaction"][0] >= res["direct_naive"]["satisfaction"][0]
    print(f"  [{'PASS' if ok1 else 'WARN'}] final short P95 beats naive >2x")
    print(f"  [{'PASS' if ok2 else 'WARN'}] final satisfaction >= naive")
    return path


if __name__ == "__main__":
    run()
