"""Paper §4.9: overload-controller threshold sensitivity — defer/reject
cutoffs and backoff perturbed +-20% from baseline, coarse priors fixed.

Validates: local stability (no unstable collapse; modest metric drift).
"""
from repro.core.policy import base_policy

from benchmarks.common import cell, row_from_summary, write_csv


def _perturbed(scale: float):
    base = base_policy()
    return base._replace(
        defer_thr=base.defer_thr * scale,
        reject_thr=base.reject_thr * scale,
        defer_backoff_ms=base.defer_backoff_ms * scale,
    )


def run(verbose=True):
    rows = []
    results = {}
    for scale in [0.8, 1.0, 1.2]:
        for mix, cong in [("balanced", "high"), ("heavy", "high")]:
            s = cell(_perturbed(scale), mix, cong)
            results[(scale, mix)] = s
            rows.append(row_from_summary(
                {"regime": f"{mix}/{cong}", "threshold_scale": scale}, s))
            if verbose:
                print(f"  scale={scale:.1f} {mix}/high "
                      f"sP95={s['short_p95_ms'][0]:5.0f} CR={s['completion_rate'][0]:.3f} "
                      f"sat={s['satisfaction'][0]:.3f} gp={s['goodput_rps'][0]:.2f}")
    path = write_csv("threshold_sensitivity", rows)
    for mix in ["balanced", "heavy"]:
        cr = [results[(sc, mix)]["completion_rate"][0] for sc in [0.8, 1.0, 1.2]]
        p = [results[(sc, mix)]["short_p95_ms"][0] for sc in [0.8, 1.0, 1.2]]
        stable = (max(cr) - min(cr) < 0.08) and (max(p) / min(p) < 1.35)
        print(f"  [{'PASS' if stable else 'WARN'}] {mix}/high stable under ±20% "
              f"(dCR={max(cr)-min(cr):.3f}, sP95 ratio={max(p)/min(p):.2f})")
    return path


if __name__ == "__main__":
    run()
