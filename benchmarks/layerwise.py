"""Paper Fig 7: layerwise progression naive -> quota -> adaptive DRR ->
Final (OLC) on the two high-congestion regimes."""
from repro.core.policy import strategy

from benchmarks.common import cell, fmt, row_from_summary, write_csv

ORDER = ["direct_naive", "quota_tiered", "adaptive_drr", "final_adrr_olc"]


def run(verbose=True):
    rows = []
    for mix in ["balanced", "heavy"]:
        for name in ORDER:
            s = cell(strategy(name), mix, "high")
            rows.append(row_from_summary(
                {"regime": f"{mix}/high", "layer_stage": name}, s))
            if verbose:
                print(f"  {mix}/high {name:16s} {fmt(s)}")
    return write_csv("layerwise_progression", rows)


if __name__ == "__main__":
    run()
