import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Roofline analysis (assignment deliverable g).

Reads the dry-run artifacts (paper_results/dryrun/*.json), adds a
layer-probe correction for XLA's scan-once cost accounting (verified
empirically: cost_analysis counts a lax.scan body ONCE regardless of trip
count), computes the three roofline terms per (arch x shape) on the
single-pod mesh, and emits paper_results/roofline.{csv,md}.

Terms (TPU v5e constants from the assignment):
  compute_s    = MODEL-analytic FLOPs / (chips * 197e12)
  memory_s     = corrected per-device HLO bytes / 819e9
  collective_s = corrected per-device collective bytes / 50e9 (1 ICI link)

Corrections:
  corrected(X) = X(L=1) + (n_layers - 1) * (X(L=2) - X(L=1))
applied to HLO flops, bytes and collective bytes (layer-probe
extrapolation; inner attention scans are additionally handled on the
analytic side).  MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D
(prefill, decode) + exact attention/SSD terms.
"""
import argparse
import dataclasses
import json

from repro.config import SHAPES
from repro.configs import ARCHS
from repro.launch.mesh import HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16

DRY_DIR = os.path.join(os.path.dirname(__file__), "..", "paper_results", "dryrun")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "paper_results")


# ---------------------------------------------------------------------------
# Analytic FLOPs
# ---------------------------------------------------------------------------

def _attn_pairs(S: int, window: int) -> float:
    if window <= 0 or window >= S:
        return S * S / 2
    return window * S - window * window / 2


def analytic_flops(cfg, shape) -> float:
    """Global model FLOPs for one step (fwd [+bwd for train])."""
    B, S = shape.global_batch, shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    n_act = cfg.active_param_count()
    if shape.kind == "decode":
        tokens = B
        base = 2.0 * n_act * tokens
        extra = 0.0
        if cfg.n_heads:
            skv = min(S, cfg.sliding_window) if cfg.sliding_window else S
            if cfg.arch_type == "hybrid":
                glob = len(cfg.global_layers)
                win_l = cfg.n_layers - glob
                eff = glob * S + win_l * min(S, cfg.sliding_window or S)
            else:
                eff = cfg.n_layers * skv
            extra += 4.0 * B * eff * cfg.n_heads * cfg.head_dim
        if cfg.ssm:
            extra += (6.0 * B * cfg.n_ssm_heads * cfg.ssm.head_dim
                      * cfg.ssm.d_state * cfg.n_layers)
        return base + extra
    tokens = B * S
    base = 2.0 * mult * n_act * tokens
    extra = 0.0
    if cfg.n_heads:
        if cfg.arch_type == "hybrid":
            glob = len(cfg.global_layers)
            pairs = (glob * _attn_pairs(S, 0)
                     + (cfg.n_layers - glob) * _attn_pairs(S, cfg.sliding_window))
        else:
            pairs = cfg.n_layers * _attn_pairs(S, cfg.sliding_window)
        extra += mult * 4.0 * B * pairs * cfg.n_heads * cfg.head_dim
    if cfg.ssm:
        s = cfg.ssm
        Q = s.chunk
        per_tok = (2 * Q * s.d_state + cfg.n_ssm_heads *
                   (2 * Q * s.head_dim + 2 * s.head_dim * s.d_state))
        extra += mult * B * S * per_tok * cfg.n_layers
    return base + extra


# ---------------------------------------------------------------------------
# Layer probes
# ---------------------------------------------------------------------------

def probe(arch: str, shape_name: str, n_layers: int) -> dict:
    """Lower+compile with a reduced layer count (same shapes otherwise)."""
    import jax
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_spec, config_for

    cfg = config_for(arch, shape_name)
    cfg = dataclasses.replace(
        cfg, n_layers=n_layers, scan_unroll=True,
        global_layers=tuple(g for g in cfg.global_layers if g < n_layers))
    mesh = make_production_mesh(multi_pod=False)
    spec = build_spec(arch, shape_name, mesh, cfg_override=cfg)
    with mesh:
        compiled = jax.jit(
            spec.fn, in_shardings=spec.in_shardings,
            donate_argnums=spec.donate).lower(*spec.args).compile()
    cost = compiled.cost_analysis() or {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(compiled.as_text())["total"],
    }


def probe_path(arch, shape_name):
    return os.path.join(DRY_DIR, f"probe__{arch}__{shape_name}.json")


def run_probes(archs=None, shapes=None):
    for arch in archs or ARCHS:
        for shape_name in shapes or list(SHAPES):
            path = probe_path(arch, shape_name)
            if os.path.exists(path):
                continue
            try:
                rec = {"L1": probe(arch, shape_name, 1),
                       "L2": probe(arch, shape_name, 2), "ok": True}
            except Exception as e:  # noqa: BLE001
                rec = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            with open(path, "w") as f:
                json.dump(rec, f)
            print(f"[probe] {arch} {shape_name} "
                  f"{'ok' if rec['ok'] else rec['error']}", flush=True)


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

def corrected(full_rec, probe_rec, key_full, key_probe, L):
    if not probe_rec.get("ok"):
        return full_rec.get(key_full, 0.0)
    x1 = probe_rec["L1"][key_probe]
    x2 = probe_rec["L2"][key_probe]
    if x2 < x1:  # fusion noise can make the 2-layer probe cheaper
        # (seen on the prefix-stub archs); fall back to the uncorrected
        # full-model value rather than extrapolating a negative slope
        return full_rec.get(key_full, probe_rec["L2"][key_probe])
    return x1 + (L - 1) * (x2 - x1)


def build_report():
    from repro.launch.specs import config_for

    rows = []
    for arch in ARCHS:
        for shape_name in SHAPES:
            fn = os.path.join(DRY_DIR, f"{arch}__{shape_name}__pod.json")
            if not os.path.exists(fn):
                continue
            with open(fn) as f:
                rec = json.load(f)
            if not rec.get("ok"):
                rows.append({"arch": arch, "shape": shape_name,
                             "ok": False, "error": rec.get("error", "")})
                continue
            cfg = config_for(arch, shape_name)
            shape = SHAPES[shape_name]
            chips = rec["n_devices"]
            pp = {}
            ppath = probe_path(arch, shape_name)
            if os.path.exists(ppath):
                with open(ppath) as f:
                    pp = json.load(f)
            L = cfg.n_layers
            hlo_flops_c = corrected(rec, pp, "hlo_flops", "flops", L)
            hlo_bytes_c = corrected(rec, pp, "hlo_bytes", "bytes", L)
            coll_c = corrected(
                {"collectives": rec["collectives"],
                 "total": rec["collectives"]["total"]},
                pp, "total", "coll", L)
            model_flops = analytic_flops(cfg, shape)

            compute_s = model_flops / (chips * PEAK_FLOPS_BF16)
            memory_s = hlo_bytes_c / HBM_BW
            collective_s = coll_c / ICI_BW
            terms = {"compute": compute_s, "memory": memory_s,
                     "collective": collective_s}
            dominant = max(terms, key=terms.get)
            bound_s = terms[dominant]
            useful_ratio = model_flops / max(hlo_flops_c * chips, 1.0)
            hbm_frac = rec.get("bytes_per_device", 0) / HBM_PER_CHIP
            rows.append({
                "arch": arch, "shape": shape_name, "ok": True,
                "chips": chips,
                "model_flops": model_flops,
                "hlo_flops_per_dev_raw": rec["hlo_flops"],
                "hlo_flops_per_dev_corrected": hlo_flops_c,
                "hlo_bytes_per_dev_corrected": hlo_bytes_c,
                "collective_bytes_per_dev": coll_c,
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "dominant": dominant,
                "bound_s": bound_s,
                "roofline_frac_compute": compute_s / max(bound_s, 1e-30),
                "useful_flops_ratio": useful_ratio,
                "mem_per_device_gb": rec.get("bytes_per_device", 0) / 1e9,
                "fits_hbm": hbm_frac <= 1.0,
                "variant": rec.get("variant", ""),
            })
    return rows


SUGGEST = {
    "compute": "compute-bound: already near the right roofline; gains need "
               "fewer redundant FLOPs (remat policy) or lower precision.",
    "memory": "memory-bound: raise arithmetic intensity — fuse, batch more "
              "tokens per weight load, or quantize weights/KV to int8.",
    "collective": "collective-bound: reshard to cut cross-chip traffic "
                  "(more FSDP, less TP; overlap collectives with compute).",
}


def emit(rows):
    os.makedirs(OUT_DIR, exist_ok=True)
    import csv
    with open(os.path.join(OUT_DIR, "roofline.csv"), "w", newline="") as f:
        cols = list(rows[0].keys())
        for r in rows:
            cols += [c for c in r if c not in cols]
        w = csv.DictWriter(f, fieldnames=cols)
        w.writeheader()
        w.writerows(rows)
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | useful_ratio | mem/dev GB | fits |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                         f"{r.get('error','')[:60]} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['mem_per_device_gb']:.2f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} |")
    with open(os.path.join(OUT_DIR, "roofline.md"), "w") as f:
        f.write("\n".join(lines) + "\n")
    for r in rows:
        if r.get("ok"):
            print(f"{r['arch']:24s} {r['shape']:12s} dom={r['dominant']:10s} "
                  f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                  f"x={r['collective_s']:.2e} useful={r['useful_flops_ratio']:.2f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--probe", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    args = ap.parse_args()
    if args.probe:
        run_probes([args.arch] if args.arch else None,
                   [args.shape] if args.shape else None)
    if args.report or not args.probe:
        emit(build_report())


if __name__ == "__main__":
    main()
