"""Fleet sweep: the (P,) provider axis under failure, skew, and
brownout (DESIGN.md §10).

Runs the fleet scenarios through the full three-layer stack plus the
layer-0 routing pass, scaling the failover scenario across fleet widths
P ∈ {1, 4, 16}.  P=1 is the degenerate fleet (the fail window takes the
*whole* provider down — the pure retry/requeue regime); P=4 and P=16
measure how endpoint-aware routing absorbs the same outage when there
is somewhere else to send the work.

Each failover cell reports a **recovery** metric: the completion rate
of requests arriving after the fail window divided by the completion
rate of requests arriving before it (phase 2 vs phase 0 of the
scenario's 0.35/0.30/0.35 split, which brackets the 0.35-0.65 fail
window).  The >= 0.99 recovery bar gates the P > 1 cells: when the
fleet has somewhere else to send the work, post-outage arrivals must
not pay for the outage.  The P=1 cell is the ungated control — the
whole provider was down, post-outage arrivals land on the requeued
backlog, and the cost ladder legitimately sheds some of them; its
reported recovery (~0.95) is the baseline the routed cells are
measured against.  The full run writes
rows under the `fleet_sweep` key of `BENCH_scenarios.json` (merging,
not clobbering, the scenario-sweep cells) and exits nonzero if any
recovery bar or finiteness gate fails.

`--smoke` runs a CI-sized slice (P ∈ {1, 4}, small N, no artifact
write) with the same gates.
"""
from __future__ import annotations

import json
import os
import sys
import time
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import numpy as np  # noqa: E402

from benchmarks import common as _common  # noqa: E402,F401 (enables the
                                          # persistent compilation cache)
from repro.core.policy import final_adrr_olc  # noqa: E402
from repro.sim import (  # noqa: E402
    SimConfig,
    run_scenario_cell,
    summarize,
    window_for,
)
from repro.sim import scenarios as scn  # noqa: E402

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_scenarios.json")

RECOVERY_BAR = 0.99

REQUIRED_FINITE = (
    "completion_rate", "satisfaction", "goodput_rps", "global_p95_ms",
)


def _failover_at(p: int) -> scn.Scenario:
    """The registry failover scenario widened/narrowed to a P-endpoint
    fleet; the fail window stays on endpoint 0."""
    base = scn.get_scenario("fleet_failover")
    return base._replace(name=f"fleet_failover_p{p}",
                         fleet=base.fleet._replace(p=p))


def _recovery(pm) -> float:
    """Post-failover completion rate over pre-failover completion rate,
    seed-averaged.  Phases index the scenario's arrival split: phase 0
    arrives entirely before the fail window, phase 2 entirely after."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        arrived = np.nanmean(np.asarray(pm.n_arrived, np.float64), axis=0)
        completed = np.nanmean(np.asarray(pm.n_completed, np.float64), axis=0)
    pre = completed[0] / max(arrived[0], 1.0)
    post = completed[-1] / max(arrived[-1], 1.0)
    if pre <= 0.0:
        return float("nan")
    return float(post / pre)


def run_sweep(*, n_requests: int, n_ticks: int, seeds: int,
              widths: tuple[int, ...], verbose: bool = True,
              ) -> tuple[list[dict], list[str]]:
    """Returns (cell dicts, gate violations)."""
    sim_cfg = SimConfig(n_ticks=n_ticks, window=window_for(n_requests))
    policy = final_adrr_olc()
    cells, violations = [], []
    grid = [(_failover_at(p), p > 1) for p in widths]
    grid += [(scn.get_scenario(n), False)
             for n in ("fleet_skew", "fleet_brownout")]
    for scenario, gated in grid:
        t0 = time.perf_counter()
        m, pm = run_scenario_cell(
            policy, scenario, seeds=seeds, n_requests=n_requests,
            sim_cfg=sim_cfg)
        secs = time.perf_counter() - t0
        s = summarize(m)
        for key in REQUIRED_FINITE:
            if not np.isfinite(s[key][0]):
                violations.append(f"{scenario.name}: {key} = {s[key][0]}")
        agg = {k: round(s[k][0], 3) if np.isfinite(s[k][0]) else None
               for k in REQUIRED_FINITE + ("n_rejects", "n_abandoned")}
        cell = {
            "scenario": scenario.name,
            "p": scenario.fleet.p,
            "cell_seconds": round(secs, 2),
            "aggregate": agg,
        }
        if scenario.name.startswith("fleet_failover"):
            rec = _recovery(pm)
            cell["recovery"] = round(rec, 4) if np.isfinite(rec) else None
            if gated and not (rec >= RECOVERY_BAR):
                violations.append(
                    f"{scenario.name}: recovery {rec:.4f} < {RECOVERY_BAR}")
        cells.append(cell)
        if verbose:
            rec_s = (f" recovery={cell['recovery']:.3f}"
                     if cell.get("recovery") is not None else "")
            cr = agg["completion_rate"]
            print(f"  {scenario.name:20s} P={scenario.fleet.p:<3d} "
                  f"{secs:5.1f}s cr={cr if cr is not None else 'nan'}"
                  f"{rec_s}")
    return cells, violations


def main(argv: list[str]) -> int:
    smoke = "--smoke" in argv
    if smoke:
        cells, violations = run_sweep(
            n_requests=64, n_ticks=4000, seeds=2, widths=(1, 4))
    else:
        cells, violations = run_sweep(
            n_requests=160, n_ticks=14000, seeds=3, widths=(1, 4, 16))
        prev = {}
        try:
            with open(BENCH_JSON) as f:
                prev = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
        prev["fleet_sweep"] = {
            "sim": {"n_requests": 160, "n_ticks": 14000, "seeds": 3,
                    "engine": "windowed"},
            "recovery_bar": RECOVERY_BAR,
            "cells": cells,
        }
        with open(BENCH_JSON, "w") as f:
            json.dump(prev, f, indent=2)
        print(f"wrote {os.path.relpath(BENCH_JSON)} fleet_sweep "
              f"({len(cells)} cells)")
    if violations:
        print("FAIL:")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"fleet sweep OK: {len(cells)} cells, "
          f"P>1 recovery >= {RECOVERY_BAR}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
