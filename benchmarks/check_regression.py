"""Bench-regression gate: fresh scheduler throughput vs the committed
baseline.

Re-runs the batched-dispatch microbenchmark (`schedule_batch` at B=16,
the production drain width) at each committed queue depth, plus the
active-window dispatch step (DESIGN.md §6) at the committed large-N
cells, and fails if a fresh rate regresses more than the tolerance band
below the committed `BENCH_scheduler.json` baseline.  Checks:

  * **absolute**: fresh B=16 slots/sec >= (1 - tolerance) x baseline.
    Cross-machine noise is real — the tolerance default (30%) is wide,
    and `BENCH_TOLERANCE` can widen it for known-slow runners without
    editing the Makefile.
  * **structural** (machine-independent): fresh B=16 must still beat
    fresh B=1 by the repo's >=2x batched-dispatch bar.  A refactor that
    quietly serializes the batch fails here even on a faster machine.
  * **windowed absolute**: fresh windowed B=1 dispatch at each
    committed (N=1e5, W) cell vs its baseline row, same tolerance
    scheme — the tentpole's O(live queue) win stays locked in.  The
    N=1e6 scale rows are informational only (`make bench-scale`): at
    that population the per-call cost is dominated by cache-sensitive
    gathers and swings ~2x run to run, too noisy for a CI gate.
  * **windowed structural**: fresh windowed B=1 at the deepest gated
    (N, W) must beat the fresh *dense* B=1 rate at the same N by >=4x
    (the committed artifact shows 19-31x; the bar leaves room for
    runner noise).  A change that quietly reintroduces O(N) work into
    the windowed tick fails here on any machine.
  * **client session** (DESIGN.md §7): fresh end-to-end `ClientSession`
    throughput over MockProvider at each committed (N, W, B) cell vs
    its baseline row, same tolerance — plus the machine-independent
    N-independence bar: the N=1e5 per-request rate must stay within 2x
    of N=1e3 (per-poll cost is O(W); a refactor that sneaks O(total N)
    work into the poll loop fails here on any machine).
  * **fused-tick speedup** (DESIGN.md §8): fresh per-poll latency vs
    the frozen pre-fusion rows (`client_session_pr5` — the four-
    dispatch, per-poll-status-pull design) must hold the >=10x bar the
    fused device tick was accepted on.  The pr5 rows are a historical
    snapshot and are never regenerated.
  * **fault recovery** (DESIGN.md §11): the committed
    `BENCH_scenarios.json` fault_sweep rows must show resilience-on
    completion >= the recovery bar on every chaos scenario, the
    trusting control demonstrably degraded on the loss scenarios, and
    zero double-retires everywhere.  This is an artifact-consistency
    gate (the sweep itself is minutes of wall clock; `make
    bench-faults` regenerates the rows and applies the same bars
    live).

Wired into `make ci` as `make check-bench`.  The baseline is read from
git (`HEAD:BENCH_scheduler.json`) so a local `make bench-sched` that
rewrote the working-tree artifact can't silently compare fresh against
fresh; outside a git checkout it falls back to the file on disk.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import numpy as np  # noqa: E402

from benchmarks.client_bench import client_session_bench  # noqa: E402
from benchmarks.multi_class import (  # noqa: E402
    batch_dispatch_bench,
    windowed_dispatch_bench,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "BENCH_scheduler.json")
SCENARIOS_JSON = os.path.join(REPO, "BENCH_scenarios.json")
DEFAULT_TOLERANCE = 0.30  # fail on >30% regression at B=16
MIN_B16_VS_B1 = 2.0       # the repo's batched-dispatch acceptance bar
MIN_WIN_VS_DENSE = 4.0    # windowed-vs-dense dispatch bar at large N
GATE_N = 100_000          # windowed cells at this depth are gated
# client-session N-independence: the per-request rate at N=1e5 must be
# within 2x of the N=1e3 rate (per-poll cost is O(W), not O(N) — the
# acceptance bar of the streaming client API, DESIGN.md §7)
MIN_CLIENT_N_RATIO = 0.5
# fused-tick acceptance bar: per-poll latency vs the frozen pre-fusion
# client_session_pr5 snapshot (DESIGN.md §8)
MIN_FUSED_SPEEDUP = 10.0


def load_baseline() -> dict:
    try:
        out = subprocess.run(
            ["git", "show", "HEAD:BENCH_scheduler.json"],
            cwd=REPO, capture_output=True, text=True, check=True,
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, FileNotFoundError,
            json.JSONDecodeError):
        with open(BASELINE) as f:
            return json.load(f)


def load_fault_rows() -> dict | None:
    """The committed fault_sweep section of BENCH_scenarios.json; falls
    back to the working-tree file when the committed copy predates the
    fault sweep (first-commit bootstrap), None when neither has it."""
    for loader in (
        lambda: json.loads(subprocess.run(
            ["git", "show", "HEAD:BENCH_scenarios.json"],
            cwd=REPO, capture_output=True, text=True, check=True).stdout),
        lambda: json.load(open(SCENARIOS_JSON)),
    ):
        try:
            section = loader().get("fault_sweep")
        except (subprocess.CalledProcessError, FileNotFoundError, OSError,
                json.JSONDecodeError):
            continue
        if section:
            return section
    return None


def check_fault_rows(failures: list[str]) -> None:
    """Artifact-consistency gate over the fault_sweep rows (see module
    docstring): the committed chaos numbers must still clear the bars
    they were accepted on."""
    from benchmarks.fault_sweep import (
        FAULT_SCENARIOS,
        LOSS_SCENARIOS,
        RECOVERY_BAR,
        SEPARATION_BAR,
    )
    section = load_fault_rows()
    if section is None:
        failures.append(
            "BENCH_scenarios.json has no fault_sweep rows — run "
            "`make bench-faults` to generate the recovery baseline")
        return
    bar = float(section.get("recovery_bar", RECOVERY_BAR))
    sep_bar = float(section.get("separation_bar", SEPARATION_BAR))
    comp: dict[tuple[str, str], float] = {}
    for cell in section.get("cells", []):
        name, mode = cell["scenario"], cell["resilience"]
        comp[(name, mode)] = cell["completion"]
        if cell.get("double_retires", 0) != 0:
            failures.append(
                f"fault_sweep {name}/{mode}: {cell['double_retires']} "
                f"double-retire(s) recorded")
    for name in FAULT_SCENARIOS:
        on, off = comp.get((name, "on")), comp.get((name, "off"))
        if on is None or off is None:
            failures.append(
                f"fault_sweep: missing on/off rows for {name!r}")
            continue
        ok_rec = np.isfinite(on) and on >= bar
        sep = on - off
        gated_sep = name in LOSS_SCENARIOS
        ok_sep = (not gated_sep) or (np.isfinite(sep) and sep >= sep_bar)
        print(f"  fault     {name:12s}: on={on:.4f} off={off:.4f} "
              f"[{'ok' if ok_rec else 'FAIL'}]"
              + (f"  separation {sep:+.4f} [{'ok' if ok_sep else 'FAIL'}]"
                 if gated_sep else ""))
        if not ok_rec:
            failures.append(
                f"fault_sweep {name}: resilience-on completion {on:.4f} "
                f"< {bar}")
        if not ok_sep:
            failures.append(
                f"fault_sweep {name}: on-off separation {sep:.4f} < "
                f"{sep_bar} — the control is not degraded")


def main(argv: list[str]) -> int:
    tolerance = float(
        os.environ.get("BENCH_TOLERANCE", DEFAULT_TOLERANCE))
    for a in argv:
        if a.startswith("--tolerance="):
            tolerance = float(a.split("=", 1)[1])

    baseline = load_baseline()
    rows = baseline.get("batch_dispatch", [])
    base_by_n = {
        r["n_requests"]: r["slots_per_sec"]
        for r in rows if r.get("max_grants") == 16
    }
    if not base_by_n:
        print("FAIL: committed BENCH_scheduler.json has no B=16 "
              "batch_dispatch rows to gate against")
        return 1

    failures = []
    print(f"bench-regression gate: tolerance {tolerance:.0%} at B=16")
    for n_req, base_rate in sorted(base_by_n.items()):
        iters = 100 if n_req <= 10_000 else 20
        fresh16 = batch_dispatch_bench(16, n_req, iters=iters)
        fresh1 = batch_dispatch_bench(1, n_req, iters=iters)
        rate = fresh16["slots_per_sec"]
        floor = (1.0 - tolerance) * base_rate
        ratio = rate / fresh1["slots_per_sec"]
        ok_abs = np.isfinite(rate) and rate >= floor
        ok_ratio = np.isfinite(ratio) and ratio >= MIN_B16_VS_B1
        print(f"  N={n_req:6d}: fresh {rate:10.0f} slots/s vs baseline "
              f"{base_rate:10.0f} (floor {floor:10.0f}) "
              f"[{'ok' if ok_abs else 'REGRESSION'}]  "
              f"B16/B1 {ratio:4.1f}x [{'ok' if ok_ratio else 'FAIL'}]")
        if not ok_abs:
            failures.append(
                f"N={n_req}: B=16 rate {rate:.0f} < floor {floor:.0f} "
                f"({rate / base_rate - 1.0:+.0%} vs baseline)")
        if not ok_ratio:
            failures.append(
                f"N={n_req}: B=16 only {ratio:.2f}x B=1 "
                f"(bar: >={MIN_B16_VS_B1}x)")

    # --- active-window gate: the large-N windowed dispatch rate -------
    win_rows = [
        r for r in baseline.get("windowed_dispatch", [])
        if r.get("max_grants") == 1 and r.get("n_requests") == GATE_N
    ]
    if not win_rows:
        print("FAIL: committed BENCH_scheduler.json has no large-N windowed "
              "B=1 rows to gate against")
        return 1
    deepest = max(win_rows, key=lambda r: (r["n_requests"], r["window"]))
    for r in sorted(win_rows, key=lambda r: (r["n_requests"], r["window"])):
        n_req, w, base_rate = r["n_requests"], r["window"], r["slots_per_sec"]
        fresh = windowed_dispatch_bench(1, n_req, w, iters=100)
        rate = fresh["slots_per_sec"]
        floor = (1.0 - tolerance) * base_rate
        ok_abs = np.isfinite(rate) and rate >= floor
        line = (f"  windowed N={n_req:7d} W={w:5d}: fresh {rate:10.0f} "
                f"slots/s vs baseline {base_rate:10.0f} "
                f"(floor {floor:10.0f}) [{'ok' if ok_abs else 'REGRESSION'}]")
        if not ok_abs:
            failures.append(
                f"windowed N={n_req} W={w}: B=1 rate {rate:.0f} < floor "
                f"{floor:.0f} ({rate / base_rate - 1.0:+.0%} vs baseline)")
        if r is deepest:
            dense1 = batch_dispatch_bench(1, n_req, iters=20)
            ratio = rate / dense1["slots_per_sec"]
            ok_ratio = np.isfinite(ratio) and ratio >= MIN_WIN_VS_DENSE
            line += (f"  win/dense {ratio:5.1f}x "
                     f"[{'ok' if ok_ratio else 'FAIL'}]")
            if not ok_ratio:
                failures.append(
                    f"windowed N={n_req} W={w}: only {ratio:.2f}x the dense "
                    f"B=1 rate (bar: >={MIN_WIN_VS_DENSE}x)")
        print(line)

    # --- client-session gate: streaming API throughput + N-independence
    crows = [r for r in baseline.get("client_session", [])]
    if not crows:
        print("FAIL: committed BENCH_scheduler.json has no client_session "
              "rows to gate against")
        return 1
    pr5_by_n = {
        r["n_requests"]: r["poll_us"]
        for r in baseline.get("client_session_pr5", [])
    }
    if not pr5_by_n:
        print("FAIL: committed BENCH_scheduler.json has no "
              "client_session_pr5 rows — the fused-tick speedup gate "
              "needs the frozen pre-fusion snapshot")
        return 1
    fresh_by_n = {}
    for r in sorted(crows, key=lambda r: r["n_requests"]):
        n_req, w, b = r["n_requests"], r["window"], r["max_grants"]
        fresh = client_session_bench(n_req, window=w, grants=b)
        rate, base_rate = fresh["requests_per_sec"], r["requests_per_sec"]
        fresh_by_n[n_req] = rate
        if n_req in pr5_by_n:
            speedup = pr5_by_n[n_req] / fresh["poll_us"]
            ok_fused = np.isfinite(speedup) and speedup >= MIN_FUSED_SPEEDUP
            print(f"  fused     N={n_req:7d}: {fresh['poll_us']:8.1f}us/poll "
                  f"vs pre-fusion {pr5_by_n[n_req]:8.1f}us "
                  f"({speedup:.1f}x) [{'ok' if ok_fused else 'FAIL'}]")
            if not ok_fused:
                failures.append(
                    f"client_session N={n_req}: fused tick only {speedup:.1f}x"
                    f" the pre-fusion poll latency (bar: "
                    f">={MIN_FUSED_SPEEDUP:.0f}x vs client_session_pr5)")
        floor = (1.0 - tolerance) * base_rate
        ok_abs = np.isfinite(rate) and rate >= floor
        print(f"  client    N={n_req:7d} W={w:5d} B={b:2d}: fresh "
              f"{rate:10.0f} req/s vs baseline {base_rate:10.0f} "
              f"(floor {floor:10.0f}) [{'ok' if ok_abs else 'REGRESSION'}]")
        if not ok_abs:
            failures.append(
                f"client_session N={n_req}: rate {rate:.0f} < floor "
                f"{floor:.0f} ({rate / base_rate - 1.0:+.0%} vs baseline)")
    if len(fresh_by_n) >= 2:
        ns = sorted(fresh_by_n)
        ratio = fresh_by_n[ns[-1]] / fresh_by_n[ns[0]]
        ok_ratio = np.isfinite(ratio) and ratio >= MIN_CLIENT_N_RATIO
        print(f"  client    N-independence: N={ns[-1]} per-request rate "
              f"{ratio:.2f}x the N={ns[0]} rate "
              f"[{'ok' if ok_ratio else 'FAIL'}]")
        if not ok_ratio:
            failures.append(
                f"client_session: N={ns[-1]} rate only {ratio:.2f}x the "
                f"N={ns[0]} rate (bar: >={MIN_CLIENT_N_RATIO}x — per-poll "
                f"cost must stay O(W), not O(N))")

    # --- fault-recovery gate: committed chaos rows still clear the bars
    check_fault_rows(failures)

    if failures:
        print("FAIL: scheduler throughput regression:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("check-bench OK: no regression beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
