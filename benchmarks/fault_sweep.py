"""Fault sweep: the chaos scenarios against the resilient client.

Replays the registry fault scenarios (sim/faults.py — silent drops,
stuck requests, duplicate storms with lying Retry-After) through the
live client stack: a virtual-clock `ClientSession` over a faulty
`MockProvider`, once with the resilience watchdog armed and once with
the trusting session as the control.  Every cell runs a FIXED poll
horizon, not `drain` — against a provider that drops completions the
trusting control would hang forever, and "how much work survived by the
horizon" is exactly the metric.

Gates (nonzero exit on violation):

  * **recovery** — resilience-on completion >= 0.99 on every fault
    scenario: the watchdog's deadline/resubmit/give-up machinery must
    recover the faulted work, not merely detect it;
  * **separation** — on the loss scenarios (silent_drop, stuck_tail)
    the trusting control must be demonstrably worse (on - off >= 0.05):
    if the control passes too, the scenario isn't exercising anything;
  * **no double-retire** — the session's terminal counters must equal
    the per-request terminal statuses exactly, in every cell including
    dup_storm: at-least-once delivery never retires a slot twice;
  * finiteness of every reported rate.

The full run merges rows under the `fault_sweep` key of
`BENCH_scenarios.json` (not clobbering the scenario/fleet sweeps);
`--smoke` runs a CI-sized slice with the same gates and no artifact
write.

Sizing note: the cells run at N where the policy's own overload ladder
stays quiet on the honest workload AND under recovery.  At larger N
(>= ~96 at medium congestion) a second-order interaction appears:
fault casualties pollute the severity signal — a dropped completion
keeps its slot INFLIGHT (phantom load) until the watchdog recovers it,
and a recovered completion lands with e2e inflated by the client-side
deadline wait (tail-EMA pollution) — and the cost ladder starts
shedding *innocent* heavy requests (~10% at N=128) even though every
fault casualty is recovered.  That collateral is the scheduler reacting
to signals the faults distorted, not a recovery failure; separating
fault latency out of the severity estimator is an open item
(ROADMAP.md).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks import common as _common  # noqa: E402,F401 (enables the
                                          # persistent compilation cache)
from repro.client import (  # noqa: E402
    ClientSession,
    MockProvider,
    Request,
    ResilienceConfig,
    SessionConfig,
)
from repro.core.policy import final_adrr_olc  # noqa: E402
from repro.sim import get_scenario  # noqa: E402
from repro.sim.scenarios import build  # noqa: E402
from repro.sim.workload import generate  # noqa: E402

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_scenarios.json")

FAULT_SCENARIOS = ("silent_drop", "stuck_tail", "dup_storm")
# scenarios where the fault destroys work outright — the trusting
# control must visibly lose it (dup_storm's faults are survivable
# without the watchdog; its gate is dup-safety, not separation)
LOSS_SCENARIOS = ("silent_drop", "stuck_tail")

RECOVERY_BAR = 0.99
SEPARATION_BAR = 0.05
DT_MS = 25.0
# tighter than the library defaults: a deeper budget (the recovery bar
# tolerates no compounding bad luck — p(4 dropped attempts) ~ 5e-4 at
# 15% drop), and an eager 3x deadline — a stuck request sits in the
# provider's outstanding count inflating everyone's service time, and
# at the default 6x a heavy-bucket casualty poisons load long enough
# for the cost ladder to start shedding innocents
RESILIENCE = ResilienceConfig(timeout_mult=3.0, max_resubmits=3)


def _batch_to_requests(batch, jitter) -> list[Request]:
    """Generated workload -> submit-ordered client requests (the same
    conversion the client tests drive sessions with)."""
    arr = np.asarray(batch.arrival_ms)
    tok = np.asarray(batch.true_tokens)
    p50 = np.asarray(batch.p50)
    p90 = np.asarray(batch.p90)
    bkt = np.asarray(batch.bucket)
    cls = np.asarray(batch.cls)
    jit = np.asarray(jitter)
    return [
        Request(rid=int(i), prompt=None, max_new=float(tok[i]),
                p50=float(p50[i]), bucket=int(bkt[i]), p90=float(p90[i]),
                cls=int(cls[i]), arrival_s=float(arr[i]) / 1e3,
                jitter=float(jit[i]))
        for i in np.argsort(arr, kind="stable")
    ]


def run_cell(name: str, *, resilient: bool, n_requests: int, n_ticks: int,
             seed: int) -> dict:
    """One (scenario, resilience, seed) cell: fixed-horizon poll loop,
    returns completion/terminal rates and the integrity counters."""
    sc = get_scenario(name)
    wl_cfg, sched, _, _ = build(sc, n_requests, n_ticks, DT_MS)
    batch, jitter = generate(jax.random.PRNGKey(seed), wl_cfg, sched)
    provider = MockProvider.from_scenario(sc, n_requests, n_ticks, DT_MS, 2)
    session = ClientSession(
        provider, final_adrr_olc(), SessionConfig(), clock="virtual",
        resilience=RESILIENCE if resilient else None)
    for r in _batch_to_requests(batch, jitter):
        session.submit(r)
    polls = 0
    while session.unfinished and polls < n_ticks:
        session.poll()
        polls += 1
    reqs = session.requests()
    stats = session.stats
    n_terminal_status = sum(
        1 for r in reqs if r.status in ("completed", "abandoned", "rejected"))
    # a double-retired slot bumps the terminal counters twice for one
    # request; per-request status can only be terminal once
    double_retires = (stats.n_completed + stats.n_abandoned
                      + stats.n_rejected) - n_terminal_status
    return {
        "completion": stats.n_completed / n_requests,
        "terminal": n_terminal_status / n_requests,
        "unfinished": session.unfinished,
        "polls": polls,
        "double_retires": double_retires,
        "n_resubmitted": stats.n_resubmitted,
        "n_gave_up": stats.n_gave_up,
        "n_dup_discarded": stats.n_dup_discarded,
        "n_late_discarded": stats.n_late_discarded,
        "provider": {"n_dropped": provider.n_dropped,
                     "n_stuck": provider.n_stuck,
                     "n_duped": provider.n_duped},
    }


def run_sweep(*, n_requests: int, n_ticks: int, seeds: int,
              verbose: bool = True) -> tuple[list[dict], list[str]]:
    """Returns (cell dicts, gate violations)."""
    cells, violations = [], []
    for name in FAULT_SCENARIOS:
        by_mode = {}
        for resilient in (True, False):
            t0 = time.perf_counter()
            runs = [run_cell(name, resilient=resilient,
                             n_requests=n_requests, n_ticks=n_ticks, seed=s)
                    for s in range(seeds)]
            secs = time.perf_counter() - t0
            comp = float(np.mean([r["completion"] for r in runs]))
            dbl = int(sum(r["double_retires"] for r in runs))
            mode = "on" if resilient else "off"
            by_mode[mode] = comp
            cell = {
                "scenario": name,
                "resilience": mode,
                "cell_seconds": round(secs, 2),
                "completion": round(comp, 4),
                "terminal": round(
                    float(np.mean([r["terminal"] for r in runs])), 4),
                "double_retires": dbl,
                "n_resubmitted": int(sum(r["n_resubmitted"] for r in runs)),
                "n_gave_up": int(sum(r["n_gave_up"] for r in runs)),
                "n_dup_discarded": int(
                    sum(r["n_dup_discarded"] for r in runs)),
                "n_late_discarded": int(
                    sum(r["n_late_discarded"] for r in runs)),
                "provider": {
                    k: int(sum(r["provider"][k] for r in runs))
                    for k in ("n_dropped", "n_stuck", "n_duped")},
            }
            cells.append(cell)
            if not np.isfinite(comp):
                violations.append(f"{name}/{mode}: completion = {comp}")
            if dbl != 0:
                violations.append(
                    f"{name}/{mode}: {dbl} double-retire(s) — at-least-once "
                    f"delivery broke slot-retirement uniqueness")
            if resilient and not (comp >= RECOVERY_BAR):
                violations.append(
                    f"{name}/on: completion {comp:.4f} < {RECOVERY_BAR}")
            if verbose:
                print(f"  {name:12s} {mode:3s} {secs:6.1f}s "
                      f"comp={comp:.4f} dbl={dbl} "
                      f"resub={cell['n_resubmitted']} "
                      f"gaveup={cell['n_gave_up']} "
                      f"dup={cell['n_dup_discarded']}")
        if name in LOSS_SCENARIOS:
            sep = by_mode["on"] - by_mode["off"]
            if not (sep >= SEPARATION_BAR):
                violations.append(
                    f"{name}: on-off separation {sep:.4f} < {SEPARATION_BAR} "
                    f"— the trusting control is not degraded, the fault "
                    f"schedule is not exercising anything")
    return cells, violations


def main(argv: list[str]) -> int:
    smoke = "--smoke" in argv
    if smoke:
        cells, violations = run_sweep(n_requests=48, n_ticks=10_000, seeds=1)
    else:
        cells, violations = run_sweep(n_requests=64, n_ticks=20_000, seeds=2)
        prev = {}
        try:
            with open(BENCH_JSON) as f:
                prev = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
        prev["fault_sweep"] = {
            "sim": {"n_requests": 64, "n_ticks": 20_000, "seeds": 2,
                    "dt_ms": DT_MS},
            "recovery_bar": RECOVERY_BAR,
            "separation_bar": SEPARATION_BAR,
            "resilience": RESILIENCE._asdict(),
            "cells": cells,
        }
        with open(BENCH_JSON, "w") as f:
            json.dump(prev, f, indent=2)
        print(f"wrote {os.path.relpath(BENCH_JSON)} fault_sweep "
              f"({len(cells)} cells)")
    if violations:
        print("FAIL:")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"fault sweep OK: {len(cells)} cells, resilience-on completion "
          f">= {RECOVERY_BAR}, zero double-retires")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
