"""Paper Table 2 / Figs 3-4: main policy comparison across four regimes.

Validates (qualitatively, constants are ours — DESIGN.md §3):
  * quota-tiered: best short tails, withheld heavy work (CR < structured)
  * adaptive DRR / Final (OLC): ~full completion, short P95 within tens
    of ms of quota
  * Final (OLC) vs plain aDRR: legible shedding improves global tails /
    goodput under heavy stress.
"""
from repro.core.policy import strategy
from repro.sim.workload import REGIMES

from benchmarks.common import cell, fmt, row_from_summary, write_csv

STRATS = ["direct_naive", "quota_tiered", "adaptive_drr", "final_adrr_olc"]


def run(verbose=True):
    rows = []
    for mix, cong in REGIMES:
        for name in STRATS:
            s = cell(strategy(name), mix, cong)
            rows.append(row_from_summary(
                {"regime": f"{mix}/{cong}", "strategy": name}, s))
            if verbose:
                print(f"  {mix}/{cong:6s} {name:16s} {fmt(s)}")
    path = write_csv("main_policy_summary", rows)
    # paper-claim checks (soft, printed):
    by = {(r["regime"], r["strategy"]): r for r in rows}
    claims = []
    for reg in ["heavy/medium", "heavy/high"]:
        claims.append((f"{reg}: quota completes less than Final",
                       by[(reg, "quota_tiered")]["completion_rate_mean"]
                       < by[(reg, "final_adrr_olc")]["completion_rate_mean"]))
    claims.append(("balanced/high: naive short P95 >> structured",
                   by[("balanced/high", "direct_naive")]["short_p95_ms_mean"]
                   > 3 * by[("balanced/high", "final_adrr_olc")]["short_p95_ms_mean"]))
    for c, ok in claims:
        print(f"  [{'PASS' if ok else 'WARN'}] {c}")
    return path


if __name__ == "__main__":
    run()
