"""Paper Table 3 (§4.1 latency calibration): the paper fits
latency_ms = a + b * output_tokens against a production API (R^2 = 0.97).
We cannot call Volcengine; instead we calibrate the SAME property against
our real JAX serving engine (reduced stablelm on CPU): single-request
generation latency vs output tokens, linear fit + R^2, bucketed stats.

Validates: generation time is linear in output length — the key property
the congestion-aware mock relies on.
"""
import time

import jax
import numpy as np

from repro.config import ServeConfig
from repro.configs import get_smoke
from repro.models import init_model
from repro.serving import generate

from benchmarks.common import write_csv

TOKEN_COUNTS = [4, 8, 16, 24, 32, 48, 64, 96]


def run(verbose=True):
    cfg = get_smoke("stablelm-1.6b")
    model = init_model(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(max_seq=160, temperature=0.0)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)

    # warm the compile caches per shape first (compile time is not latency)
    for n in TOKEN_COUNTS:
        generate(model.params, cfg, sc, prompt, n)

    rows = []
    xs, ys = [], []
    for n in TOKEN_COUNTS:
        lats = []
        for rep in range(3):
            t0 = time.perf_counter()
            out = generate(model.params, cfg, sc, prompt, n)
            out.block_until_ready()
            lats.append((time.perf_counter() - t0) * 1e3)
        lat = float(np.median(lats))
        xs.append(n)
        ys.append(lat)
        rows.append({"output_tokens": n, "latency_ms": round(lat, 2),
                     "std_ms": round(float(np.std(lats)), 2)})
        if verbose:
            print(f"  tokens={n:4d} latency={lat:8.1f} ms")
    xs, ys = np.asarray(xs, float), np.asarray(ys, float)
    b, a = np.polyfit(xs, ys, 1)
    pred = a + b * xs
    ss_res = ((ys - pred) ** 2).sum()
    ss_tot = ((ys - ys.mean()) ** 2).sum()
    r2 = 1 - ss_res / ss_tot
    print(f"  fit: latency_ms = {a:.1f} + {b:.3f} * tokens   R^2 = {r2:.3f}")
    print(f"  [{'PASS' if r2 > 0.9 else 'WARN'}] linear scaling confirmed "
          f"(paper reports R^2 = 0.97 on a production API)")
    rows.append({"output_tokens": -1, "latency_ms": round(a, 2),
                 "std_ms": round(b, 4)})
    return write_csv("latency_calibration", rows), r2


if __name__ == "__main__":
    run()
