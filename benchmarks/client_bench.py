"""Streaming client-session throughput: the live-path half of the
windowed-scaling story.

`ClientSession` keeps O(W) state regardless of how many requests the
session has ever seen, so its per-poll cost — and therefore its
per-request rate at a fixed drain width — must be independent of the
total population N.  This driver measures end-to-end session throughput
(submit -> schedule_batch dispatch -> MockProvider -> completion) at
N ∈ {1e3, 1e5} over a fast-physics provider (service « dt, so the
scheduler, not the mock, is the bottleneck) and emits `client_session`
rows into BENCH_scheduler.json.  `benchmarks/check_regression.py` gates
both the absolute rates and the N-independence ratio (the N=1e5
per-request rate must stay within 2x of N=1e3).

`--smoke` is the CI serving smoke: a small session must drain to 100%
completion over the mock, and the deprecated ScheduledClient shim must
still run a closed list end to end.

`--profile` runs the same sweep with the session's per-poll wall-time
accounting on and prints the stage/dispatch/pull/grants breakdown per
poll — the fastest way to see whether a regression is host-side
(staging, mirrors), dispatch overhead, or device compute (the blocking
summary pull).  Pass `--trace-dir DIR` to also capture a
`jax.profiler` trace of the N=1e3 run for TensorBoard/Perfetto.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import (  # noqa: E402,F401
    enable_compilation_cache,
    merge_rows,
)
from repro.client import (  # noqa: E402
    ClientSession,
    MockProvider,
    Request,
    SessionConfig,
)
from repro.core.policy import strategy  # noqa: E402
from repro.sim.provider import default_physics  # noqa: E402

N_SWEEP = (1_000, 100_000)
WINDOW = 1_024
GRANTS = 16
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_scheduler.json")


def _bench_policy():
    """Throughput-shaped policy: overload control off (every grant
    admits), per-class and global concurrency caps lifted (the fast
    mock never congests, so caps would only meter the drain), and an
    effectively infinite timeout so a deep N=1e5 backlog measures
    dispatch throughput, not abandonment bookkeeping."""
    return strategy("adaptive_drr")._replace(
        timeout_mult=jnp.full((4,), 1e9, jnp.float32),
        class_cap=jnp.full((2,), 1e9, jnp.float32),
        max_inflight=jnp.float32(1e9))


def _fast_physics():
    """Service far below a tick: completions land next poll, so the
    session's own per-poll cost is the measured quantity."""
    return default_physics(base_ms=1.0, ms_per_token=0.0,
                           comfort_concurrency=1e9)


def _requests(n: int) -> list[Request]:
    # all arrived at t=0: worst-case standing queue, every poll admits
    # into a full window and dispatches a full grant batch
    return [
        Request(rid=i, prompt=None, max_new=8.0, p50=8.0,
                bucket=i % 4, arrival_s=0.0)
        for i in range(n)
    ]


def client_session_bench(n_requests: int, window: int = WINDOW,
                         grants: int = GRANTS, profile: bool = False,
                         trace_dir: str | None = None,
                         repeats: int = 3) -> dict:
    # Single-drain wall time swings ~1.5x run to run on a busy host, which
    # is wider than the check_regression tolerance band — report the best
    # of `repeats` full drains so both the committed rows and the in-gate
    # measurement see the machine's actual capability, not its worst
    # scheduling hiccup.  Profiling/tracing runs stay single-drain so the
    # accumulated per-poll breakdown covers exactly one drain.
    if profile or trace_dir:
        repeats = 1
    policy = _bench_policy()
    phys = _fast_physics()
    best = None
    for _ in range(max(1, repeats)):
        sess = ClientSession(
            MockProvider(phys, dt_ms=25.0), policy,
            SessionConfig(window=window, max_grants=grants, dt_ms=25.0),
            clock="virtual", phys=phys)
        prof = sess.enable_profiling() if profile else None
        for r in _requests(n_requests):
            sess.submit(r)
        max_polls = 20 * (n_requests // grants + 50)
        if trace_dir:
            import jax
            jax.profiler.start_trace(trace_dir)
        t0 = time.perf_counter()
        sess.drain(max_polls=max_polls)
        wall = time.perf_counter() - t0
        if trace_dir:
            import jax
            jax.profiler.stop_trace()
        if prof and prof["polls"]:
            np_ = prof["polls"]
            acct = sum(
                prof[k] for k in ("stage", "dispatch", "pull", "grants"))
            print(f"    profile N={n_requests} ({np_} device polls, "
                  f"{acct / np_ * 1e6:7.1f}us/poll accounted):")
            for k in ("stage", "dispatch", "pull", "grants"):
                print(f"      {k:9s} {prof[k] / np_ * 1e6:8.1f}us/poll "
                      f"({prof[k] / acct * 100:5.1f}%)")
        n_done = sess.stats.n_completed
        if n_done != n_requests:
            raise RuntimeError(
                f"client_session_bench N={n_requests}: only {n_done} of "
                f"{n_requests} completed")
        row = {
            "n_requests": n_requests,
            "window": window,
            "max_grants": grants,
            "polls": sess.stats.n_polls,
            "poll_us": round(wall / sess.stats.n_polls * 1e6, 2),
            "requests_per_sec": round(n_requests / wall, 1),
        }
        if best is None or row["poll_us"] < best["poll_us"]:
            best = row
    return best


def write_client_bench(verbose: bool = True) -> str:
    prev = {}
    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError):
        pass
    rows = []
    for n in N_SWEEP:
        r = client_session_bench(n)
        rows.append(r)
        if verbose:
            print(f"  client_session N={n:7d} W={r['window']} "
                  f"B={r['max_grants']}: {r['poll_us']:8.1f}us/poll "
                  f"({r['requests_per_sec']:.0f} req/s)")
    prev["client_session"] = merge_rows(
        rows, prev.get("client_session", []),
        ("n_requests", "window", "max_grants"))
    by_n = {r["n_requests"]: r["requests_per_sec"] for r in rows}
    if len(N_SWEEP) == 2:
        ratio = by_n[N_SWEEP[1]] / by_n[N_SWEEP[0]]
        prev["client_session_n1e5_vs_n1e3_rate"] = round(ratio, 3)
        ok = ratio >= 0.5
        print(f"  [{'PASS' if ok else 'WARN'}] per-request rate at N=1e5 is "
              f"{ratio:.2f}x the N=1e3 rate "
              f"({'meets' if ok else 'MISSES'} the windowed "
              f"N-independence bar of >=0.5x)")
    with open(BENCH_JSON, "w") as f:
        json.dump(prev, f, indent=2)
    return BENCH_JSON


def smoke() -> int:
    """CI serving smoke: session over MockProvider drains to 100%, and
    the deprecated ScheduledClient shim still serves a closed list."""
    policy = _bench_policy()
    phys = _fast_physics()
    sess = ClientSession(
        MockProvider(phys, dt_ms=25.0), policy,
        SessionConfig(window=64, max_grants=8, dt_ms=25.0),
        clock="virtual", phys=phys)
    n = 256
    for r in _requests(n):
        sess.submit(r)
    sess.drain(max_polls=5000)
    if sess.stats.n_completed != n:
        print(f"FAIL: serving smoke completed {sess.stats.n_completed}/{n}")
        return 1
    print(f"  serving smoke: ClientSession drained {n}/{n} "
          f"in {sess.stats.n_polls} polls")

    import warnings

    from repro.serving import ScheduledClient

    class _Echo:
        def submit(self, prompt, max_new):
            return np.arange(int(max_new), dtype=np.int32)

    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=4.0,
                    p50=4.0, bucket=0) for i in range(4)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        out = ScheduledClient(_Echo(), strategy("final_adrr_olc")).run(
            reqs, time_scale=40.0)
    bad = [r.rid for r in out if r.status != "completed"]
    if bad:
        print(f"FAIL: serving smoke shim left {bad} uncompleted")
        return 1
    print("  serving smoke: ScheduledClient shim completed 4/4")
    return 0


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    if "--profile" in sys.argv:
        trace_dir = None
        if "--trace-dir" in sys.argv:
            trace_dir = sys.argv[sys.argv.index("--trace-dir") + 1]
        for i, n in enumerate(N_SWEEP):
            # trace only the first (small) run: a 1e5-poll trace is
            # gigabytes and the per-poll program is identical
            client_session_bench(n, profile=True,
                                 trace_dir=trace_dir if i == 0 else None)
        sys.exit(0)
    write_client_bench()
