"""Per-architecture provider physics (beyond-paper extension).

Connects the two halves of the framework: the DRY-RUN decode roofline of
each assigned architecture determines the mock provider's per-token cost
(the dominant decode term / batch = seconds per generated token per
request), and the paper's client-side stack is then evaluated against
each architecture's provider.

This answers a question the paper cannot ask with a single mock: does
the three-layer decomposition's advantage survive across backends that
differ by ~50x in per-token cost (mamba2-780m vs nemotron-4-340b)?

Output: paper_results/tables/arch_physics_summary.csv
"""
from __future__ import annotations

import json
import os

from repro.core.policy import strategy
from repro.sim import WorkloadConfig, run_cell, summarize
from repro.sim.provider import physics_for_arch
from repro.sim.workload import CONGESTION_MULT, _MEAN_TOKENS

from benchmarks.common import SIM, row_from_summary, write_csv

DRY_DIR = os.path.join(os.path.dirname(__file__), "..",
                       "paper_results", "dryrun")

HBM_BW = 819e9  # bytes/s per chip (v5e)


def ms_per_token_from_dryrun(arch: str) -> float | None:
    """Decode-step memory term / batch -> ms per generated token/request.

    decode_32k runs global_batch=128, so one step produces 128 tokens;
    the per-request serial cost is the full step time (all requests share
    the step), which we charge per token: step_s = bytes/dev / HBM_BW.
    """
    fn = os.path.join(DRY_DIR, f"{arch}__decode_32k__pod.json")
    if not os.path.exists(fn):
        return None
    rec = json.load(open(fn))
    if not rec.get("ok"):
        return None
    step_s = rec["hlo_bytes"] / HBM_BW
    return step_s * 1000.0


ARCHS = ["mamba2-780m", "stablelm-1.6b", "phi3.5-moe-42b-a6.6b",
         "qwen1.5-32b", "nemotron-4-340b"]


def run(verbose: bool = True):
    rows = []
    for arch in ARCHS:
        ms_tok = ms_per_token_from_dryrun(arch)
        if ms_tok is None:
            if verbose:
                print(f"  [skip] {arch}: no decode dry-run artifact")
            continue
        # clamp into a regime the 350 s sim horizon can express
        ms_tok_eff = min(max(ms_tok, 0.5), 40.0)
        phys = physics_for_arch(ms_per_token=ms_tok_eff)
        # offered load re-normalized to THIS provider's knee: the default
        # arrival_rate assumes 6.5 ms/token, so scale by the service-time
        # ratio (arrival_scale is a static WorkloadConfig field — each
        # value is its own compile, no jit-cache poisoning)
        default_service = 90.0 + 6.5 * _MEAN_TOKENS["balanced"]
        arch_service = 90.0 + ms_tok_eff * _MEAN_TOKENS["balanced"]
        scale = default_service / arch_service
        rate = CONGESTION_MULT["high"] * 4.0 / (arch_service / 1e3)
        n_req = max(48, min(200, int(rate * 80)))
        wl = WorkloadConfig(n_requests=n_req, mix="balanced",
                            congestion="high", information="coarse",
                            arrival_scale=round(scale, 4))
        for name in ("direct_naive", "final_adrr_olc"):
            s = summarize(run_cell(strategy(name), wl, seeds=3,
                                   phys=phys, sim_cfg=SIM))
            rows.append(row_from_summary(
                {"arch": arch, "ms_per_token": round(ms_tok_eff, 2),
                 "n_req": n_req, "strategy": name}, s))
            if verbose and name == "final_adrr_olc":
                naive = rows[-2]
                print(f"  {arch:22s} ms/tok={ms_tok_eff:5.1f} "
                      f"final sP95={s['short_p95_ms'][0]:6.0f} "
                      f"CR={s['completion_rate'][0]:.2f} "
                      f"(naive sP95={naive['short_p95_ms_mean']:.0f} "
                      f"CR={naive['completion_rate_mean']:.2f})")
    path = write_csv("arch_physics_summary", rows)
    # headline check: the structured stack protects short tails against
    # EVERY backend, fast or slow
    by_arch = {}
    for r in rows:
        by_arch.setdefault(r["arch"], {})[r["strategy"]] = r
    ok = all(
        v["final_adrr_olc"]["short_p95_ms_mean"]
        <= v["direct_naive"]["short_p95_ms_mean"] * 1.05
        and v["final_adrr_olc"]["completion_rate_mean"]
        >= v["direct_naive"]["completion_rate_mean"] - 0.02
        for v in by_arch.values() if len(v) == 2)
    print(f"  [{'PASS' if ok else 'WARN'}] three-layer stack dominates "
          f"naive on short-tail + completion for every backend arch")
    return path


if __name__ == "__main__":
    run()
