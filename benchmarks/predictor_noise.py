"""Paper Fig 8 (§4.10): multiplicative predictor-noise sweep
L in {0, 0.1, 0.2, 0.4, 0.6} on policy-facing p50/p90, physics fixed,
Final (OLC) fixed, all four regimes.

Validates: graceful degradation — no cliff; completion stays ~flat in
balanced regimes; the response is graded in heavy regimes.
"""
from repro.core.policy import strategy
from repro.sim.workload import REGIMES

from benchmarks.common import cell, row_from_summary, write_csv

LEVELS = [0.0, 0.1, 0.2, 0.4, 0.6]


def run(verbose=True):
    rows = []
    series = {}
    for mix, cong in REGIMES:
        for L in LEVELS:
            s = cell(strategy("final_adrr_olc"), mix, cong, predictor_noise=L)
            rows.append(row_from_summary(
                {"regime": f"{mix}/{cong}", "noise_L": L}, s))
            series.setdefault((mix, cong), []).append(s)
            if verbose:
                print(f"  {mix}/{cong:6s} L={L:.1f} "
                      f"sP95={s['short_p95_ms'][0]:5.0f} CR={s['completion_rate'][0]:.3f} "
                      f"gp={s['goodput_rps'][0]:.2f}")
    path = write_csv("predictor_noise_summary", rows)
    for (mix, cong), ss in series.items():
        crs = [x["completion_rate"][0] for x in ss]
        p95s = [x["short_p95_ms"][0] for x in ss]
        graceful = (min(crs) > 0.85 * max(crs)) and (max(p95s) < 2.5 * min(p95s))
        print(f"  [{'PASS' if graceful else 'WARN'}] {mix}/{cong}: graceful "
              f"(CR {min(crs):.2f}-{max(crs):.2f}, sP95 {min(p95s):.0f}-{max(p95s):.0f})")
    return path


if __name__ == "__main__":
    run()
