"""Paper Table 5 / Figs 5-6 (§4.7): overload bucket_policy shapes with
Final (OLC) otherwise fixed, under the two high-congestion regimes.

Also emits the overload-action histogram by bucket (Fig 5): rejections
must concentrate on xlong; shorts are never rejected.
"""
import jax
import numpy as np

from repro.core.policy import strategy, with_bucket_policy
from repro.core.types import REJECTED
from repro.sim import default_physics, generate, run_sim
from repro.sim.workload import WorkloadConfig

from benchmarks.common import SIM, N_REQ, cell, fmt, row_from_summary, write_csv

SHAPES = ["ladder", "uniform_mild", "uniform_harsh", "reverse"]


def action_histogram(shape: str, mix: str, cong: str, seeds=5):
    """Per-bucket reject/defer counts summed over seeds."""
    pol = with_bucket_policy(strategy("final_adrr_olc"), shape)
    rej = np.zeros(4)
    defers = np.zeros(4)
    for seed in range(seeds):
        wl = WorkloadConfig(n_requests=N_REQ, mix=mix, congestion=cong)
        batch, jit = generate(jax.random.PRNGKey(seed), wl)
        final = run_sim(pol, batch, jit, default_physics(), SIM)
        bkt = np.asarray(batch.bucket)
        rej += np.bincount(bkt[np.asarray(final.req.status) == REJECTED],
                           minlength=4)
        defers += np.bincount(bkt, weights=np.asarray(final.req.n_defers),
                              minlength=4)
    return rej, defers


def run(verbose=True):
    rows = []
    for mix, cong in [("balanced", "high"), ("heavy", "high")]:
        for shape in SHAPES:
            pol = with_bucket_policy(strategy("final_adrr_olc"), shape)
            s = cell(pol, mix, cong)
            rows.append(row_from_summary(
                {"regime": f"{mix}/{cong}", "bucket_policy": shape}, s))
            if verbose:
                print(f"  {mix}/{cong} {shape:14s} {fmt(s)} "
                      f"rej={s['n_rejects'][0]:.1f} def={s['n_defer_events'][0]:.1f}")
    path = write_csv("overload_policy_comparison_summary", rows)

    # Fig 5: action histogram for the default ladder over both regimes
    hist_rows = []
    for mix in ["balanced", "heavy"]:
        rej, defers = action_histogram("ladder", mix, "high")
        for b, name in enumerate(["short", "medium", "long", "xlong"]):
            hist_rows.append({"regime": f"{mix}/high", "bucket": name,
                              "rejects": int(rej[b]), "defers": int(defers[b])})
        print(f"  {mix}/high ladder actions: rejects by bucket {rej.astype(int)}, "
              f"defers {defers.astype(int)}")
        ok_short = rej[0] == 0 and defers[0] == 0
        ok_xlong = rej[3] >= rej[2]
        print(f"  [{'PASS' if ok_short else 'FAIL'}] shorts never rejected/deferred")
        print(f"  [{'PASS' if ok_xlong else 'WARN'}] rejections concentrate on xlong")
    write_csv("overload_actions_by_bucket", hist_rows)
    return path


if __name__ == "__main__":
    run()
