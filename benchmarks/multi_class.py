"""Beyond-paper: config-driven K-class scheduling sweep.

Exercises the tentpole generalization — the same three-layer stack
instantiated at K ∈ {2, 4, 8} tenants under balanced/heavy congestion —
and reports:

  * per-class joint metrics (P95 / deadline satisfaction / goodput) so
    multi-tenant fairness is legible per lane, plus the cross-class
    dispersion that the DRR allocation is supposed to bound;
  * scheduler-step wall-clock per K (the vectorized class axis must be
    no slower at K=2 than the seed two-lane path, and ~flat in K);
  * batch-dispatch throughput: `schedule_batch` at B ∈ {1, 4, 16}
    grants per tick × queue depth N ∈ {1e3, 1e5} — the multi-grant pass
    amortizes the O(K·N) layer-2 work over B grants, so slots/sec must
    scale super-linearly vs B sequential single-slot traces (the
    acceptance bar is ≥2× at B=16 vs B=1 at equal tick budgets);
  * active-window dispatch throughput (DESIGN.md §6): the windowed
    per-tick policy path at N ∈ {1e3, 1e5[, 1e6 with --scale]} × W ∈
    {1024, 4096} plus end-to-end windowed engine ticks/sec — per-tick
    cost is O(W), so the rate must be ~flat in N where the dense rows
    collapse ~30× (the acceptance bar is ≥10× the dense B=1 N=1e5
    rate), and the N=1e6 engine row is the population the dense scan
    cannot run at all;
  * a `BENCH_scheduler.json` microbenchmark artifact (all sweeps) so
    future PRs have a perf trajectory to compare against.

The K=2 cell runs the paper's `paper2` lane scheme with the seed policy
(bit-exact with the seed scheduler — tests/test_multi_class.py), so its
per-class metrics double as the seed-equivalence check: lane 0 equals
the short-bucket scalars within seed noise.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.sim.engine as eng  # noqa: E402
from repro.core.policy import base_policy, kclass_policy, n_classes  # noqa: E402
from repro.core.scheduler import schedule_batch, schedule_slot  # noqa: E402
from repro.core.types import (  # noqa: E402
    WindowCarry,
    init_sim_state,
)
from repro.sim import (  # noqa: E402
    SimConfig,
    WorkloadConfig,
    default_physics,
    run_cell,
    run_sim,
    summarize,
)

from benchmarks.common import (  # noqa: E402
    Timer,
    merge_rows,
    write_csv,
)

K_SWEEP = (2, 4, 8)
B_SWEEP = (1, 4, 16)           # grants per batched dispatch pass
N_SWEEP = (1_000, 100_000)     # queue depths (requests resident)
# active-window sweep (DESIGN.md §6): horizon population x window
# capacity.  N_SCALE only runs under --scale (`make bench-scale`) —
# the dense path cannot touch it at all, the windowed rows prove it
# runs; rows for skipped Ns are preserved from the committed artifact.
W_SWEEP = (1_024, 4_096)
N_SWEEP_WIN = (1_000, 100_000)
N_SCALE = 1_000_000
WB_SWEEP = (1, 16)             # grants per windowed dispatch pass
REGIMES = [("balanced", "medium"), ("heavy", "high")]
MAX_K = max(K_SWEEP)
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_scheduler.json")


def _policy_for(k: int):
    """K=2 runs the seed (paper) policy on the paper2 lanes; K>2 runs the
    symmetric-tenant instantiation of the same stack."""
    return base_policy() if k == 2 else kclass_policy(k)


def _workload_for(k: int, mix: str, congestion: str, n_req: int):
    cmap = "paper2" if k == 2 else f"tenant{k}"
    return WorkloadConfig(
        n_requests=n_req, mix=mix, congestion=congestion, class_map=cmap)


def _cell_row(k, mix, congestion, s, secs):
    row = {
        "n_classes": k,
        "mix": mix,
        "congestion": congestion,
        "cell_seconds": round(secs, 2),
    }
    for key in ("global_p95_ms", "completion_rate", "satisfaction",
                "goodput_rps", "n_rejects"):
        row[f"{key}_mean"] = round(s[key][0], 3)
    for c in range(MAX_K):
        for key in ("class_p95_ms", "class_satisfaction", "class_goodput_rps"):
            v = s.get(f"{key}#{c}")
            row[f"{key.replace('class_', '')}_c{c}"] = (
                round(v, 3) if v is not None else "")
    return row


def _per_class_summary(m, k):
    """mean over seeds for each class lane, flattened to scalar keys."""
    out = summarize(m)
    flat = {kk: vv for kk, vv in out.items()}
    for name in ("class_p95_ms", "class_satisfaction", "class_goodput_rps"):
        arr = np.asarray(getattr(m, name), np.float64)  # (seeds, K)
        for c in range(k):
            col = arr[:, c]
            finite = col[np.isfinite(col)]
            # a lane can be empty in short smoke runs: report NaN quietly
            flat[f"{name}#{c}"] = (
                float(finite.mean()) if finite.size else float("nan"))
    return flat


def scheduler_step_bench(k: int, n_req: int = 256, iters: int = 300) -> dict:
    """Wall-clock of one jitted schedule_slot at class count K."""
    policy = _policy_for(k)
    wl = _workload_for(k, "heavy", "high", n_req)
    from repro.sim.workload import generate

    batch, _ = generate(jax.random.PRNGKey(0), wl)
    state = init_sim_state(batch.n, n_classes(policy))._replace(
        now_ms=jnp.float32(1e5))
    step = jax.jit(schedule_slot)

    t0 = time.perf_counter()
    d = step(policy, batch, state)
    jax.block_until_ready(d)
    compile_s = time.perf_counter() - t0

    # best-of-3: shared-container noise easily swamps a single block
    run_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            d = step(policy, batch, state)
        jax.block_until_ready(d)
        run_s = min(run_s, time.perf_counter() - t0)
    return {
        "n_classes": k,
        "n_requests": n_req,
        "compile_seconds": round(compile_s, 4),
        "slot_us": round(run_s / iters * 1e6, 2),
        "slots_per_sec": round(iters / run_s, 1),
    }


def batch_dispatch_bench(b: int, n_req: int, iters: int = 100) -> dict:
    """Wall-clock of one jitted schedule_batch granting up to B per call
    at queue depth N.  slots/sec counts grant opportunities (B × calls),
    the apples-to-apples rate against B sequential schedule_slot calls
    at an equal tick budget."""
    policy = base_policy()
    wl = _workload_for(2, "heavy", "high", n_req)
    from repro.sim.workload import generate

    batch, _ = generate(jax.random.PRNGKey(0), wl)
    state = init_sim_state(batch.n, n_classes(policy))._replace(
        now_ms=jnp.float32(1e7))  # everything arrived: worst-case queue
    step = jax.jit(schedule_batch, static_argnames=("max_grants", "backend"))

    t0 = time.perf_counter()
    d = step(policy, batch, state, max_grants=b)
    jax.block_until_ready(d)
    compile_s = time.perf_counter() - t0

    run_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            d = step(policy, batch, state, max_grants=b)
        jax.block_until_ready(d)
        run_s = min(run_s, time.perf_counter() - t0)
    return {
        "max_grants": b,
        "n_requests": n_req,
        "compile_seconds": round(compile_s, 4),
        "call_us": round(run_s / iters * 1e6, 2),
        "slots_per_sec": round(b * iters / run_s, 1),
    }


def _full_window(n_req: int, w: int):
    """Worst-case live queue: a full window of arrived pending work over
    an N-deep horizon population.  Slot i holds request i (the window is
    request-id sorted by construction, matching the engine invariant)."""
    policy = base_policy()
    wl = _workload_for(2, "heavy", "high", n_req)
    from repro.sim.workload import generate

    batch, jitter = generate(jax.random.PRNGKey(0), wl)
    state = init_sim_state(batch.n, n_classes(policy))._replace(
        now_ms=jnp.float32(1e7))
    win = WindowCarry(
        slot_req=jnp.arange(w, dtype=jnp.int32),
        arr_ptr=jnp.int32(w),
        n_live=jnp.int32(w),
    )
    return policy, batch, jitter, state, win


def windowed_dispatch_bench(b: int, n_req: int, w: int,
                            iters: int = 100) -> dict:
    """Wall-clock of one windowed dispatch step — the active-window
    engine's per-tick policy path: gather the (W,) window view, run
    `schedule_batch` over (K, W), translate slot decisions to global
    request ids.  Cost is O(W) by construction; `n_req` only sets the
    population the view gathers from, so the rate should be ~flat in N
    at fixed W — the tentpole property the dense rows above collapse on.
    """
    assert w <= n_req
    policy, batch, _, state, win = _full_window(n_req, w)

    @functools.partial(jax.jit, static_argnames=("max_grants",))
    def step(state, win, max_grants):
        wb, wr, _ = eng._window_view(batch, state.req, win.slot_req)
        d = schedule_batch(policy, wb, state._replace(req=wr),
                           max_grants=max_grants)
        return d._replace(req_idx=win.slot_req[jnp.clip(d.req_idx, 0, w - 1)])

    t0 = time.perf_counter()
    d = step(state, win, max_grants=b)
    jax.block_until_ready(d)
    compile_s = time.perf_counter() - t0

    run_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            d = step(state, win, max_grants=b)
        jax.block_until_ready(d)
        run_s = min(run_s, time.perf_counter() - t0)
    return {
        "max_grants": b,
        "n_requests": n_req,
        "window": w,
        "compile_seconds": round(compile_s, 4),
        "call_us": round(run_s / iters * 1e6, 2),
        "slots_per_sec": round(b * iters / run_s, 1),
    }


def windowed_engine_bench(n_req: int, w: int, n_ticks: int = 400,
                          k_slots: int = 16) -> dict:
    """End-to-end windowed `run_sim` throughput (ticks/sec) at horizon
    population N — admission, compaction, retirement scatters and the
    dispatch pass included.  The N=1e6 row is the feasibility proof: the
    dense engine's per-tick O(K*N) scan cannot run that population at
    all (extrapolated ~3 slots/s from the committed N=1e5 collapse)."""
    policy = base_policy()
    wl = _workload_for(2, "heavy", "high", n_req)
    from repro.sim.workload import generate

    batch, jitter = generate(jax.random.PRNGKey(0), wl)
    phys = default_physics()
    cfg = SimConfig(n_ticks=n_ticks, k_slots=k_slots, window=w)

    run = jax.jit(lambda: run_sim(policy, batch, jitter, phys, cfg))
    t0 = time.perf_counter()
    jax.block_until_ready(run())
    compile_and_first_s = time.perf_counter() - t0

    run_s = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        run_s = min(run_s, time.perf_counter() - t0)
    return {
        "n_requests": n_req,
        "window": w,
        "n_ticks": n_ticks,
        "k_slots": k_slots,
        "first_run_seconds": round(compile_and_first_s, 3),
        "ticks_per_sec": round(n_ticks / run_s, 1),
        "grant_opps_per_sec": round(k_slots * n_ticks / run_s, 1),
    }


def write_windowed_bench(bench: dict, prev: dict, scale: bool = False,
                         verbose: bool = True) -> None:
    """Active-window N x W sweep appended into the BENCH artifact."""
    n_sweep = N_SWEEP_WIN + ((N_SCALE,) if scale else ())
    rows = []
    for n_req in n_sweep:
        # a window cannot exceed the population; small-N cells fall back
        # to W=N (the window covers everything — the dense-equivalent)
        ws = [w for w in W_SWEEP if w <= n_req] or [n_req]
        for w in ws:
            for b in WB_SWEEP:
                r = windowed_dispatch_bench(b, n_req, w, iters=100)
                rows.append(r)
                if verbose:
                    print(f"  windowed    B={b:2d} N={n_req:7d} W={w:5d}: "
                          f"{r['call_us']:9.1f}us/call "
                          f"({r['slots_per_sec']:.0f} slots/s)")
    bench["windowed_dispatch"] = merge_rows(
        rows, prev.get("windowed_dispatch", []),
        ("max_grants", "n_requests", "window"))

    erows = []
    for n_req in n_sweep:
        er = windowed_engine_bench(n_req, w=min(4096, n_req))
        erows.append(er)
        if verbose:
            print(f"  engine(win) N={n_req:7d} W={er['window']:5d}: "
                  f"{er['ticks_per_sec']:.0f} ticks/s "
                  f"({er['grant_opps_per_sec']:.0f} grant-opps/s)")
    bench["windowed_engine"] = merge_rows(
        erows, prev.get("windowed_engine", []), ("n_requests",))

    # headline ratios: windowed vs dense dispatch at the deep queue —
    # the tentpole acceptance bar is >=10x the dense B=1 N=1e5 rate at
    # a production-sized window (per-W keys: the W=1024 cell is the
    # live-queue-sized operating point, W=4096 the worst case)
    dense = {(r["max_grants"], r["n_requests"]): r["slots_per_sec"]
             for r in bench.get("batch_dispatch", [])}
    win = {(r["max_grants"], r["n_requests"], r["window"]): r["slots_per_sec"]
           for r in bench["windowed_dispatch"]}
    base = dense.get((1, 100_000))
    best = 0.0
    for w in W_SWEEP:
        fresh = win.get((1, 100_000, w))
        if base and fresh:
            ratio = fresh / base
            best = max(best, ratio)
            bench[f"win_vs_dense_b1_rate_n100000_w{w}"] = round(ratio, 3)
    if best:
        ok = best >= 10.0
        print(f"  [{'PASS' if ok else 'WARN'}] windowed B=1 N=1e5 dispatch "
              f"up to {best:.1f}x the dense rate "
              f"({'meets' if ok else 'MISSES'} the >=10x bar)")


def write_batch_bench(bench: dict, verbose: bool = True) -> None:
    """B × N batch-dispatch sweep appended into the BENCH artifact."""
    rows = []
    for n_req in N_SWEEP:
        iters = 100 if n_req <= 10_000 else 20
        base_rate = None
        for b in B_SWEEP:
            r = batch_dispatch_bench(b, n_req, iters=iters)
            rows.append(r)
            if b == 1:
                base_rate = r["slots_per_sec"]
            if verbose:
                print(f"  schedule_batch B={b:2d} N={n_req:6d}: "
                      f"{r['call_us']:9.1f}us/call "
                      f"({r['slots_per_sec']:.0f} slots/s)")
        ratio = rows[-1]["slots_per_sec"] / base_rate
        key = f"b16_vs_b1_rate_ratio_n{n_req}"
        bench[key] = round(ratio, 3)
        ok = ratio >= 2.0
        print(f"  [{'PASS' if ok else 'WARN'}] N={n_req}: B=16 grants "
              f"{ratio:.1f}x the B=1 slot rate at equal tick budgets "
              f"({'meets' if ok else 'MISSES'} the >=2x bar)")
    bench["batch_dispatch"] = rows


# aggregate summary keys that must be finite in every cell (exactly the
# columns _cell_row emits): NaN/inf here means a degenerate run (nothing
# arrived or completed), which must fail loudly — a silent pass would
# blind the CI bench gate.  Per-lane values are exempt: a lane can be
# legitimately empty in short smoke runs.
REQUIRED_FINITE = (
    "global_p95_ms", "completion_rate", "satisfaction", "goodput_rps",
    "n_rejects",
)


def check_finite(rows: list[dict]) -> list[str]:
    """Returns violation strings for any non-finite required aggregate."""
    bad = []
    for row in rows:
        for key in REQUIRED_FINITE:
            v = row.get(f"{key}_mean")
            if v is None or not np.isfinite(v):
                bad.append(
                    f"K={row['n_classes']} {row['mix']}/{row['congestion']}: "
                    f"{key}_mean = {v}")
    return bad


def run(verbose: bool = True, n_ticks: int | None = None, n_req: int = 160,
        seeds: int = 5, sched_bench: bool = True):
    sim_cfg = SimConfig(n_ticks=n_ticks if n_ticks is not None else 14000)
    rows = []
    k2_summary = {}
    for mix, congestion in REGIMES:
        for k in K_SWEEP:
            wl = _workload_for(k, mix, congestion, n_req)
            with Timer() as t:
                m = run_cell(_policy_for(k), wl, seeds=seeds, sim_cfg=sim_cfg)
                jax.block_until_ready(m.class_p95_ms)
            s = _per_class_summary(m, k)
            if k == 2:
                k2_summary[(mix, congestion)] = s
            rows.append(_cell_row(k, mix, congestion, s, t.s))
            if verbose:
                lanes = " ".join(
                    f"c{c}:{s[f'class_satisfaction#{c}']:.2f}"
                    for c in range(k))
                print(f"  K={k} {mix}/{congestion:6s} {t.s:5.1f}s "
                      f"goodput={s['goodput_rps'][0]:.2f} sat/lane [{lanes}]")

    path = write_csv("multi_class_summary", rows)

    # --- seed-equivalence readout: paper2 lane 0 == short-bucket scalars
    for (mix, congestion), s in k2_summary.items():
        short_scalar = s["short_p95_ms"][0]
        lane0 = s["class_p95_ms#0"]
        ok = (not np.isfinite(short_scalar)) or abs(lane0 - short_scalar) <= max(
            0.05 * short_scalar, 1.0)
        print(f"  [{'PASS' if ok else 'WARN'}] K=2 {mix}/{congestion}: lane-0 "
              f"P95 {lane0:.0f}ms matches short-bucket scalar "
              f"{short_scalar:.0f}ms")

    violations = check_finite(rows)
    if violations:
        # raise (don't just return) so every driver — __main__/--smoke,
        # benchmarks/run.py, an interactive call — fails loudly
        print("FAIL: non-finite aggregate metrics:")
        for v in violations:
            print(f"  {v}")
        raise RuntimeError(
            f"degenerate benchmark run: {len(violations)} non-finite "
            f"aggregate metric(s)")

    # --- scheduler-step microbenchmark -> BENCH_scheduler.json
    # (skipped in smoke: the committed artifact is the full run's, and
    # the CI regression gate compares fresh numbers against it)
    if sched_bench:
        write_sched_bench(verbose=verbose)
    return path, BENCH_JSON


def write_sched_bench(verbose: bool = True, iters: int = 300,
                      scale: bool = False) -> str:
    """Scheduler-throughput microbenchmark: slots/sec per K, the
    batch-dispatch B × N sweep, and the active-window N × W sweep,
    written to BENCH_scheduler.json so future PRs have a perf
    trajectory.  `scale` adds the N=1e6 cells (`make bench-scale`);
    without it the committed N=1e6 rows are carried forward."""
    prev = {}
    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError):
        pass
    bench = {"benchmark": "schedule_slot", "steps": []}
    base_rate = None
    for k in K_SWEEP:
        b = scheduler_step_bench(k, iters=iters)
        bench["steps"].append(b)
        if k == 2:
            base_rate = b["slots_per_sec"]
        if verbose:
            print(f"  schedule_slot K={k}: {b['slot_us']:7.1f}us/slot "
                  f"({b['slots_per_sec']:.0f} slots/s, "
                  f"compile {b['compile_seconds']:.2f}s)")
    k8_rate = bench["steps"][-1]["slots_per_sec"]
    bench["k8_vs_k2_rate_ratio"] = round(k8_rate / base_rate, 3)
    ok = k8_rate >= 0.5 * base_rate
    print(f"  [{'PASS' if ok else 'WARN'}] K=8 scheduler rate "
          f"{'within' if ok else 'NOT within'} 2x of K=2 "
          f"(vectorized class axis)")
    # persist the K sweep before the (longer) batch sweep, then rewrite
    # with the batch rows — an interrupted B x N run can't lose the data
    # already computed
    with open(BENCH_JSON, "w") as f:
        json.dump(bench, f, indent=2)
    write_batch_bench(bench, verbose=verbose)
    with open(BENCH_JSON, "w") as f:
        json.dump(bench, f, indent=2)
    write_windowed_bench(bench, prev, scale=scale, verbose=verbose)
    with open(BENCH_JSON, "w") as f:
        json.dump(bench, f, indent=2)
    return BENCH_JSON


if __name__ == "__main__":
    if "--sched-only" in sys.argv:
        write_sched_bench(scale="--scale" in sys.argv)
    else:
        smoke = "--smoke" in sys.argv
        try:
            run(n_ticks=300 if smoke else None,
                n_req=48 if smoke else 160,
                seeds=2 if smoke else 5,
                sched_bench=not smoke)
        except RuntimeError as e:
            print(e)
            sys.exit(1)
