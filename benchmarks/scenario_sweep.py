"""Scenario sweep: the full policy stack across the nonstationary
scenario registry (DESIGN.md §5) × allocation modes.

For every named scenario (burst trains, diurnal ramps, heavy-dominated
phase shifts, flash crowds, brownouts, provider rate limits, …) and
each allocation mode, runs the three-layer stack over seeds and reports
per-phase windowed metrics — P95 by class, deadline satisfaction, shed
counts by ladder rung, provider 429 bounces — into the
`BENCH_scenarios.json` artifact.  This is the regime grid the paper's
regime-dependent claims actually turn on: the stationary anchors are
where the policies agree, the nonstationary cells are where they
separate.

`--smoke` runs a CI-sized slice (no artifact write — the committed
artifact is the full run's) and exits nonzero if any required aggregate
metric is NaN/inf, so a degenerate run can't pass silently.
"""
from __future__ import annotations

import json
import os
import sys
import time
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import numpy as np  # noqa: E402

from benchmarks import common as _common  # noqa: E402,F401 (enables the
                                          # persistent compilation cache)
from repro.core.policy import fair_queuing, final_adrr_olc  # noqa: E402
from repro.sim import (  # noqa: E402
    SimConfig,
    list_scenarios,
    run_scenario_cell,
    summarize,
)

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_scenarios.json")

ALLOC_MODES = {
    "adrr": final_adrr_olc,   # the paper's Final (OLC) stack
    "fq": fair_queuing,       # strict round-robin allocation, no OLC
}

# aggregates that must be finite in every cell — a NaN here means the
# run was degenerate (nothing completed / nothing arrived), which must
# fail loudly rather than produce an empty-looking artifact
REQUIRED_FINITE = (
    "completion_rate", "satisfaction", "goodput_rps", "global_p95_ms",
    "makespan_ms",
)


def _mean_over_seeds(arr) -> np.ndarray:
    a = np.asarray(arr, np.float64)
    with warnings.catch_warnings():
        # a phase can be legitimately empty across every seed (no
        # completions in a trough window) — report NaN -> null, quietly
        warnings.simplefilter("ignore", RuntimeWarning)
        return np.nanmean(a, axis=0)


def _phase_rows(pm) -> list[dict]:
    """Per-phase dicts, seed-averaged; class vectors flattened to lists."""
    mean = {name: _mean_over_seeds(getattr(pm, name)) for name in pm._fields}

    def f(x, r=3):
        v = float(x)
        return round(v, r) if np.isfinite(v) else None

    rows = []
    for p in range(mean["phase_start_ms"].shape[0]):
        rows.append({
            "start_ms": f(mean["phase_start_ms"][p], 1),
            "n_arrived": f(mean["n_arrived"][p], 1),
            "n_completed": f(mean["n_completed"][p], 1),
            "n_abandoned": f(mean["n_abandoned"][p], 1),
            "n_throttled": f(mean["n_throttled"][p], 1),
            "shed_by_bucket": [f(v, 1) for v in mean["shed_by_bucket"][p]],
            "satisfaction": f(mean["satisfaction"][p]),
            "p95_ms": f(mean["p95_ms"][p], 1),
            "class_p95_ms": [f(v, 1) for v in mean["class_p95_ms"][p]],
            "class_satisfaction": [
                f(v) for v in mean["class_satisfaction"][p]],
        })
    return rows


def run_sweep(
    *,
    n_requests: int,
    n_ticks: int,
    seeds: int,
    verbose: bool = True,
) -> tuple[list[dict], list[str]]:
    """Returns (cell dicts, list of NaN/inf violations)."""
    sim_cfg = SimConfig(n_ticks=n_ticks)
    cells, violations = [], []
    for name in list_scenarios():
        for mode, policy_fn in ALLOC_MODES.items():
            t0 = time.perf_counter()
            m, pm = run_scenario_cell(
                policy_fn(), name,
                seeds=seeds, n_requests=n_requests, sim_cfg=sim_cfg,
            )
            secs = time.perf_counter() - t0
            s = summarize(m)
            for key in REQUIRED_FINITE:
                if not np.isfinite(s[key][0]):
                    violations.append(f"{name}/{mode}: {key} = {s[key][0]}")
            agg = {
                k: round(s[k][0], 3) if np.isfinite(s[k][0]) else None
                for k in REQUIRED_FINITE + ("n_rejects", "n_abandoned")
            }
            agg["n_throttled"] = round(
                float(np.asarray(pm.n_throttled, np.float64).sum(axis=1).mean()),
                1,
            )
            cells.append({
                "scenario": name,
                "alloc": mode,
                "cell_seconds": round(secs, 2),
                "aggregate": agg,
                "phases": _phase_rows(pm),
            })
            if verbose:
                def fv(key, spec):
                    v = agg[key]
                    return format(v, spec) if v is not None else "nan"
                print(
                    f"  {name:16s} {mode:5s} {secs:5.1f}s "
                    f"cr={fv('completion_rate', '.2f')} "
                    f"sat={fv('satisfaction', '.2f')} "
                    f"p95={fv('global_p95_ms', '.0f')}ms "
                    f"shed={fv('n_rejects', '.1f')} "
                    f"429={agg['n_throttled']:.0f}"
                )
    return cells, violations


def main(argv: list[str]) -> int:
    smoke = "--smoke" in argv
    if smoke:
        cells, violations = run_sweep(n_requests=48, n_ticks=2400, seeds=2)
    else:
        cells, violations = run_sweep(n_requests=160, n_ticks=14000, seeds=3)
        artifact = {
            "benchmark": "scenario_sweep",
            "sim": {"n_requests": 160, "n_ticks": 14000, "seeds": 3},
            "alloc_modes": sorted(ALLOC_MODES),
            "scenarios": list_scenarios(),
            "cells": cells,
        }
        with open(BENCH_JSON, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {os.path.relpath(BENCH_JSON)} ({len(cells)} cells)")
    if violations:
        print("FAIL: non-finite aggregate metrics:")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"scenario sweep OK: {len(cells)} cells, all aggregates finite")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
