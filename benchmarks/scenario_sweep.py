"""Scenario sweep: the full policy stack across the nonstationary
scenario registry (DESIGN.md §5) × allocation modes.

For every named scenario (burst trains, diurnal ramps, heavy-dominated
phase shifts, flash crowds, brownouts, provider rate limits, …) and
each allocation mode, runs the three-layer stack over seeds and reports
per-phase windowed metrics — P95 by class, deadline satisfaction, shed
counts by ladder rung, provider 429 bounces — into the
`BENCH_scenarios.json` artifact.  This is the regime grid the paper's
regime-dependent claims actually turn on: the stationary anchors are
where the policies agree, the nonstationary cells are where they
separate.

`--smoke` runs a CI-sized slice (no artifact write — the committed
artifact is the full run's) and exits nonzero if any required aggregate
metric is NaN/inf, so a degenerate run can't pass silently.

`--engine {windowed,dense}` selects the per-tick execution strategy:
`windowed` (the default) runs every cell on the O(W) active-window
engine with W from `window_for(n_requests)`; `dense` forces the
original O(N) scan.  The two are bit-exact whenever W covers the peak
live queue (tests/test_scenarios.py pins this per scenario), so the
flag changes wall-clock, not results — `dense` exists for A/B timing
and as the oracle when sizing W for a new regime.

`--scale` is the N=1e6 sweep (`make bench-scale`, never CI): the full
scenario grid at a million requests on the windowed engine, with
`arrival_scale` compressing the offered load into the nominal N=160
span so the horizon stays 14k ticks while the population grows 6250x.
Rows land under the `scale_1e6` key of `BENCH_scenarios.json`
(informational — deep-overload cells legitimately shed almost
everything, so the NaN gate is reported but not enforced there).
"""
from __future__ import annotations

import json
import os
import sys
import time
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import numpy as np  # noqa: E402

from benchmarks import common as _common  # noqa: E402,F401 (enables the
                                          # persistent compilation cache)
from repro.core.policy import fair_queuing, final_adrr_olc  # noqa: E402
from repro.sim import (  # noqa: E402
    SimConfig,
    list_scenarios,
    run_scenario_cell,
    summarize,
    window_for,
)

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_scenarios.json")

ALLOC_MODES = {
    "adrr": final_adrr_olc,   # the paper's Final (OLC) stack
    "fq": fair_queuing,       # strict round-robin allocation, no OLC
}

# aggregates that must be finite in every cell — a NaN here means the
# run was degenerate (nothing completed / nothing arrived), which must
# fail loudly rather than produce an empty-looking artifact
REQUIRED_FINITE = (
    "completion_rate", "satisfaction", "goodput_rps", "global_p95_ms",
    "makespan_ms",
)


def _mean_over_seeds(arr) -> np.ndarray:
    a = np.asarray(arr, np.float64)
    with warnings.catch_warnings():
        # a phase can be legitimately empty across every seed (no
        # completions in a trough window) — report NaN -> null, quietly
        warnings.simplefilter("ignore", RuntimeWarning)
        return np.nanmean(a, axis=0)


def _phase_rows(pm) -> list[dict]:
    """Per-phase dicts, seed-averaged; class vectors flattened to lists."""
    mean = {name: _mean_over_seeds(getattr(pm, name)) for name in pm._fields}

    def f(x, r=3):
        v = float(x)
        return round(v, r) if np.isfinite(v) else None

    rows = []
    for p in range(mean["phase_start_ms"].shape[0]):
        rows.append({
            "start_ms": f(mean["phase_start_ms"][p], 1),
            "n_arrived": f(mean["n_arrived"][p], 1),
            "n_completed": f(mean["n_completed"][p], 1),
            "n_abandoned": f(mean["n_abandoned"][p], 1),
            "n_throttled": f(mean["n_throttled"][p], 1),
            "shed_by_bucket": [f(v, 1) for v in mean["shed_by_bucket"][p]],
            "satisfaction": f(mean["satisfaction"][p]),
            "p95_ms": f(mean["p95_ms"][p], 1),
            "class_p95_ms": [f(v, 1) for v in mean["class_p95_ms"][p]],
            "class_satisfaction": [
                f(v) for v in mean["class_satisfaction"][p]],
        })
    return rows


def _engine_scenarios() -> list[str]:
    """Registry scenarios the closed-loop engine can score.  Fault
    scenarios (sim/faults.py) break the transport contract at the live
    provider boundary only — the engine models an honest transport, so
    they ride benchmarks/fault_sweep.py instead."""
    from repro.sim import get_scenario
    return [n for n in list_scenarios() if get_scenario(n).faults is None]


def run_sweep(
    *,
    n_requests: int,
    n_ticks: int,
    seeds: int,
    engine: str = "windowed",
    arrival_scale: float = 1.0,
    verbose: bool = True,
) -> tuple[list[dict], list[str]]:
    """Returns (cell dicts, list of NaN/inf violations)."""
    if engine not in ("windowed", "dense"):
        raise ValueError(f"engine must be 'windowed' or 'dense', got {engine!r}")
    window = window_for(n_requests) if engine == "windowed" else None
    sim_cfg = SimConfig(n_ticks=n_ticks, window=window)
    cells, violations = [], []
    for name in _engine_scenarios():
        for mode, policy_fn in ALLOC_MODES.items():
            t0 = time.perf_counter()
            m, pm = run_scenario_cell(
                policy_fn(), name,
                seeds=seeds, n_requests=n_requests, sim_cfg=sim_cfg,
                arrival_scale=arrival_scale,
            )
            secs = time.perf_counter() - t0
            s = summarize(m)
            for key in REQUIRED_FINITE:
                if not np.isfinite(s[key][0]):
                    violations.append(f"{name}/{mode}: {key} = {s[key][0]}")
            agg = {
                k: round(s[k][0], 3) if np.isfinite(s[k][0]) else None
                for k in REQUIRED_FINITE + ("n_rejects", "n_abandoned")
            }
            agg["n_throttled"] = round(
                float(np.asarray(pm.n_throttled, np.float64).sum(axis=1).mean()),
                1,
            )
            cells.append({
                "scenario": name,
                "alloc": mode,
                "cell_seconds": round(secs, 2),
                "aggregate": agg,
                "phases": _phase_rows(pm),
            })
            if verbose:
                def fv(key, spec):
                    v = agg[key]
                    return format(v, spec) if v is not None else "nan"
                print(
                    f"  {name:16s} {mode:5s} {secs:5.1f}s "
                    f"cr={fv('completion_rate', '.2f')} "
                    f"sat={fv('satisfaction', '.2f')} "
                    f"p95={fv('global_p95_ms', '.0f')}ms "
                    f"shed={fv('n_rejects', '.1f')} "
                    f"429={agg['n_throttled']:.0f}"
                )
    return cells, violations


SCALE_N = 1_000_000
SCALE_BASE_N = 160  # arrival_scale = SCALE_N / SCALE_BASE_N keeps the
                    # span at the nominal full-run horizon (14k ticks)


def run_scale_sweep(verbose: bool = True) -> int:
    """The first full-grid N=1e6 run: every scenario × alloc mode at a
    million requests on the windowed engine (W = window_for cap), one
    seed, offered over the nominal N=160 span.  Writes the rows under
    `scale_1e6` in BENCH_scenarios.json, preserving the full-run cells.
    Deep overload is the regime being measured, so NaN aggregates
    (nothing completed in a phase) are reported, not fatal."""
    cells, violations = run_sweep(
        n_requests=SCALE_N, n_ticks=14000, seeds=1,
        arrival_scale=SCALE_N / SCALE_BASE_N, verbose=verbose)
    prev = {}
    try:
        with open(BENCH_JSON) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError):
        pass
    prev["scale_1e6"] = {
        "sim": {"n_requests": SCALE_N, "n_ticks": 14000, "seeds": 1,
                "engine": "windowed",
                "arrival_scale": SCALE_N / SCALE_BASE_N},
        "cells": cells,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(prev, f, indent=2)
    print(f"wrote {os.path.relpath(BENCH_JSON)} scale_1e6 "
          f"({len(cells)} cells)")
    if violations:
        print(f"note: {len(violations)} non-finite aggregates under deep "
              f"overload (informational):")
        for v in violations:
            print(f"  {v}")
    return 0


def main(argv: list[str]) -> int:
    smoke = "--smoke" in argv
    engine = "windowed"
    if "--engine" in argv:
        engine = argv[argv.index("--engine") + 1]
    if "--scale" in argv:
        return run_scale_sweep()
    if smoke:
        cells, violations = run_sweep(n_requests=48, n_ticks=2400, seeds=2,
                                      engine=engine)
    else:
        cells, violations = run_sweep(n_requests=160, n_ticks=14000, seeds=3,
                                      engine=engine)
        artifact = {
            "benchmark": "scenario_sweep",
            "sim": {"n_requests": 160, "n_ticks": 14000, "seeds": 3,
                    "engine": engine},
            "alloc_modes": sorted(ALLOC_MODES),
            "scenarios": _engine_scenarios(),
            "cells": cells,
        }
        with open(BENCH_JSON, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {os.path.relpath(BENCH_JSON)} ({len(cells)} cells)")
    if violations:
        print("FAIL: non-finite aggregate metrics:")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"scenario sweep OK: {len(cells)} cells, all aggregates finite")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
