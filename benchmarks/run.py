"""Benchmark suite entry point — one module per paper table/figure.

Prints `name,seconds,artifact` CSV lines and writes every table to
paper_results/tables/.  Roofline/dry-run artifacts are produced by
`python -m repro.launch.dryrun --all` + `python benchmarks/roofline.py`
(separate processes because they force 512 host devices).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from benchmarks import (  # noqa: E402
    arch_physics,
    fair_queuing,
    info_ladder,
    latency_calibration,
    layerwise,
    main_policy,
    multi_class,
    overload_policy,
    predictor_noise,
    sharegpt_trace,
    threshold_sensitivity,
)

SUITES = [
    ("main_policy[T2]", main_policy.run),
    ("info_ladder[T1]", info_ladder.run),
    ("fair_queuing[T4]", fair_queuing.run),
    ("overload_policy[T5]", overload_policy.run),
    ("layerwise[F7]", layerwise.run),
    ("predictor_noise[F8]", predictor_noise.run),
    ("threshold_sensitivity[4.9]", threshold_sensitivity.run),
    ("sharegpt_trace[T6]", sharegpt_trace.run),
    ("latency_calibration[T3]", latency_calibration.run),
    # beyond-paper: client stack vs per-architecture provider physics
    ("arch_physics[ext]", arch_physics.run),
    # beyond-paper: config-driven K-class scheduling (tenants/lanes sweep)
    ("multi_class[ext]", multi_class.run),
]


def main() -> None:
    rows = []
    for name, fn in SUITES:
        print(f"=== {name}", flush=True)
        t0 = time.time()
        out = fn()
        path = out[0] if isinstance(out, tuple) else out
        rows.append((name, time.time() - t0, path))
    print("\nname,seconds,artifact")
    for name, secs, path in rows:
        print(f"{name},{secs:.1f},{os.path.relpath(path)}", flush=True)


if __name__ == "__main__":
    main()
